"""Benchmark harness — one benchmark per paper claim/figure.

  fig2_t0t1        — Fig 2: wall time + event count vs WAN bandwidth (the
                     interrupt-storm superlinearity)
  agent_scaling    — §1/§4: distribute the simulation to lift the one-machine
                     bottleneck (events/s vs agent count)
  sync_overhead    — §4.3: collective-GVT windows vs per-event sync; messages
                     per processed event stays ~O(1)
  scheduler        — §4.1: paper placement vs random/round-robin (load balance
                     + cross-agent message ratio)
  contexts         — fig 9: multiplexing independent runs on one fleet
  exec_compaction  — engine step 4: compact-then-scan (exec_cap) vs full-pool
                     scan, events/s on sparse pools at growing pool_cap
  batched_dispatch — engine step 4: grouped vectorized dispatch vs the PR 1
                     sequential fold on dense same-kind windows (dispatch cost
                     isolated: NOOP handlers, distinct-dst events)
  wide_component   — engine step 4: per-row delta scatter vs the PR 2
                     whole-table merge on wide component tables (64-CPU farms;
                     merge cost isolated: conflict-free JOB_SUBMIT windows)
  insert_churn     — PR 5 pool lifecycle: free-list ring insert/release vs the
                     retained insert_ref O(pool_cap) scan (gated subsystem
                     ratio + informational end-to-end engine ratio)
  fused_superstep  — PR 10 fused window front-end: the one-jit fused select +
                     gather + conflict + group + release-rank program vs the
                     same stages dispatched separately (gated); on TPU also
                     the compiled Pallas megakernel vs the stitched twin
                     ("requires": "tpu"); asserts fused engine == stitched
                     engine == heapq oracle before timing
  adaptive_exec    — PR 5 monitoring-driven exec width: ladder policy vs the
                     static exec_cap=256 default on spill-heavy windows
                     (fewer windows, same events, oracle-exact)
  cache_churn      — PR 4 registry seam: the replica-cache component defined
                     entirely outside core (repro/scenarios/cache.py) running
                     through the registry-generated batched dispatch
                     (gated since PR 5)
  shard_scaling    — PR 6 distributed scale-out: events/s at 64 packed agents
                     on 4 forced host devices vs 1 (shard_map x vmap driver;
                     subprocesses, trajectory entry — no gate on shared-CPU
                     "devices")
  ensemble_throughput — PR 8 vmap-over-seeds ensembles: one fused 128-replica
                     run_ensemble launch vs a sequential run_local loop
                     (replicas/s; gated in the distributed CI job since
                     PR 9 — "requires": "distributed" in baseline.json)
  fleet_resume     — PR 9 elastic orchestration: orchestrated preempt+resume
                     wall vs uninterrupted (resume_overhead ratio; trajectory
                     entry — no gate)
  kernels          — µs/call for each Pallas kernel's XLA reference path
  workload_sim     — DESIGN.md §2: DES-predicted step time vs analytic roofline

Output: ``name,us_per_call,derived`` CSV rows on stdout. ``--json PATH``
additionally writes the rows as machine-readable JSON (derived ``k=v`` pairs
parsed into a dict) — CI uploads this as the BENCH_PR2.json artifact and gates
on the batched_dispatch and wide_component speedups
(benchmarks/check_regression.py; see docs/benchmarks.md).
``--quick`` runs only the fast subset (CI smoke): exec_compaction,
batched_dispatch and wide_component at pool_cap=4096, scheduler, kernels,
workload_sim.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Engine, ScenarioBuilder, events as ev
from repro.core import monitoring as mon
from repro.core import scheduler as sched
from repro.core.workload import CellModel, simulate_training

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us: float, derived: str = ""):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}")


def t0t1(wan_bw, n_flows=48, interval=8, n_agents=1, lookahead=2,
         flow_mb=100.0, pool_cap=1024, exec_cap=None, fused_select=False):
    b = ScenarioBuilder(max_cpu=4, queue_cap=32, max_link=4, max_flow=64)
    t0 = b.add_regional_center(n_cpu=2, cpu_power=10.0, disk=20000.0,
                               tape=200000.0, tape_rate=5.0)
    t1 = b.add_regional_center(n_cpu=2, cpu_power=8.0, disk=20000.0,
                               tape=200000.0, tape_rate=5.0)
    wan = b.add_net_region(link_bws=[wan_bw, wan_bw], link_lats=[5, 5])
    b.add_generator(target_lp=wan, kind=ev.K_FLOW_START,
                    payload=[flow_mb, 0, -1, -1, t1["farm"], ev.K_JOB_SUBMIT,
                             t1["storage"], ev.K_DATA_WRITE],
                    interval=interval, count=n_flows)
    kw = {} if exec_cap is None else dict(exec_cap=exec_cap)
    return b.build(n_agents=n_agents, lookahead=lookahead, t_end=200_000,
                   pool_cap=pool_cap, work_per_mb=2.0,
                   fused_select=fused_select, **kw)


def run_engine(built, max_windows=100_000):
    world, own, init_ev, spec = built
    eng = Engine(world, own, init_ev, spec)
    st = eng.run_local(max_windows=max_windows)
    jax.block_until_ready(st.counters)
    return eng, st


def bench_fig2_t0t1():
    """Paper Fig 2: fixed workload, decreasing WAN bandwidth.

    The paper's curve is SEQUENTIAL wall time exploding with the interrupt
    storm; we time the heapq oracle (the sequential simulator) alongside the
    vectorized engine, whose window count stays nearly flat — the distribution
    argument in one row.
    """
    from repro.core import run_sequential
    for bw in (16.0, 4.0, 1.0, 0.25):
        built = t0t1(bw)
        t0 = time.perf_counter()
        _, oc, otrace = run_sequential(*built)
        t_seq = time.perf_counter() - t0
        eng, _ = run_engine(built)                     # compile
        t0 = time.perf_counter()
        _, st = run_engine(built)
        dt = time.perf_counter() - t0
        c = np.asarray(st.counters).sum(axis=0)
        emit(f"fig2_t0t1_bw{bw}", dt * 1e6,
             f"events={int(c[mon.C_EVENTS])};stale={int(c[mon.C_STALE])};"
             f"interrupts={int(c[mon.C_INTERRUPTS])};"
             f"windows={int(np.asarray(st.windows)[0])};"
             f"sequential_ms={t_seq * 1e3:.0f}")


def bench_fig2b_congestion():
    """Fig 2's mechanism on the offered-load axis: at fixed bandwidth, shrink
    the inter-arrival interval — overlap (and thus interrupt/stale events, the
    paper's cost driver) grows superlinearly while the per-flow workload is
    constant. The sequential oracle's wall time follows the event count; the
    conservative-window engine absorbs it in near-constant windows."""
    from repro.core import run_sequential
    for interval in (32, 16, 8, 4):
        built = t0t1(1.0, n_flows=48, interval=interval)
        t0 = time.perf_counter()
        _, oc, otrace = run_sequential(*built)
        t_seq = time.perf_counter() - t0
        c = np.asarray(oc)
        emit(f"fig2b_congestion_iv{interval}", t_seq * 1e6,
             f"events={len(otrace)};stale={int(c[mon.C_STALE])};"
             f"interrupts={int(c[mon.C_INTERRUPTS])};"
             f"dropped_flows={int(c[mon.C_DROP_FLOW])}")


def bench_agent_scaling():
    """Same model, 1..8 agents. On one CPU core vmap lanes run serially, so the
    honest scaling metric is the per-agent load division: the max events any
    single agent processes (== wall time on real parallel hardware)."""
    for a in (1, 2, 4, 8):
        built = t0t1(1.0, n_agents=a)
        run_engine(built)
        t0 = time.perf_counter()
        _, st = run_engine(built)
        dt = time.perf_counter() - t0
        c = np.asarray(st.counters)
        total = int(c[:, mon.C_EVENTS].sum())
        hottest = int(c[:, mon.C_EVENTS].max())
        emit(f"agent_scaling_a{a}", dt * 1e6,
             f"events={total};max_per_agent={hottest};"
             f"parallel_efficiency={total / max(a * hottest, 1):.2f}")


def bench_sync_overhead():
    """Windows (collective syncs) per processed event vs lookahead size —
    the paper's 'minimum number of messages' claim, collectivized."""
    for la in (1, 2, 4, 8):
        built = t0t1(1.0, n_agents=4, lookahead=la)
        run_engine(built)
        t0 = time.perf_counter()
        _, st = run_engine(built)
        dt = time.perf_counter() - t0
        c = np.asarray(st.counters).sum(axis=0)
        windows = int(np.asarray(st.windows)[0])
        events = int(c[mon.C_EVENTS])
        emit(f"sync_overhead_la{la}", dt * 1e6,
             f"windows={windows};events={events};"
             f"syncs_per_event={windows / max(events, 1):.3f}")


def bench_scheduler():
    """Placement quality: paper scheduler vs random vs round-robin."""
    rng = np.random.RandomState(0)
    a, n_lp = 8, 64
    perf = jnp.asarray(rng.rand(a).astype(np.float32) * 10)
    lp_ctx = jnp.asarray(rng.randint(0, 4, n_lp), jnp.int32)

    t0 = time.perf_counter()
    paper = np.asarray(sched.plan_placement(perf, lp_ctx, a))
    dt = time.perf_counter() - t0
    rr = np.arange(n_lp) % a
    rand = rng.randint(0, a, n_lp)

    def stats(placement):
        load = np.bincount(placement, minlength=a)
        # cross-agent message proxy: LP pairs of one ctx on different agents
        cross = 0
        tot = 0
        ctx = np.asarray(lp_ctx)
        for c in range(4):
            ids = np.where(ctx == c)[0]
            for i in ids:
                for j in ids:
                    if i < j:
                        tot += 1
                        cross += placement[i] != placement[j]
        return load.max() / max(load.mean(), 1e-9), cross / max(tot, 1)

    for name, pl in (("paper", paper), ("roundrobin", rr), ("random", rand)):
        imb, cross = stats(pl)
        emit(f"scheduler_{name}", dt * 1e6 if name == "paper" else 0.0,
             f"imbalance={imb:.2f};cross_ratio={cross:.2f}")


def bench_contexts():
    """Two runs multiplexed on one fleet vs run serially."""
    def one_ctx(ctx_count):
        b = ScenarioBuilder(max_cpu=4, max_flow=32)
        for c in range(ctx_count):
            t1 = b.add_regional_center(n_cpu=2, cpu_power=8.0, disk=2000.0,
                                       tape=20000.0, tape_rate=5.0, ctx=c)
            wan = b.add_net_region(link_bws=[1.0], link_lats=[5], ctx=c)
            b.add_generator(target_lp=wan, kind=ev.K_FLOW_START,
                            payload=[40.0, 0, -1, -1, t1["farm"],
                                     ev.K_JOB_SUBMIT, t1["storage"],
                                     ev.K_DATA_WRITE],
                            interval=20, count=12, ctx=c)
        return b.build(n_agents=4, n_ctx=ctx_count, lookahead=2, t_end=20_000,
                       pool_cap=512, work_per_mb=2.0)

    built = one_ctx(1)
    run_engine(built)
    t0 = time.perf_counter()
    run_engine(built)
    t_single = time.perf_counter() - t0

    built = one_ctx(2)
    run_engine(built)
    t0 = time.perf_counter()
    _, st = run_engine(built)
    t_multi = time.perf_counter() - t0
    emit("contexts_multiplex", t_multi * 1e6,
         f"two_runs_vs_serial={t_multi / max(2 * t_single, 1e-9):.2f}x")


def bench_exec_compaction(pool_caps=(1024, 4096, 16384)):
    """Compacted windowed execution vs the seed's full-pool scan.

    Sparse-pool worst case for the seed engine: events spaced wider than the
    lookahead, so every conservative window has ~1 safe event but the seed
    fold still pays O(pool_cap) sequential scan iterations. exec_cap=pool_cap
    reproduces the seed behavior exactly (the compaction is then the identity
    permutation prefix), so the comparison isolates the scan length.
    """
    def build(pool_cap, exec_cap):
        b = ScenarioBuilder(max_cpu=2, queue_cap=8, max_link=2, max_flow=8)
        farm = b.add_farm([5.0])
        n_ev = min(pool_cap // 4, 512)
        for i in range(n_ev):
            b.add_event(time=1 + 8 * i, kind=ev.K_NOOP, src=farm, dst=farm)
        built = b.build(n_agents=1, lookahead=4, t_end=8 * n_ev + 16,
                        pool_cap=pool_cap, emit_cap=64, exec_cap=exec_cap)
        return built, n_ev

    for pool_cap in pool_caps:
        rates = {}
        for label, exec_cap in (("compact", 256), ("fullscan", pool_cap)):
            built, n_ev = build(pool_cap, exec_cap)
            run_engine(built)                         # compile
            t0 = time.perf_counter()
            _, st = run_engine(built)
            dt = time.perf_counter() - t0
            n = int(np.asarray(st.counters)[0, mon.C_EVENTS])
            assert n == n_ev, (n, n_ev)
            rates[label] = n / dt
        emit(f"exec_compaction_p{pool_cap}", 1e6 / rates["compact"],
             f"events_s_compact={rates['compact']:.0f};"
             f"events_s_fullscan={rates['fullscan']:.0f};"
             f"speedup={rates['compact'] / rates['fullscan']:.1f}x")


def bench_batched_dispatch(pool_caps=(4096,), width=1024, lookahead=4):
    """Grouped vectorized dispatch vs the PR 1 sequential compacted fold.

    Dense same-kind worst case for the sequential fold: every conservative
    window holds ``width`` same-tick NOOP events to distinct LPs, so the PR 1
    path pays ``width`` sequential scan iterations while the batched path runs
    one vmapped dispatch (conflict-free by construction) — the benchmark
    isolates dispatch cost because the NOOP handler itself does no work.
    """
    def build(pool_cap, batched):
        b = ScenarioBuilder(max_cpu=1, queue_cap=2, max_link=1, max_flow=2)
        sinks = [b.add_idle_lp() for _ in range(width)]
        n_tick = max(pool_cap // width, 1)
        for t in range(n_tick):
            for lp in sinks:
                b.add_event(time=1 + lookahead * t, kind=ev.K_NOOP,
                            src=lp, dst=lp)
        built = b.build(n_agents=1, lookahead=lookahead,
                        t_end=lookahead * (n_tick + 1) + 2,
                        pool_cap=pool_cap, emit_cap=64, exec_cap=width,
                        batched_dispatch=batched)
        return built, n_tick * width

    for pool_cap in pool_caps:
        rates = {}
        for label, batched in (("batched", True), ("sequential", False)):
            (world, own, init_ev, spec), n_ev = build(pool_cap, batched)
            eng = Engine(world, own, init_ev, spec)
            jax.block_until_ready(eng.run_local().counters)   # compile
            t0 = time.perf_counter()
            st = eng.run_local()                              # cached jit
            jax.block_until_ready(st.counters)
            dt = time.perf_counter() - t0
            n = int(np.asarray(st.counters)[0, mon.C_EVENTS])
            assert n == n_ev, (n, n_ev)
            rates[label] = n / dt
        emit(f"batched_dispatch_p{pool_cap}", 1e6 / rates["batched"],
             f"events_s_batched={rates['batched']:.0f};"
             f"events_s_sequential={rates['sequential']:.0f};"
             f"speedup={rates['batched'] / rates['sequential']:.2f}x")


def bench_wide_component(pool_caps=(4096,), width=256, n_cpu=64, lookahead=4):
    """Per-row delta scatter vs the PR 2 whole-table merge on wide tables.

    ``width`` farms of ``n_cpu`` CPUs each (cpu tables are (width, n_cpu) —
    ≥64 columns), one JOB_SUBMIT per farm per window (conflict-free by
    construction), alternating with the JOB_END completion windows. Both
    configurations run the identical grouped vectorized dispatch; only the
    merge differs — the delta path scatters ``width`` declared rows
    (O(lanes x row)), the dense path materializes ``width`` full-table copies
    and picks changed elements (O(lanes x tables), the PR 2 strategy). The
    events/s ratio therefore isolates the merge cost, which is what the
    regression gate pins (machine-normalized: both sides measured in this
    process on this host).
    """
    def build(pool_cap, merge_mode):
        b = ScenarioBuilder(max_cpu=n_cpu, queue_cap=8, max_link=1, max_flow=2)
        farms = [b.add_farm([1.0] * n_cpu) for _ in range(width)]
        n_tick = max(pool_cap // (2 * width), 1)
        # submits at 1 + 8t start a 3-tick job on a free CPU; with
        # lookahead=4 the JOB_END lands at 5 + 8t — its own window, so
        # submit and completion windows alternate and never conflict
        for t in range(n_tick):
            for lp in farms:
                b.add_event(time=1 + 2 * lookahead * t, kind=ev.K_JOB_SUBMIT,
                            src=lp, dst=lp, payload=[3.0, 1.0, -1, -1, 0])
        built = b.build(n_agents=1, lookahead=lookahead,
                        t_end=2 * lookahead * (n_tick + 1) + 2,
                        pool_cap=pool_cap, emit_cap=width + 8, exec_cap=width,
                        merge_mode=merge_mode)
        return built, 2 * n_tick * width

    for pool_cap in pool_caps:
        rates = {}
        for merge_mode in ("delta", "dense"):
            (world, own, init_ev, spec), n_ev = build(pool_cap, merge_mode)
            eng = Engine(world, own, init_ev, spec)
            jax.block_until_ready(eng.run_local().counters)   # compile
            t0 = time.perf_counter()
            st = eng.run_local()                              # cached jit
            jax.block_until_ready(st.counters)
            dt = time.perf_counter() - t0
            c = np.asarray(st.counters)[0]
            n = int(c[mon.C_EVENTS])
            assert n == n_ev, (n, n_ev)
            assert int(c[mon.C_BATCH_FALLBACK]) == 0, "scenario must be clean"
            rates[merge_mode] = n / dt
        emit(f"wide_component_p{pool_cap}", 1e6 / rates["delta"],
             f"events_s_delta={rates['delta']:.0f};"
             f"events_s_dense={rates['dense']:.0f};"
             f"width={width};n_cpu={n_cpu};"
             f"speedup={rates['delta'] / rates['dense']:.2f}x")


def bench_insert_churn(pool_caps=(4096,), burst=256, iters=64, width=256,
                       n_ticks=8, lookahead=4):
    """Pool-lifecycle churn: the free-list ring vs the retained insert_ref scan.

    The gated metric isolates the subsystem the ring replaced: a jitted loop
    of the per-window lifecycle cycle — release the previous burst's slots,
    insert a dense ``burst``-row emit batch — over a half-resident pool at
    ``pool_cap``. The ring path does O(burst) work per cycle; the scan path
    pays the O(pool_cap) free-rank cumsum + rank->slot scatter (insert) and
    the pool-wide mask (release) every cycle, exactly as the PR 1-4 engine
    did. events/s ratio, machine-normalized (both sides in one process).

    The same row also reports the *end-to-end* engine ratio on an emit-heavy
    dense generator scenario (``engine_speedup``, informational): there the
    common per-window costs — the (time, seq) selection sort above all —
    dilute the lifecycle win, which is exactly why the gate pins the
    subsystem, not the whole window.
    """
    for pool_cap in pool_caps:
        resident = pool_cap // 2
        pool0 = ev.empty_pool(pool_cap)
        rows = [dict(time=100_000 + i, seq=i, kind=0, src=0, dst=0)
                for i in range(resident)]
        pool0, _ = ev.insert(pool0, ev.batch_from_rows(rows))
        batch = ev.batch_from_rows(
            [dict(time=50_000 + i, seq=4096 + i, kind=0, src=0, dst=0)
             for i in range(burst)])
        ones = jnp.ones((burst,), bool)

        @jax.jit
        def churn_ring(pool):
            def body(_, pool):
                slots = pool.free_ring[
                    (pool.free_head + jnp.arange(burst, dtype=jnp.int32))
                    % pool_cap]
                pool, _ = ev.insert(pool, batch)
                return ev.release(pool, slots, ones)
            return jax.lax.fori_loop(0, iters, body, pool)

        @jax.jit
        def churn_ref(pool):
            def body(_, pool):
                before = pool.valid
                pool, _ = ev.insert_ref(pool, batch)
                return ev.pop_mask_ref(pool, pool.valid & ~before)
            return jax.lax.fori_loop(0, iters, body, pool)

        rates = {}
        for label, fn in (("ring", churn_ring), ("ref", churn_ref)):
            out = fn(pool0)
            jax.block_until_ready(out.valid)              # compile
            assert int(np.asarray(out.free_count)) == pool_cap - resident
            t0 = time.perf_counter()
            out = fn(pool0)
            jax.block_until_ready(out.valid)
            rates[label] = iters * burst / (time.perf_counter() - t0)

        # end-to-end engine context: width generators, each window inserting
        # ~2*width emits (activity + next tick) — emit-heavy dense windows
        def build_engine(insert_mode):
            b = ScenarioBuilder(max_cpu=1, queue_cap=2, max_link=1, max_flow=2)
            for _ in range(width):
                lp = b.add_idle_lp()
                b.add_generator(target_lp=lp, kind=ev.K_NOOP, payload=[],
                                interval=lookahead, count=n_ticks)
            return b.build(n_agents=1, lookahead=lookahead,
                           t_end=lookahead * (n_ticks + 3) + 2,
                           pool_cap=pool_cap, emit_cap=2 * width + 8,
                           exec_cap=2 * width, insert_mode=insert_mode)

        erates = {}
        for mode in ("ring", "ref"):
            world, own, init_ev, spec = build_engine(mode)
            eng = Engine(world, own, init_ev, spec)
            jax.block_until_ready(eng.run_local().counters)   # compile
            t0 = time.perf_counter()
            st = eng.run_local()
            jax.block_until_ready(st.counters)
            dt = time.perf_counter() - t0
            n = int(np.asarray(st.counters)[0, mon.C_EVENTS])
            assert n == 2 * width * n_ticks, (n, 2 * width * n_ticks)
            erates[mode] = n / dt

        emit(f"insert_churn_p{pool_cap}", 1e6 / rates["ring"],
             f"events_s_ring={rates['ring']:.0f};"
             f"events_s_ref={rates['ref']:.0f};"
             f"burst={burst};resident={resident};"
             f"speedup={rates['ring'] / rates['ref']:.2f}x;"
             f"engine_events_s_ring={erates['ring']:.0f};"
             f"engine_events_s_ref={erates['ref']:.0f};"
             f"engine_speedup={erates['ring'] / erates['ref']:.2f}x")


def bench_fused_superstep(pool_cap=4096, exec_cap=256, iters=500):
    """PR 10 fused window front-end: the superstep megakernel seam.

    The gated metric is the fused window *tail* — everything the megakernel
    fuses downstream of the (time, seq) sort the two paths share: exec mask,
    slot gathers, conflict mask, same-kind grouping, release ranks — run as
    the megakernel's own algorithm (pairwise duplicate count instead of the
    sort-based ``sync.conflict_mask``) in ONE program, vs the stitched
    composition dispatched one stage at a time with every intermediate
    index/rank array materialized between dispatches, exactly the per-hook
    shape the engine's non-fused path composes from. Dense windows over a
    full pool at ``pool_cap``; windows/s ratio, machine-normalized (both
    sides in one process; insert_churn idiom). The shared pool-wide sort is
    *excluded* from both sides — it is identical work, and including it
    would only dilute the seam the gate pins. Byte-identity of the two
    tails (and of the ref oracle ``fused_select_ref``) is asserted in-bench.

    On a TPU backend the same family adds the compiled-Pallas lane
    (``fused_superstep_tpu_*``, ``"requires": "tpu"`` in baseline.json): the
    complete megakernel — sort included, ring cursor in SMEM, every
    intermediate VMEM-resident — against the one-jit stitched twin
    ``engine.fused_select_xla``, both compiled.

    Before timing anything the row asserts end-to-end byte-identity: the
    fused engine (``spec.fused_select=True``, the interpret-Pallas path off
    TPU) runs the identical trace/counters/world as the stitched engine and
    the sequential heapq oracle on a dense scenario. ``engine_speedup`` is
    the end-to-end fused-engine ratio (informational — off TPU the
    interpreted megakernel *loses*; the gate pins the fusion seam itself).
    """
    from repro.core import merged_engine_trace, run_sequential, sync
    from repro.core.engine import (fused_select_xla, group_by_kind_xla,
                                   select_events_xla)
    from repro.kernels import ref as kref

    # --- byte-identity proof: fused engine == stitched engine == oracle ---
    built_f = t0t1(2.0, n_flows=32, pool_cap=1024, fused_select=True)
    built_s = t0t1(2.0, n_flows=32, pool_cap=1024)
    _, _, otrace = run_sequential(*built_s)
    states, erates = {}, {}
    for label, built in (("fused", built_f), ("stitched", built_s)):
        eng = Engine(*built, trace_cap=8192)
        jax.block_until_ready(eng.run_local().counters)       # compile
        t0 = time.perf_counter()
        st = eng.run_local()
        jax.block_until_ready(st.counters)
        dt = time.perf_counter() - t0
        states[label] = st
        erates[label] = int(np.asarray(st.counters)[:, mon.C_EVENTS].sum()) / dt
        trace = merged_engine_trace(np.asarray(st.trace),
                                    np.asarray(st.trace_n))
        assert trace == otrace, f"{label} engine trace != heapq oracle"
    assert jax.tree.all(jax.tree.map(
        lambda a, b: bool((np.asarray(a) == np.asarray(b)).all()),
        states["fused"], states["stitched"])), \
        "fused engine state != stitched engine state"

    # --- the fusion seam, subsystem-isolated on a dense full pool ---
    cap, m = pool_cap, exec_cap
    n_kinds, n_tables, n_res = ev.N_KINDS, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 10)
    safe = jax.random.bernoulli(ks[0], 0.9, (cap,))
    tk = jnp.where(safe, jax.random.randint(ks[1], (cap,), 0, 1000),
                   jnp.int32(2**31 - 1))
    sq = jax.random.randint(ks[2], (cap,), 0, 2**20)
    tm = jax.random.randint(ks[3], (cap,), 0, 1000)
    kind = jax.random.randint(ks[4], (cap,), 0, n_kinds)
    src = jax.random.randint(ks[5], (cap,), 0, 16)
    dst = jax.random.randint(ks[6], (cap,), 0, 16)
    ctx = jax.random.randint(ks[7], (cap,), 0, 100)
    pay = jax.random.normal(ks[8], (cap, ev.PAYLOAD))
    tbl = jax.random.randint(ks[9], (cap,), 0, n_tables)
    res = jax.random.randint(ks[9], (cap,), 0, n_res)
    valid = jnp.ones((cap,), bool)
    tail = jnp.int32(cap - 7)                      # ring cursor wraps
    kw = dict(n_kinds=n_kinds, n_res=n_res, n_tables=n_tables)

    # the shared sort-select — identical work on both sides, computed once
    # and excluded from the timed seam
    exec_idx = jax.jit(lambda tk, sq: select_events_xla(tk, sq, m))(tk, sq)
    jax.block_until_ready(exec_idx)

    @jax.jit
    def fused_tail(idx, tail):
        # the megakernel's own window tail as one program: exec mask, the
        # slot gathers, the pairwise-count conflict mask (no sort), group,
        # release ranks — nothing materialized between stages
        es = sync.exec_selection_ring(safe, idx)
        tb, rs = tbl[idx], res[idx]
        rkey = tb * jnp.int32(n_res) + rs
        comp = es & (tb > 0)
        cnt = jnp.sum((rkey[:, None] == rkey[None, :]) & comp[None, :],
                      axis=1)
        clean = es & ~(comp & (cnt >= 2))
        g = (tm[idx], kind[idx], src[idx], dst[idx], ctx[idx], pay[idx],
             valid[idx])
        order, _rank, _counts = group_by_kind_xla(g[1], clean,
                                                  n_kinds=n_kinds)
        w = es.astype(jnp.int32)
        return es, clean, order, (tail + jnp.cumsum(w) - w) % cap, g

    # the stitched composition: one dispatch per hook, intermediates
    # materialized between them (the non-fused engine's per-window shape)
    s_safe = jax.jit(sync.exec_selection_ring)
    s_gather = jax.jit(lambda idx, *cols: tuple(c[idx] for c in cols))
    s_clean = jax.jit(lambda es, tb, rs: es & ~sync.conflict_mask(
        es, tb, rs, n_res=n_res, n_tables=n_tables))
    s_group = jax.jit(
        lambda kind_w, clean: group_by_kind_xla(kind_w, clean,
                                                n_kinds=n_kinds)[0])

    @jax.jit
    def s_rel(es, tail):
        w = es.astype(jnp.int32)
        return (tail + jnp.cumsum(w) - w) % cap

    def staged_tail(idx, tail):
        es = s_safe(safe, idx)
        tb, rs = s_gather(idx, tbl, res)
        clean = s_clean(es, tb, rs)
        g = s_gather(idx, tm, kind, src, dst, ctx, pay, valid)
        order = s_group(g[1], clean)
        return es, clean, order, s_rel(es, tail), g

    rates = {}
    for label, fn in (("fused", fused_tail), ("staged", staged_tail)):
        jax.block_until_ready(fn(exec_idx, tail))  # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(exec_idx, tail)
        jax.block_until_ready(out)
        rates[label] = iters / (time.perf_counter() - t0)

    # the two tails are byte-identical, and both match the ref oracle
    got, want = fused_tail(exec_idx, tail), staged_tail(exec_idx, tail)
    for a, b in zip(got[:4], want[:4]):
        assert (np.asarray(a) == np.asarray(b)).all()
    for a, b in zip(got[4], want[4]):
        assert (np.asarray(a) == np.asarray(b)).all()
    fs_ref = kref.fused_select_ref(tk, sq, safe, tm, kind, src, dst, ctx,
                                   pay, valid, tbl, res, tail, m, **kw)
    assert (np.asarray(fs_ref.exec_idx) == np.asarray(exec_idx)).all()
    assert (np.asarray(fs_ref.clean) == np.asarray(got[1])).all()
    assert (np.asarray(fs_ref.order) == np.asarray(got[2])).all()

    emit(f"fused_superstep_p{pool_cap}", 1e6 / rates["fused"],
         f"windows_s_fused={rates['fused']:.0f};"
         f"windows_s_staged={rates['staged']:.0f};"
         f"exec_cap={m};"
         f"speedup={rates['fused'] / rates['staged']:.2f}x;"
         f"engine_events_s_fused={erates['fused']:.0f};"
         f"engine_events_s_stitched={erates['stitched']:.0f};"
         f"engine_speedup={erates['fused'] / erates['stitched']:.2f}x")

    if jax.default_backend() == "tpu":
        # the compiled megakernel itself (sort included, SMEM ring cursor)
        # vs the one-jit stitched twin
        from repro.kernels import ops

        @jax.jit
        def one_jit_stitched(tail):
            fs = fused_select_xla(tk, sq, safe, tm, kind, src, dst, ctx,
                                  pay, valid, tbl, res, tail, m, **kw)
            return fs.exec_safe, fs.clean, fs.order, fs.rel_pos

        def pallas(tail):
            fs = ops.fused_select(tk, sq, safe, tm, kind, src, dst, ctx, pay,
                                  valid, tbl, res, tail, m, **kw)
            return fs.exec_safe, fs.clean, fs.order, fs.rel_pos

        prates = {}
        for label, fn in (("pallas", pallas), ("stitched", one_jit_stitched)):
            jax.block_until_ready(fn(tail))        # compile
            t0 = time.perf_counter()
            for _ in range(iters):
                out = fn(tail)
            jax.block_until_ready(out)
            prates[label] = iters / (time.perf_counter() - t0)
        for a, b in zip(pallas(tail), one_jit_stitched(tail)):
            assert (np.asarray(a) == np.asarray(b)).all()
        emit(f"fused_superstep_tpu_p{pool_cap}",
             1e6 / prates["pallas"],
             f"windows_s_pallas={prates['pallas']:.0f};"
             f"windows_s_stitched={prates['stitched']:.0f};"
             f"exec_cap={m};"
             f"speedup={prates['pallas'] / prates['stitched']:.2f}x")


def bench_adaptive_exec(width=1024, n_ticks=4, lookahead=4, pool_cap=4096):
    """Monitoring-driven exec width vs the static exec_cap=256 default.

    Spill-heavy scenario: every conservative window offers ``width`` same-tick
    events, so the static default executes 256 and spills the rest — paying
    four windows (four GVT collectives) per tick. The adaptive ladder grows to
    the window size after one spilled window and finishes in ~width/ladder_top
    fewer windows, byte-identical to the oracle (spill semantics are exact for
    any width sequence — tests/test_policy.py pins the trace equality).
    Reported: window counts, windows saved, and wall rates (informational —
    the adaptive driver syncs monitoring to the host every window, which the
    vmap driver avoids, so on CPU the window saving is the honest headline).
    """
    from repro.core.policy import ExecPolicy

    def build(**kw):
        b = ScenarioBuilder(max_cpu=1, queue_cap=2, max_link=1, max_flow=2)
        sinks = [b.add_idle_lp() for _ in range(width)]
        for t in range(n_ticks):
            for lp in sinks:
                b.add_event(time=1 + lookahead * t, kind=ev.K_NOOP,
                            src=lp, dst=lp)
        return b.build(n_agents=1, lookahead=lookahead,
                       t_end=lookahead * (n_ticks + 1) + 2,
                       pool_cap=pool_cap, emit_cap=64, **kw)

    world, own, init_ev, spec = build(exec_cap=256)
    eng_s = Engine(world, own, init_ev, spec)
    jax.block_until_ready(eng_s.run_local().counters)     # compile
    t0 = time.perf_counter()
    st_s = eng_s.run_local()
    jax.block_until_ready(st_s.counters)
    dt_s = time.perf_counter() - t0

    ladder = ExecPolicy(ladder=(256, 512, min(width, pool_cap)))
    world, own, init_ev, spec = build(exec_policy=ladder)
    eng_a = Engine(world, own, init_ev, spec)
    eng_a.run_adaptive()                                   # compile rungs
    t0 = time.perf_counter()
    st_a = eng_a.run_adaptive()
    dt_a = time.perf_counter() - t0

    n = int(np.asarray(st_s.counters)[0, mon.C_EVENTS])
    assert n == int(np.asarray(st_a.counters)[0, mon.C_EVENTS]) == width * n_ticks
    w_s = int(np.asarray(st_s.windows)[0])
    w_a = int(np.asarray(st_a.windows)[0])
    assert w_a < w_s, (w_a, w_s)
    emit("adaptive_exec", dt_a * 1e6,
         f"windows_static={w_s};windows_adaptive={w_a};"
         f"windows_saved={w_s - w_a};"
         f"events_s_static={n / dt_s:.0f};events_s_adaptive={n / dt_a:.0f};"
         f"spill_static={int(np.asarray(st_s.counters)[0, mon.C_EXEC_SPILL])};"
         f"spill_adaptive={int(np.asarray(st_a.counters)[0, mon.C_EXEC_SPILL])}")


def bench_cache_churn(pool_caps=(4096,), width=256, n_keys=4, lookahead=4):
    """The outside-core replica-cache component under batched dispatch.

    ``width`` cache LPs, one lookup per cache per round (distinct rows —
    conflict-free batch), keys cycling mod ``n_keys`` so the run mixes cold
    misses (which emit CACHE_FILLs into their own window) with warm hits.
    Registry-generated handlers must keep batched-dispatch throughput: the
    events/s ratio vs the sequential fold is recorded as a trajectory (no
    regression gate yet — see benchmarks/baseline.json "trajectory").
    """
    import dataclasses

    from repro.scenarios.cache import build_churn_scenario

    for pool_cap in pool_caps:
        n_rounds = max(pool_cap // (2 * width), 2)
        built, _caches = build_churn_scenario(
            n_caches=width, n_keys=n_keys, n_rounds=n_rounds,
            cache_ways=n_keys, miss_lat=lookahead, lookahead=lookahead,
            pool_cap=pool_cap, emit_cap=2 * width + 8, exec_cap=width)
        world, own, init_ev, spec = built
        rates = {}
        for label, batched in (("batched", True), ("sequential", False)):
            spec_b = dataclasses.replace(spec, batched_dispatch=batched)
            eng = Engine(world, own, init_ev, spec_b)
            jax.block_until_ready(eng.run_local().counters)   # compile
            t0 = time.perf_counter()
            st = eng.run_local()                              # cached jit
            jax.block_until_ready(st.counters)
            dt = time.perf_counter() - t0
            c = np.asarray(st.counters)[0]
            n = int(c[mon.C_EVENTS])
            assert int(c[mon.C_BATCH_FALLBACK]) == 0, "scenario must be clean"
            rates[label] = n / dt
        w = jax.tree.map(lambda x: np.asarray(x[0]), st.world)
        hits, miss = int(w.cache_hits.sum()), int(w.cache_miss.sum())
        emit(f"cache_churn_p{pool_cap}", 1e6 / rates["batched"],
             f"events_s_batched={rates['batched']:.0f};"
             f"events_s_sequential={rates['sequential']:.0f};"
             f"width={width};hits={hits};misses={miss};"
             f"speedup={rates['batched'] / rates['sequential']:.2f}x")


def bench_trace_stream(n_flows=32, n_agents=2, ring=64, drain_every=8,
                       exec_cap=32):
    """PR 7 host-streaming trace drain: events/s with the device-side ring +
    io_callback drain vs (a) tracing off and (b) a big in-device buffer.

    Same scenario three ways, one process, one host — the gated ``speedup``
    is the stream/off throughput ratio (<= 1; it prices the whole streaming
    path: the host-stepped window driver replacing the fused while_loop, the
    per-window drain callback, and the host-side span reassembly).
    ``stream_vs_buffer`` prices the drain against in-device tracing under
    the same driver economics. Correctness rides along: the streamed trace
    must reassemble byte-identical to the in-device buffer's merge with
    C_TRACE_DROP == 0 — the ring (``ring`` rows, far below the run's total)
    wraps many times over.
    """
    from repro.core import TraceStream, merged_engine_trace

    built = t0t1(4.0, n_flows=n_flows, interval=4, n_agents=n_agents,
                 exec_cap=exec_cap)

    def timed(trace_cap, stream=None):
        world, own, init_ev, spec = built
        kw = dict(trace_cap=trace_cap)
        if stream is not None:
            kw.update(trace_stream=stream, drain_every=drain_every)
        eng = Engine(world, own, init_ev, spec, **kw)
        jax.block_until_ready(eng.run_local().counters)   # compile
        t0 = time.perf_counter()
        st = eng.run_local()
        jax.block_until_ready(st.counters)
        return st, time.perf_counter() - t0

    st_off, dt_off = timed(0)
    st_buf, dt_buf = timed(1 << 16)
    ts = TraceStream()
    st_str, dt_str = timed(ring, stream=ts)

    c = np.asarray(st_str.counters)
    n = int(c[:, mon.C_EVENTS].sum())
    assert n == int(np.asarray(st_off.counters)[:, mon.C_EVENTS].sum())
    drop = int(c[:, mon.C_TRACE_DROP].sum())
    assert drop == 0, f"streaming dropped {drop} trace rows"
    assert int(np.asarray(st_str.trace_n).max()) > ring, "ring never wrapped"
    want = merged_engine_trace(np.asarray(st_buf.trace),
                               np.asarray(st_buf.trace_n))
    assert ts.merged() == want, "streamed trace != in-device buffer"

    emit("trace_stream", dt_str * 1e6,
         f"events={n};streamed={ts.n_streamed};ring={ring};"
         f"windows={int(np.asarray(st_str.windows)[0])};trace_drop={drop};"
         f"events_s_off={n / dt_off:.0f};events_s_buffer={n / dt_buf:.0f};"
         f"events_s_stream={n / dt_str:.0f};"
         f"stream_vs_buffer={dt_buf / dt_str:.2f};"
         f"speedup={dt_off / dt_str:.2f}")


def bench_ensemble_throughput(replicas=128, seq_sample=8):
    """PR 8 vmap-over-seeds ensembles: replicas/s for one fused
    ``run_ensemble`` launch vs a sequential ``run_local`` loop over
    individually seeded states (``seq_sample`` runs extrapolated to a rate).

    The scenario is the failure-injection farm — its ``fp_rng`` LCG is what
    the default seed jump decorrelates, so the replicas genuinely diverge
    (different window counts) rather than re-running one trajectory R times.
    Correctness rides along: a sampled replica's full state slice must be
    byte-identical to its individual seeded run (the while_loop batching
    freezes finished replicas, it never lets them keep stepping). Recorded
    as a baseline.json *trajectory* entry — no gate; the speedup on
    shared-CPU "devices" prices launch amortization, not real parallel
    silicon.
    """
    from repro.core.engine import seed_rng_fields
    from repro.scenarios.failures import build_failure_scenario

    built, _info = build_failure_scenario(n_farms=2, pool_cap=128)
    eng = Engine(*built)
    seeds = np.arange(replicas, dtype=np.int32)
    jax.block_until_ready(eng.run_ensemble(seeds).counters)      # compile
    t0 = time.perf_counter()
    out = eng.run_ensemble(seeds)
    jax.block_until_ready(out.counters)
    dt_ens = time.perf_counter() - t0

    solo = Engine(*built)
    seed_one = jax.jit(seed_rng_fields)
    init = solo.init_state()
    jax.block_until_ready(
        solo.run_local(state=seed_one(init, np.int32(0))).counters)  # compile
    t0 = time.perf_counter()
    for s in range(seq_sample):
        st = solo.run_local(state=seed_one(init, np.int32(s)))
        jax.block_until_ready(st.counters)
    dt_seq = time.perf_counter() - t0

    r = replicas - 1
    one = solo.run_local(state=seed_one(init, np.int32(r)))
    same = jax.tree.all(jax.tree.map(
        lambda x, y: bool((np.asarray(x)[r] == np.asarray(y)).all()),
        out, one))
    assert bool(same), "ensemble replica != individual seeded run"

    rate_ens = replicas / dt_ens
    rate_seq = seq_sample / dt_seq
    n_events = int(np.asarray(out.counters)[:, :, mon.C_EVENTS].sum())
    n_windows = len({int(w) for w in np.asarray(out.windows)[:, 0]})
    emit("ensemble_throughput", dt_ens * 1e6,
         f"replicas={replicas};events={n_events};"
         f"distinct_window_counts={n_windows};"
         f"replicas_s_ensemble={rate_ens:.1f};replicas_s_seq={rate_seq:.1f};"
         f"speedup={rate_ens / rate_seq:.2f}")


def bench_shard_scaling(n_agents=64, n_ticks=32, lookahead=2):
    """Distributed scale-out: events/s at 64 packed agents, 4 host devices vs
    1 (the shard_map x vmap driver; K = 16 vs 64 lanes per shard).

    Each agent owns one idle LP with one NOOP per tick, so every conservative
    window executes one event per agent — embarrassingly agent-parallel,
    isolating the driver overheads (staged all_to_all + tuple-axis GVT
    collective vs pure vmap lanes). Subprocesses, because the host device
    count is fixed at jax import. Recorded as a baseline.json *trajectory*
    entry, no gate: forced host devices share this container's CPU, so the
    wall-clock ratio is hardware truth only on a real multi-device fleet.
    """
    import os
    import subprocess
    import sys

    child = r"""
import os, sys
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + sys.argv[1])
import json, time
import numpy as np
import jax
from jax.sharding import Mesh
from repro.core import Engine, ScenarioBuilder, events as ev
from repro.core import monitoring as mon

n_agents, n_ticks, lookahead = (int(a) for a in sys.argv[2:5])
b = ScenarioBuilder(max_cpu=1, queue_cap=2, max_link=1, max_flow=2)
lps = [b.add_idle_lp() for _ in range(n_agents)]
for t in range(n_ticks):
    for lp in lps:
        b.add_event(time=1 + lookahead * t, kind=ev.K_NOOP, src=lp, dst=lp)
built = b.build(n_agents=n_agents, lookahead=lookahead,
                t_end=lookahead * (n_ticks + 1) + 2, pool_cap=n_ticks + 2,
                emit_cap=8)
eng = Engine(*built)
mesh = Mesh(np.array(jax.devices()), ("agents",))
jax.block_until_ready(eng.run_distributed(mesh).counters)   # compile
t0 = time.perf_counter()
st = eng.run_distributed(mesh)
jax.block_until_ready(st.counters)
dt = time.perf_counter() - t0
c = np.asarray(st.counters)
print(json.dumps({"events": int(c[:, mon.C_EVENTS].sum()), "s": dt,
                  "windows": int(np.asarray(st.windows)[0])}))
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    res = {}
    for nd in (1, 4):
        out = subprocess.run(
            [sys.executable, "-c", child, str(nd), str(n_agents),
             str(n_ticks), str(lookahead)],
            capture_output=True, text=True, env=env, timeout=900)
        assert out.returncode == 0, out.stderr[-2000:]
        res[nd] = json.loads(out.stdout.strip().splitlines()[-1])
    assert res[1]["events"] == res[4]["events"] == n_agents * n_ticks
    eps = {nd: r["events"] / r["s"] for nd, r in res.items()}
    emit("shard_scaling", res[4]["s"] * 1e6,
         f"agents={n_agents};devices=4;events={res[4]['events']};"
         f"windows={res[4]['windows']};events_s_d4={eps[4]:.0f};"
         f"events_s_d1={eps[1]:.0f};speedup={eps[4] / eps[1]:.2f}")


def bench_fleet_resume(preempt_window=16, every=8):
    """PR 9 elastic fleet orchestration: the price of surviving a preemption.

    Same checkpointed scenario twice through the Orchestrator on one host:
    uninterrupted, and preempted mid-run (injected shard-loss probe at
    window ``preempt_window``) with automatic resume from the latest
    committed checkpoint. ``resume_overhead`` is the wall ratio
    preempted/uninterrupted — it prices the second attempt's engine
    rebuild + re-jit + checkpoint restore + replayed windows. Trajectory
    entry, no gate: the overhead is dominated by recompilation, which real
    fleets amortize across much longer runs. Byte-equality of the two final
    states is asserted inside (the orchestrator's core promise)."""
    import tempfile

    from repro.fleet import FleetPolicy, Orchestrator

    built = t0t1(2.0, n_flows=32, interval=8, pool_cap=512, exec_cap=64)

    def orchestrated(preempt, tmp):
        pol = FleetPolicy(checkpoint_dir=tmp, checkpoint_every=every)
        orch = Orchestrator(pol, preempt=preempt)
        t0 = time.perf_counter()
        res = orch.run(built, devices=jax.devices()[:1])
        jax.block_until_ready(res.state.counters)
        return res, time.perf_counter() - t0

    with tempfile.TemporaryDirectory() as tmp:       # compile warmup
        orchestrated(None, tmp)
    with tempfile.TemporaryDirectory() as tmp:
        res_u, dt_u = orchestrated(None, tmp)
    with tempfile.TemporaryDirectory() as tmp:
        res_p, dt_p = orchestrated(
            lambda w, a: 1 if a == 0 and w >= preempt_window else None, tmp)
    same = jax.tree.all(jax.tree.map(
        lambda x, y: bool((np.asarray(x) == np.asarray(y)).all()),
        res_p.state, res_u.state))
    assert bool(same), "preempted+resumed state != uninterrupted"
    assert res_p.counts["PREEMPT"] == 1 and res_p.counts["RESUME"] == 1
    n = int(np.asarray(res_u.state.counters)[:, mon.C_EVENTS].sum())
    emit("fleet_resume", dt_p * 1e6,
         f"events={n};windows={int(np.asarray(res_u.state.windows)[0])};"
         f"preempt_window={preempt_window};checkpoint_every={every};"
         f"attempts={res_p.attempts};"
         f"s_uninterrupted={dt_u:.3f};s_preempted={dt_p:.3f};"
         f"resume_overhead={dt_p / dt_u:.2f}")


def bench_kernels():
    from repro.kernels import ops
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (8, 512, 64))
    k = jax.random.normal(ks[1], (4, 512, 64))
    v = jax.random.normal(ks[2], (4, 512, 64))

    from repro.kernels.ref import attention_ref
    fa_ref = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    fa_ref(q, k, v)
    t0 = time.perf_counter()
    for _ in range(10):
        jax.block_until_ready(fa_ref(q, k, v))
    emit("kernel_flash_attention_xla_ref", (time.perf_counter() - t0) / 10 * 1e6,
         "shape=8x512x64")

    from repro.models.linear_rnn import gla_chunked
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (1, 512, 8, 64)) * 0.3))
    qq = jax.random.normal(ks[4], (1, 512, 8, 64))
    gf = jax.jit(lambda q, k, v, w: gla_chunked(q, k, v, w, mode="k")[0])
    gf(qq, qq, qq, w)
    t0 = time.perf_counter()
    for _ in range(10):
        jax.block_until_ready(gf(qq, qq, qq, w))
    emit("kernel_gla_chunked_xla_ref", (time.perf_counter() - t0) / 10 * 1e6,
         "shape=1x512x8x64")

    tk = jax.random.randint(ks[0], (1024,), 0, 1000)
    sq = jax.random.randint(ks[1], (1024,), 0, 2**20)
    from repro.core.engine import lexsort_time_seq
    sf = jax.jit(lexsort_time_seq)
    sf(tk, sq)
    t0 = time.perf_counter()
    for _ in range(50):
        jax.block_until_ready(sf(tk, sq))
    emit("kernel_event_sort_xla_ref", (time.perf_counter() - t0) / 50 * 1e6,
         "n=1024")

    from repro.core.network import incidence, maxmin_rates
    routes = jax.random.randint(ks[2], (64, 3), -1, 8)
    inc = incidence(routes, 8)
    bw = jnp.abs(jax.random.normal(ks[3], (8,))) * 5 + 0.5
    act = jax.random.bernoulli(ks[4], 0.7, (64,))
    mf = jax.jit(maxmin_rates)
    mf(inc, bw, act)
    t0 = time.perf_counter()
    for _ in range(50):
        jax.block_until_ready(mf(inc, bw, act))
    emit("kernel_waterfill_xla_ref", (time.perf_counter() - t0) / 50 * 1e6,
         "F=64,L=8")


def bench_workload_sim():
    """DES-simulated multi-pod step time vs analytic roofline estimate."""
    cell = CellModel(n_pods=2, t_compute_s=0.05, dcn_bytes_per_pod=2e9,
                     n_steps=6)
    t0 = time.perf_counter()
    out = simulate_training(cell)
    dt = time.perf_counter() - t0
    emit("workload_sim_2pod", dt * 1e6,
         f"sim={out['simulated_step_s']:.4f}s;analytic={out['analytic_step_s']:.4f}s;"
         f"events={out['events']}")
    # straggler: pod 0 at 1.5x compute — simulated step stretches accordingly
    cell_s = CellModel(n_pods=2, t_compute_s=0.05, dcn_bytes_per_pod=2e9,
                       n_steps=6, slow_pod_factor=1.5)
    out_s = simulate_training(cell_s)
    emit("workload_sim_straggler", 0.0,
         f"sim={out_s['simulated_step_s']:.4f}s;"
         f"slowdown={out_s['simulated_step_s'] / max(out['simulated_step_s'], 1e-12):.2f}x")


def _parse_derived(derived: str):
    out = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = float(v.rstrip("x"))
        except ValueError:
            out[k] = v
    return out


def write_json(path: str) -> None:
    """Machine-readable results (the CI benchmark artifact + regression gate)."""
    rec = {
        "meta": {"backend": jax.default_backend(), "jax": jax.__version__},
        "rows": [{"name": n, "us_per_call": us, "derived": _parse_derived(d)}
                 for n, us, d in ROWS],
    }
    with open(path, "w") as f:
        json.dump(rec, f, indent=2, sort_keys=True)
    print(f"# wrote {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fast CI-smoke subset only")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write results as machine-readable JSON "
                         "(uploaded from CI as the benchmark artifact and "
                         "checked by benchmarks/check_regression.py)")
    ap.add_argument("--shard-scaling", action="store_true",
                    help="also run the multi-device shard_scaling benchmark "
                         "(subprocesses with forced host device counts; run "
                         "by the dedicated distributed CI job)")
    ap.add_argument("--ensemble", action="store_true",
                    help="also run the ensemble_throughput benchmark "
                         "(128-replica vmap-over-seeds launch vs a "
                         "sequential loop; run by the distributed CI job)")
    ap.add_argument("--fleet", action="store_true",
                    help="also run the fleet_resume benchmark (orchestrated "
                         "preempt+resume wall vs uninterrupted; run by the "
                         "distributed CI job)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.quick:
        bench_exec_compaction(pool_caps=(4096,))
        bench_batched_dispatch(pool_caps=(4096,))
        bench_wide_component(pool_caps=(4096,))
        bench_insert_churn(pool_caps=(4096,))
        bench_fused_superstep()
        bench_adaptive_exec()
        bench_cache_churn(pool_caps=(4096,))
        bench_trace_stream()
        bench_scheduler()
        bench_kernels()
        bench_workload_sim()
    else:
        bench_fig2_t0t1()
        bench_fig2b_congestion()
        bench_agent_scaling()
        bench_sync_overhead()
        bench_scheduler()
        bench_contexts()
        bench_exec_compaction()
        bench_batched_dispatch()
        bench_wide_component()
        bench_insert_churn()
        bench_fused_superstep()
        bench_adaptive_exec()
        bench_cache_churn()
        bench_trace_stream()
        bench_shard_scaling()
        bench_ensemble_throughput()
        bench_fleet_resume()
        bench_kernels()
        bench_workload_sim()
    if args.shard_scaling and args.quick:
        bench_shard_scaling()
    if args.ensemble and args.quick:
        bench_ensemble_throughput()
    if args.fleet and args.quick:
        bench_fleet_resume()
    if args.json:
        write_json(args.json)


if __name__ == "__main__":
    main()
