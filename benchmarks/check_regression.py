"""Benchmark regression gate for CI.

Usage: python benchmarks/check_regression.py RESULTS.json BASELINE.json

Reads the machine-readable output of ``benchmarks/run.py --json`` and fails
(exit 1) when the dense same-kind dispatch benchmark's events/s regresses more
than ``tolerance`` below the committed baseline. The gated metric is the
batched/sequential speedup ratio measured in one process on one host, so the
gate is insensitive to how fast the CI runner happens to be.
"""

import json
import sys


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    with open(sys.argv[1]) as f:
        results = json.load(f)
    with open(sys.argv[2]) as f:
        baseline = json.load(f)

    name = baseline["benchmark"]
    metric = baseline["metric"]
    rows = {row["name"]: row["derived"] for row in results["rows"]}
    if name not in rows:
        print(f"FAIL: benchmark row {name!r} missing from {sys.argv[1]}")
        return 1

    measured = float(rows[name][metric])
    gate = float(baseline["gate_speedup"])
    tolerance = float(baseline["tolerance"])
    floor = gate * (1.0 - tolerance)
    ref = float(baseline["reference"]["speedup"])
    msg = (
        f"{name}.{metric}: measured={measured:.2f} floor={floor:.2f} "
        f"(gate={gate:.2f} -{tolerance:.0%}, dev reference={ref:.2f})"
    )
    print(msg)
    if measured < floor:
        print(f"FAIL: {metric} regressed below the gate floor")
        return 1
    print("OK: no regression")
    return 0


if __name__ == "__main__":
    sys.exit(main())
