"""Benchmark regression gate for CI.

Usage: python benchmarks/check_regression.py RESULTS.json BASELINE.json [--all]

Reads the machine-readable output of ``benchmarks/run.py --json`` and fails
(exit 1) when any gated benchmark metric regresses more than its ``tolerance``
below the committed baseline. Every gated metric is a speedup ratio between two
configurations measured in one process on one host, so the gates are
insensitive to how fast the CI runner happens to be (see docs/benchmarks.md).

``BASELINE.json`` holds a list of gates under the ``"gates"`` key (a bare
single-gate object, the pre-PR 3 format, is also accepted):

    {"gates": [{"benchmark": <row name>, "metric": <derived key>,
                "gate_speedup": <floor>, "tolerance": <fraction>,
                "reference": {...dev measurement, informational...}}, ...]}

A gate may carry ``"requires": "<ci-job>"`` when only one CI job runs its
benchmark (e.g. ensemble_throughput runs in the distributed job only, the
fused_superstep TPU row in the workflow_dispatch TPU job only). The default
invocation *skips* those gates — a missing row would otherwise fail the jobs
that never produce it — and a producing job passes ``--all``, which checks
every gate whose row is present: under ``--all`` a ``requires``-marked gate
whose row is absent is SKIPped (that row belongs to a different opt-in job),
while a missing row for an ordinary gate is still a hard FAIL.
"""

import json
import sys


def check_gate(gate: dict, rows: dict, results_path: str) -> bool:
    name = gate["benchmark"]
    metric = gate["metric"]
    if name not in rows:
        print(f"FAIL: benchmark row {name!r} missing from {results_path}")
        return False

    measured = float(rows[name][metric])
    floor = float(gate["gate_speedup"]) * (1.0 - float(gate["tolerance"]))
    ref = float(gate["reference"]["speedup"])
    msg = (
        f"{name}.{metric}: measured={measured:.2f} floor={floor:.2f} "
        f"(gate={float(gate['gate_speedup']):.2f} "
        f"-{float(gate['tolerance']):.0%}, dev reference={ref:.2f})"
    )
    print(msg)
    if measured < floor:
        print(f"FAIL: {name}.{metric} regressed below the gate floor")
        return False
    return True


def main() -> int:
    run_all = "--all" in sys.argv[1:]
    paths = [a for a in sys.argv[1:] if a != "--all"]
    if len(paths) != 2:
        print(__doc__)
        return 2
    with open(paths[0]) as f:
        results = json.load(f)
    with open(paths[1]) as f:
        baseline = json.load(f)

    gates = baseline["gates"] if "gates" in baseline else [baseline]
    rows = {row["name"]: row["derived"] for row in results["rows"]}
    skipped = 0
    if not run_all:
        only = [g for g in gates if not g.get("requires")]
        skipped = len(gates) - len(only)
        for g in gates:
            if g.get("requires"):
                print(f"SKIP: {g['benchmark']} (requires the "
                      f"{g['requires']!r} CI job; pass --all there)")
        gates = only
    else:
        # --all means "check everything this job produced": a requires-marked
        # gate whose row is absent belongs to a different opt-in job (e.g.
        # the TPU lane) and is skipped, not failed
        present = [g for g in gates
                   if not g.get("requires") or g["benchmark"] in rows]
        skipped = len(gates) - len(present)
        for g in gates:
            if g.get("requires") and g["benchmark"] not in rows:
                print(f"SKIP: {g['benchmark']} (requires the "
                      f"{g['requires']!r} CI job; row not in this run)")
        gates = present
    ok = all([check_gate(g, rows, paths[0]) for g in gates])
    if not ok:
        return 1
    print(f"OK: no regression ({len(gates)} gate(s)"
          + (f", {skipped} skipped" if skipped else "") + ")")
    return 0


if __name__ == "__main__":
    sys.exit(main())
