"""Docs link checker (CI `docs` job; also run by tests/test_docs.py).

Scans README.md and docs/*.md for markdown links and verifies every relative
target resolves to an existing file or directory (anchors stripped; http(s)/
mailto targets skipped). Keeps the documented surface from rotting: a renamed
file or a typo'd path fails CI instead of shipping a dead link.

Usage: python tools/check_docs.py [repo_root]
"""
from __future__ import annotations

import pathlib
import re
import sys

# [text](target) — target without closing parens; images share the syntax.
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SKIP_PREFIXES = ("http://", "https://", "mailto:")


def iter_doc_files(root: pathlib.Path):
    readme = root / "README.md"
    if readme.exists():
        yield readme
    yield from sorted((root / "docs").glob("*.md"))


def check_file(path: pathlib.Path) -> list[str]:
    """Return a list of human-readable errors for dead relative links."""
    errors = []
    text = path.read_text(encoding="utf-8")
    for lineno, line in enumerate(text.splitlines(), start=1):
        for m in _LINK_RE.finditer(line):
            target = m.group(1)
            if target.startswith(_SKIP_PREFIXES) or target.startswith("#"):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (path.parent / rel).exists():
                errors.append(f"{path}:{lineno}: dead link -> {target}")
    return errors


def main(root: str = ".") -> int:
    rootp = pathlib.Path(root).resolve()
    files = list(iter_doc_files(rootp))
    if not files:
        print(f"FAIL: no docs found under {rootp}")
        return 1
    errors = [e for f in files for e in check_file(f)]
    for e in errors:
        print(e)
    if errors:
        print(f"FAIL: {len(errors)} dead link(s) across {len(files)} file(s)")
        return 1
    print(f"OK: {len(files)} file(s), all relative links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1] if len(sys.argv) > 1 else "."))
