"""API drift gate: the registry must stay the single source of the engine tables.

Usage: PYTHONPATH=src python tools/check_api.py   (exit 1 on drift)

Rebuilds the builtin model on a *fresh* registry (``register_builtin_model`` +
``register_builtin_handlers`` — the same declarations core itself runs) and
fails when anything ``repro.core`` exports diverges from the regenerated
schema: ``DELTA_SCHEMA``, ``KIND_TABLE``, the ``World``/``WorldDelta``/
``WorldOwnership`` field layouts, the owner-wins sync field lists, the kind
ids, or handler coverage. Catches hand-edits that bypass the declarative API
(the pre-PR 4 failure mode: six files to keep in sync by eye). Also checks
that ``repro.core.__all__`` — the supported public surface — resolves.

Wired into the CI lint and docs jobs; mirrored by ``tests/test_registry.py``.
"""

from __future__ import annotations

import sys


def check() -> list[str]:
    import repro.core as core
    from repro.core import __all__ as public
    from repro.core import components, events, handlers
    from repro.core.registry import Registry

    fresh = Registry()
    components.register_builtin_model(fresh)
    handlers.register_builtin_handlers(fresh)

    errors: list[str] = []

    def expect(name: str, got, want):
        if got != want:
            errors.append(
                f"{name} drifted:\n  exported: {got}\n  regenerated: {want}"
            )

    expect("events.KIND_TABLE", tuple(events.KIND_TABLE), fresh.kind_table)
    expect("events.N_KINDS", events.N_KINDS, fresh.n_kinds)
    expect("events.N_TABLES", events.N_TABLES, fresh.n_tables)
    expect("handlers.DELTA_SCHEMA", handlers.DELTA_SCHEMA, fresh.delta_schema)
    expect("handlers.ROW_FIELDS", tuple(handlers.ROW_FIELDS), fresh.row_fields)
    expect("World fields", components.World._fields, fresh.world_struct()._fields)
    expect(
        "WorldDelta fields",
        handlers.WorldDelta._fields,
        fresh.delta_struct()._fields,
    )
    expect(
        "WorldOwnership fields",
        components.WorldOwnership._fields,
        fresh.ownership_struct()._fields,
    )
    expect(
        "sync field lists (owner-wins plan)",
        components.BUILTIN.sync_plan(),
        fresh.sync_plan(),
    )
    # counter indices: the registry's builtin counter table must be exactly
    # the monitoring C_* constants (Registry.__init__ seeds from
    # monitoring.BUILTIN_COUNTERS; a drifted index would silently misattribute
    # every stat an extension declares on top)
    from repro.core import monitoring as mon

    expect(
        "builtin counter table",
        {name: idx for name, idx in fresh.counters.items()},
        {name: getattr(mon, f"C_{name}") for name, _doc in mon.BUILTIN_COUNTERS},
    )
    expect("n_counters (builtin)", fresh.n_counters, mon.N_COUNTERS)

    kind_ids = {k.name: k.id for k in components.BUILTIN.kinds}
    expect("kind ids", {k.name: k.id for k in fresh.kinds}, kind_ids)
    for name, kid in kind_ids.items():
        exported = getattr(events, f"K_{name}")
        if exported != kid:
            errors.append(f"events.K_{name} == {exported}, registry says {kid}")

    # handler coverage: every kind dispatches (raises RegistryError if not)
    try:
        fresh.make_handlers(lookahead=1)
    except Exception as e:  # noqa: BLE001
        errors.append(f"regenerated dispatch table failed: {e}")

    # the declared public surface must resolve
    missing = [n for n in public if not hasattr(core, n)]
    if missing:
        errors.append(f"repro.core.__all__ names missing attributes: {missing}")

    # checkpoint surface: the saved-leaf layout is derived from the
    # registry-generated structs, so every World/EngineState field must
    # appear under its struct-field name (the pre-PR 8 checkpointer used a
    # str(path) fallback that produced '.world'-style keys and silently
    # drifted from the PR 4 registry structs)
    import repro.checkpoint as ckpkg
    from repro.checkpoint import tree_keys
    from repro.core.engine import EngineState

    missing = [n for n in ckpkg.__all__ if not hasattr(ckpkg, n)]
    if missing:
        errors.append(f"repro.checkpoint.__all__ names missing attributes: {missing}")
    scalar_fields = (
        "counters",
        "t_now",
        "done",
        "windows",
        "trace",
        "trace_n",
        "trace_tail",
    )
    want_keys = sorted(
        [f"world/{f}" for f in fresh.world_struct()._fields]
        + [f"pool/{f}" for f in events.EventPool._fields]
        + list(scalar_fields)
    )
    template = EngineState(
        world=fresh.world_struct()(*[0] * len(fresh.world_struct()._fields)),
        pool=events.EventPool(*[0] * len(events.EventPool._fields)),
        **{f: 0 for f in scalar_fields},
    )
    expect("checkpoint leaf keys", sorted(tree_keys(template)), want_keys)

    # fleet surface: the orchestrator's public names must resolve, the fleet
    # counters must be registry-declared with the host-side-only class (an
    # in-graph "counter" class here would mean someone started bumping them
    # inside the window program, breaking resume byte-identity)
    import repro.fleet as fleet

    missing = [n for n in fleet.__all__ if not hasattr(fleet, n)]
    if missing:
        errors.append(f"repro.fleet.__all__ names missing attributes: {missing}")
    for idx in mon.FLEET_COUNTERS:
        if mon.counter_class(idx) != "fleet":
            errors.append(
                f"counter {idx} in FLEET_COUNTERS but counter_class says "
                f"{mon.counter_class(idx)!r} (must be 'fleet': booked "
                "host-side only)"
            )

    # catalog surface: every entry must build-resolve cleanly and ensemble
    # entries must declare the replicas/seed0 sizing convention
    from repro.scenarios import catalog

    if not catalog.names():
        errors.append("scenario catalog is empty")
    for name in catalog.names():
        sd = catalog.get(name)
        if not callable(sd.build):
            errors.append(f"catalog entry {name!r}: build is not callable")
        if not sd.doc:
            errors.append(f"catalog entry {name!r}: missing doc")
        if sd.driver == "ensemble" and "seed0" not in sd.defaults():
            errors.append(
                f"catalog ensemble entry {name!r}: missing 'seed0' parameter"
            )
    return errors


def main() -> int:
    errors = check()
    for e in errors:
        print(f"FAIL: {e}")
    if errors:
        print(
            f"{len(errors)} API drift error(s); regenerate exports from "
            "the registry (see docs/scenario_api.md)"
        )
        return 1
    print("OK: registry and core exports agree (no schema drift)")
    return 0


if __name__ == "__main__":
    sys.path.insert(0, "src")
    sys.exit(main())
