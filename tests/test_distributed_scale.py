"""Deterministic scale-out tests: shard_map x vmap agent packing, cross-shard
event migration, lockstep adaptive width — all via the shared subprocess
harness (4 forced host devices), no hypothesis dependency so the full
distributed surface is exercised even on minimal installs."""

import pytest

from distributed_harness import run_distributed_child


@pytest.mark.slow
def test_agent_packing_more_agents_than_devices():
    """6 agents on 4 devices (K=2, two pad rows): the packed shard_map x vmap
    driver is byte-identical to run_local in full state and to the sequential
    oracle in trace."""
    res = run_distributed_child(r"""
otrace = oracle_trace()
w, o, e, s = t0t1_build(6)
eng = Engine(w, o, e, s, trace_cap=4096)
mesh = Mesh(np.array(jax.devices()), ("agents",))
st_d = eng.run_distributed(mesh, max_windows=20000)
st_l = eng.run_local(max_windows=20000)
cnt = np.asarray(st_d.counters)
print(json.dumps({
    "full_state_equal": tree_eq(st_d, st_l),
    "trace_is_oracle": engine_trace(st_d) == otrace,
    "n": len(otrace),
    "no_drops": int(cnt[:, mon.C_DROP_POOL].sum()) == 0
                and int(cnt[:, mon.C_DROP_ROUTE].sum()) == 0,
}))
""")
    assert res["full_state_equal"] and res["trace_is_oracle"]
    assert res["no_drops"] and res["n"] > 0


@pytest.mark.slow
def test_cross_shard_migration_mid_run():
    """Mid-run placement swap between agents on different shards: the
    migrated states match across drivers, C_MIGRATE_OUT/IN balance with
    nonzero traffic, and the continued distributed run still executes the
    exact oracle trace."""
    res = run_distributed_child(r"""
otrace = oracle_trace()
n = 6
w, o, e, s = t0t1_build(n)
eng = Engine(w, o, e, s, trace_cap=4096)
mesh = Mesh(np.array(jax.devices()), ("agents",))
axes = eng._dist_axes(mesh)
stp = eng._pad_state(eng.init_state(), axes.size)
step = eng._dist_window_fn(mesh, s.exec_cap)
for _ in range(30):
    stp = step(stp)
mid = eng._slice_state(stp)
# agent 0 lives on shard 0, agent 5 on shard 2 (K=2): a true cross-shard swap
la = np.asarray(mid.world.lp_agent[0])
new_la = np.where(la == 0, 5, np.where(la == 5, 0, la)).astype(np.int32)
mig_d = eng.apply_placement_distributed(mid, new_la, mesh)
mig_l = eng.apply_placement_local(mid, new_la)
cnt = np.asarray(mig_d.counters)
out_sum = int(cnt[:, mon.C_MIGRATE_OUT].sum())
in_sum = int(cnt[:, mon.C_MIGRATE_IN].sum())
fin = eng.run_distributed(mesh, max_windows=20000, state=mig_d)
print(json.dumps({
    "migrated_states_equal": tree_eq(mig_d, mig_l),
    "balanced": out_sum == in_sum,
    "moved": out_sum,
    "continued_trace_is_oracle": engine_trace(fin) == otrace,
}))
""")
    assert res["migrated_states_equal"]
    assert res["balanced"] and res["moved"] > 0
    assert res["continued_trace_is_oracle"]


@pytest.mark.slow
def test_adaptive_per_shard_width_lockstep():
    """The distributed LISA loop engages the ladder (width 1 spills on this
    dense two-generator scenario and climbs every rung) and its max-reduced
    per-shard decisions reproduce run_adaptive's rung trajectory and full
    state byte-for-byte; the trace stays oracle-exact."""
    res = run_distributed_child(r"""
bkw = dict(interval=5, second_gen=True)
otrace = oracle_trace(**bkw)
w, o, e, s = t0t1_build(6, **bkw)
eng = Engine(w, o, e, s, trace_cap=4096)
mesh = Mesh(np.array(jax.devices()), ("agents",))
p = ExecPolicy(ladder=(1, 4, 16))
st_a = eng.run_adaptive(max_windows=20000, policy=p)
rungs_a = eng.adaptive_rungs
st_da = eng.run_distributed_adaptive(mesh, max_windows=20000, policy=p)
rungs_da = eng.adaptive_rungs
print(json.dumps({
    "rungs_lockstep": rungs_a == rungs_da,
    "rungs_used": sorted(set(rungs_a)),
    "full_state_equal": tree_eq(st_a, st_da),
    "trace_is_oracle": engine_trace(st_da) == otrace,
}))
""")
    assert res["rungs_lockstep"]
    assert len(res["rungs_used"]) > 1, res
    assert res["full_state_equal"]
    assert res["trace_is_oracle"]


@pytest.mark.slow
def test_streaming_trace_distributed_past_cap():
    """The PR 7 streaming contract on the 4-device driver: a 32-row
    device-side ring on a run whose per-agent traces overflow it completes
    with C_TRACE_DROP == 0, and the host-merged streamed trace (per-shard
    rings drained independently, global agent id = shard-major state row) is
    byte-identical to the sequential oracle AND to the big-buffer in-device
    run — on both the static and the lockstep-adaptive driver."""
    res = run_distributed_child(r"""
bkw = dict(n_flows=24, t_end=20000, exec_cap=16)
otrace = oracle_trace(**bkw)
mesh = Mesh(np.array(jax.devices()), ("agents",))
w, o, e, s = t0t1_build(6, **bkw)
ref = Engine(w, o, e, s, trace_cap=4096).run_distributed(mesh)
ref_trace = engine_trace(ref)

ts = mon.TraceStream()
ms = mon.MetricsStream(interval=32)
eng = Engine(w, o, e, s, trace_cap=32, trace_stream=ts, metrics_stream=ms,
             drain_every=8)
st = eng.run_distributed(mesh)
cnt = np.asarray(st.counters)

bkw_a = dict(n_flows=24, t_end=20000)
w2, o2, e2, s2 = t0t1_build(6, **bkw_a)
ts2 = mon.TraceStream()
eng2 = Engine(w2, o2, e2, s2, trace_cap=32, trace_stream=ts2, drain_every=8)
st2 = eng2.run_distributed_adaptive(
    mesh, policy=ExecPolicy(ladder=(4, 8, 16)))
cnt2 = np.asarray(st2.counters)
print(json.dumps({
    "past_cap": int(np.asarray(st.trace_n).max()) > 32,
    "drop": int(cnt[:, mon.C_TRACE_DROP].sum()),
    "streamed_is_oracle": ts.merged() == otrace,
    "streamed_is_buffered": ts.merged() == ref_trace,
    "n": len(otrace),
    "metrics_final": ms.latest["counters"]["EVENTS"],
    "adaptive_drop": int(cnt2[:, mon.C_TRACE_DROP].sum()),
    "adaptive_streamed_is_oracle": ts2.merged() == otrace,
}))
""")
    assert res["past_cap"], res
    assert res["drop"] == 0 and res["adaptive_drop"] == 0
    assert res["streamed_is_oracle"] and res["streamed_is_buffered"]
    assert res["adaptive_streamed_is_oracle"]
    assert res["metrics_final"] == res["n"] > 0
