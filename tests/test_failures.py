"""Failure/repair process LP (scenarios/failures.py) — the third zero-core-edit
extension, and the proof of the PR 5 registry features riding along: extension
kinds writing a *builtin* table under the delta contract, registry-declared
monitoring counters, and int32 payload dtype views. The batched engine, the
sequential engine path, and the heapq oracle must agree byte-for-byte.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core import Engine, merged_engine_trace, run_sequential
from repro.core import monitoring as mon
from repro.core.components import BUILTIN
from repro.scenarios.failures import (
    C_CPU_FAILS,
    C_CPU_REPAIRS,
    C_FAIL_BURSTS,
    FAIL_REGISTRY,
    build_failure_scenario,
)

NON_DIAG = [i for i in range(mon.N_COUNTERS) if i not in mon.BATCH_DIAG_COUNTERS]


def run_pair(built, trace_cap=4096, max_windows=20000):
    world, own, init_ev, spec = built
    eng_b = Engine(world, own, init_ev, spec, trace_cap=trace_cap)
    st_b = eng_b.run_local(max_windows=max_windows)
    spec_s = dataclasses.replace(spec, batched_dispatch=False)
    eng_s = Engine(world, own, init_ev, spec_s, trace_cap=trace_cap)
    st_s = eng_s.run_local(max_windows=max_windows)
    return st_b, st_s


def trace_of(st):
    return merged_engine_trace(np.asarray(st.trace), np.asarray(st.trace_n))


def assert_identical(st_b, st_s):
    np.testing.assert_array_equal(
        np.asarray(st_b.counters)[:, NON_DIAG],
        np.asarray(st_s.counters)[:, NON_DIAG],
    )
    # declared extension counters must agree across paths too
    np.testing.assert_array_equal(
        np.asarray(st_b.counters)[:, mon.N_COUNTERS :],
        np.asarray(st_s.counters)[:, mon.N_COUNTERS :],
    )
    assert trace_of(st_b) == trace_of(st_s)
    for name, a, b in zip(st_b.world._fields, st_b.world, st_s.world):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)


def test_registry_extends_builtin_without_touching_it():
    assert "fproc" in FAIL_REGISTRY.components
    assert "fproc" not in BUILTIN.components  # zero core edits
    assert FAIL_REGISTRY.n_kinds == BUILTIN.n_kinds + 3
    assert FAIL_REGISTRY.kind_table[: BUILTIN.n_kinds] == BUILTIN.kind_table
    # CPU_FAIL / CPU_REPAIR declare the *builtin* farm table
    farm_id = BUILTIN.components["farm"].table_id
    assert FAIL_REGISTRY.kind_table[BUILTIN.n_kinds + 1] == farm_id
    assert FAIL_REGISTRY.kind_table[BUILTIN.n_kinds + 2] == farm_id
    # declared counters extend the builtin vector
    assert C_CPU_FAILS >= mon.N_COUNTERS
    assert FAIL_REGISTRY.n_counters == mon.N_COUNTERS + 4
    assert "CPU_FAILS" not in BUILTIN.counters


def test_oversized_burst_is_rejected_or_counted():
    """fp_burst beyond the emit slots: the builder refuses, and a directly
    built oversized process counts its truncated failures."""
    from repro.core import events as ev
    from repro.scenarios.failures import (
        C_FAIL_BURST_TRUNC,
        FAIL_TICK,
        FailureScenarioBuilder,
    )

    with pytest.raises(ValueError, match="BURST_TRUNC"):
        build_failure_scenario(burst=ev.MAX_EMIT)
    b = FailureScenarioBuilder(max_cpu=8)
    farm = b.add_farm([1.0] * 8)
    proc = b.add_fproc(
        fp_target=farm,
        fp_burst=ev.MAX_EMIT + 2,
        fp_fail_mean=8,
        fp_repair_mean=4,
        fp_rng=1,
        fp_left=2,
    )
    b.add_event(time=1, kind=FAIL_TICK, src=proc, dst=proc)
    world, own, init_ev, spec = b.build(
        n_agents=1, lookahead=1, t_end=400, pool_cap=64
    )
    st = Engine(world, own, init_ev, spec).run_local()
    c = np.asarray(st.counters)[0]
    assert c[C_FAIL_BURST_TRUNC] == 2 * 3  # 3 truncated per burst, 2 bursts
    assert c[C_CPU_FAILS] == 2 * (ev.MAX_EMIT - 1)


@pytest.mark.parametrize("n_agents", [1, 2])
def test_failures_match_oracle(n_agents):
    built, _ids = build_failure_scenario(
        n_farms=4,
        n_cpu=4,
        burst=2,
        n_bursts=4,
        jobs_per_farm=3,
        n_agents=n_agents,
    )
    world, own, init_ev, spec = built
    ow, oc, otrace = run_sequential(world, own, init_ev, spec)
    st_b, st_s = run_pair(built)
    assert trace_of(st_b) == otrace
    assert_identical(st_b, st_s)
    w = jax.tree.map(lambda x: np.asarray(x[0]), st_b.world)
    np.testing.assert_array_equal(np.asarray(ow.cpu_busy), w.cpu_busy)
    np.testing.assert_array_equal(np.asarray(ow.fp_rng), w.fp_rng)
    # declared counters count the same events as the oracle's run
    c = np.asarray(st_b.counters).sum(axis=0)
    oc = np.asarray(oc)
    assert c[C_CPU_FAILS] == oc[C_CPU_FAILS] > 0
    assert c[C_CPU_REPAIRS] == oc[C_CPU_REPAIRS] > 0
    assert c[C_FAIL_BURSTS] == oc[C_FAIL_BURSTS] > 0
    # every failure eventually repairs (t_end covers the repair tail)
    assert c[C_CPU_REPAIRS] <= c[C_CPU_FAILS]


def test_burst_on_one_farm_serializes_through_fallback():
    """A burst > 1 on a single farm is a same-row collision group: the
    conflict mask must route it through the sequential fallback."""
    built, _ids = build_failure_scenario(n_farms=1, n_cpu=8, burst=3, n_bursts=3)
    world, own, init_ev, spec = built
    _ow, _oc, otrace = run_sequential(world, own, init_ev, spec)
    st_b, st_s = run_pair(built)
    c = np.asarray(st_b.counters)[0]
    assert c[mon.C_BATCH_FALLBACK] > 0
    assert trace_of(st_b) == otrace
    assert_identical(st_b, st_s)


def test_distinct_farms_batch_clean():
    """One single-failure process per farm: distinct farm rows, no fallback
    from the failure traffic itself (bursts of 1, staggered seeds)."""
    built, _ids = build_failure_scenario(
        n_farms=6, n_cpu=4, burst=1, n_bursts=2, lookahead=1
    )
    world, own, init_ev, spec = built
    _ow, _oc, otrace = run_sequential(world, own, init_ev, spec)
    st_b, _st_s = run_pair(built)
    c = np.asarray(st_b.counters)[0]
    assert c[mon.C_BATCH_EXEC] > 0
    assert trace_of(st_b) == otrace


def test_failed_cpu_queues_jobs_until_repair():
    """A job submitted while the only CPU is down must queue, then start on
    the repair's FIFO pop and complete — the failure actually bites."""
    from repro.core.components import JOB_SUBMIT
    from repro.scenarios.failures import FAIL_TICK, FailureScenarioBuilder

    b = FailureScenarioBuilder(max_cpu=1, queue_cap=4)
    farm = b.add_farm([1.0])
    proc = b.add_fproc(
        fp_target=farm,
        fp_burst=1,
        fp_fail_mean=4,
        fp_repair_mean=60,
        fp_rng=3,
        fp_left=1,
    )
    b.add_event(time=1, kind=FAIL_TICK, src=proc, dst=proc)
    # the job lands while the CPU is down (the fail fires at t=2)
    b.add_event(
        time=6,
        kind=JOB_SUBMIT,
        src=farm,
        dst=farm,
        payload=JOB_SUBMIT.pack(work=2.0, mem=1.0),
    )
    world, own, init_ev, spec = b.build(
        n_agents=1, lookahead=1, t_end=1000, pool_cap=64
    )
    st = Engine(world, own, init_ev, spec, trace_cap=512).run_local()
    c = np.asarray(st.counters)[0]
    w = jax.tree.map(lambda x: np.asarray(x[0]), st.world)
    _ow, _oc, otrace = run_sequential(world, own, init_ev, spec)
    assert trace_of(st) == otrace
    assert c[C_CPU_FAILS] == 1 and c[C_CPU_REPAIRS] == 1
    # queued during the outage, completed after the repair popped it
    assert c[mon.C_JOBS_SUBMITTED] == 1 and c[mon.C_JOBS_DONE] == 1
    assert int(w.jobq_n[0]) == 0 and int(w.cpu_busy[0, 0]) == 0


try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    fail_params = st.fixed_dictionaries(
        dict(
            n_farms=st.integers(1, 5),
            n_cpu=st.sampled_from([2, 4, 8]),
            procs_per_farm=st.integers(1, 2),
            burst=st.integers(1, 3),
            fail_mean=st.integers(4, 20),
            repair_mean=st.integers(2, 12),
            n_bursts=st.integers(1, 5),
            jobs_per_farm=st.sampled_from([0, 3]),
            seed=st.integers(0, 2**20),
            n_agents=st.sampled_from([1, 2]),
        )
    )

    @settings(max_examples=6, deadline=None)
    @given(fail_params)
    def test_failures_match_oracle_property(p):
        """Randomized failure churn: batched == sequential == oracle."""
        built, _ids = build_failure_scenario(**p)
        world, own, init_ev, spec = built
        _ow, _oc, otrace = run_sequential(world, own, init_ev, spec)
        st_b, st_s = run_pair(built)
        assert trace_of(st_b) == otrace
        assert_identical(st_b, st_s)
