"""Checkpoint/resume correctness: crash-exact by construction, proven here.

The contract (docs/architecture.md, "Checkpoint / resume"): a
:class:`SimCheckpointer` snapshot at a GVT-aligned window boundary captures
the *entire* run — event pool ring + cursors, world tables (including the
in-handler LCG fields), counters, trace ring + ``trace_tail``, the host-side
drained trace spans, and the adaptive policy rung — so a resumed run is
byte-identical to the uninterrupted one and hence to the sequential heapq
oracle, on any of the four drivers, after a real SIGKILL, and onto a
different device count. The fast tests drive the in-process drivers through
randomized checkpoint windows; the slow tests add the subprocess
kill-and-resume scaffold (``tests/distributed_harness.py``) with forced host
devices.
"""

import signal
import tempfile

import jax
import numpy as np
import pytest

from conftest import t0t1_builder
from distributed_harness import run_distributed_child, run_killed_child
from repro.checkpoint import Checkpointer, SimCheckpointer, tree_keys
from repro.core import Engine, TraceStream, merged_engine_trace, run_sequential
from repro.core import monitoring as mon
from repro.core.policy import ExecPolicy

try:
    from hypothesis import example, given, settings
    from hypothesis import strategies as st_

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - the no-hypothesis CI job
    HAVE_HYPOTHESIS = False


def build(n_agents, *, pool_cap=256, exec_cap=None, exec_policy=None):
    b, kw = t0t1_builder()
    kw["pool_cap"] = pool_cap
    if exec_cap is not None:
        kw["exec_cap"] = exec_cap
    if exec_policy is not None:
        kw["exec_policy"] = exec_policy
    return b.build(n_agents=n_agents, **kw)


def tree_eq(a, b):
    return bool(
        jax.tree.all(
            jax.tree.map(
                lambda x, y: bool((np.asarray(x) == np.asarray(y)).all()), a, b
            )
        )
    )


@pytest.fixture(scope="module")
def oracle(t0t1_oracle):
    _w, _c, trace = t0t1_oracle
    return trace


# ------------------------------------------------------------ layout + API
def test_checkpoint_keys_are_registry_struct_names():
    """Leaf keys come from the registry-generated NamedTuple fields — the
    seed's pre-PR 4 keystr fallback produced '.world'-style strings."""
    w, o, e, s = build(2)
    state = Engine(w, o, e, s).init_state()
    keys = tree_keys(state)
    for f in state.world._fields:
        assert f"world/{f}" in keys
    for f in state.pool._fields:
        assert f"pool/{f}" in keys
    for f in (
        "counters",
        "t_now",
        "done",
        "windows",
        "trace",
        "trace_n",
        "trace_tail",
    ):
        assert f in keys
    assert len(keys) == len(set(keys))
    assert not any(k.startswith(".") or "GetAttrKey" in k for k in keys)


def test_generic_checkpointer_roundtrip_engine_state(tmp_path):
    """The generic tree layer round-trips a full EngineState bit-exact and
    refuses a structure mismatch."""
    w, o, e, s = build(3, exec_cap=8)
    eng = Engine(w, o, e, s, trace_cap=512)
    st = eng.step_local(eng.init_state())
    ck = Checkpointer(str(tmp_path))
    ck.save(7, st, blocking=True)
    step, back = ck.restore(eng.init_state())
    assert step == 7 and tree_eq(back, st)
    with pytest.raises(ValueError, match="mismatch"):
        ck.restore({"not": np.zeros(3)})


def test_sim_checkpointer_validates_shapes(tmp_path):
    """Restoring into a different scenario spec is loud, not silent."""
    w, o, e, s = build(2, exec_cap=8)
    ck = SimCheckpointer(str(tmp_path), every=4)
    eng = Engine(w, o, e, s, trace_cap=512, checkpointer=ck)
    eng.run_local()
    other = Engine(*build(3, exec_cap=8), trace_cap=512)
    with pytest.raises(ValueError, match="shape"):
        ck.restore_sim(other)


def test_sim_checkpointer_gc_keeps_newest(tmp_path):
    w, o, e, s = build(2, exec_cap=8)
    ck = SimCheckpointer(str(tmp_path), every=3, keep=2)
    eng = Engine(w, o, e, s, trace_cap=512, checkpointer=ck)
    eng.run_local()
    steps = ck.all_steps()
    assert len(steps) == 2 and steps[-1] - steps[-2] == 3


# ------------------------------------------------- resume == uninterrupted
def test_resume_local_byte_identical(oracle, tmp_path):
    """Static driver: restore from every saved window into a *fresh* engine
    and finish with run_local — final state bytes == the uninterrupted
    while_loop run == the oracle trace."""
    built = build(4, exec_cap=16)
    ref = Engine(*built, trace_cap=4096).run_local()
    ref_trace = merged_engine_trace(np.asarray(ref.trace), np.asarray(ref.trace_n))
    assert ref_trace == oracle
    ck = SimCheckpointer(str(tmp_path), every=11, keep=99)
    eng = Engine(*built, trace_cap=4096, checkpointer=ck)
    full = eng.run_local()
    assert tree_eq(full, ref)  # host-stepped loop == while_loop driver
    steps = ck.all_steps()
    assert len(steps) >= 3
    for step in steps[:3]:
        eng2 = Engine(
            *built,
            trace_cap=4096,
            checkpointer=SimCheckpointer(str(tmp_path)),
        )
        rec = eng2.restore(step=step)
        assert rec.step == step and rec.rung is None
        assert tree_eq(eng2.run_local(state=rec.state), ref)


def test_resume_adaptive_rung_trajectory(oracle, tmp_path):
    """Adaptive driver: the checkpoint carries the post-choose_rung rung, so
    prefix + resumed rung trajectories concatenate to the uninterrupted
    trajectory exactly, and the state bytes match."""
    ladder = ExecPolicy(ladder=(4, 8, 32))
    built = build(4, exec_policy=ladder)
    ref_eng = Engine(*built, trace_cap=4096)
    ref = ref_eng.run_adaptive()
    ref_trace = merged_engine_trace(np.asarray(ref.trace), np.asarray(ref.trace_n))
    assert ref_trace == oracle
    ck = SimCheckpointer(str(tmp_path), every=7, keep=99)
    eng = Engine(*built, trace_cap=4096, checkpointer=ck)
    full = eng.run_adaptive()
    assert tree_eq(full, ref)
    assert eng.adaptive_rungs == ref_eng.adaptive_rungs
    step = ck.all_steps()[1]
    eng2 = Engine(
        *built,
        trace_cap=4096,
        checkpointer=SimCheckpointer(str(tmp_path)),
    )
    rec = eng2.restore(step=step)
    assert rec.rung is not None
    res = eng2.run_adaptive(state=rec.state, rung=rec.rung)
    assert tree_eq(res, ref)
    resumed_rungs = ref_eng.adaptive_rungs[:step] + eng2.adaptive_rungs
    assert resumed_rungs == ref_eng.adaptive_rungs


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(
        every=st_.integers(min_value=2, max_value=13),
        n_agents=st_.sampled_from([1, 3]),
        driver=st_.sampled_from(["local", "adaptive"]),
        streaming=st_.booleans(),
        pick=st_.integers(min_value=0, max_value=7),
    )
    @example(every=5, n_agents=3, driver="adaptive", streaming=True, pick=2)
    @example(every=2, n_agents=1, driver="local", streaming=True, pick=7)
    def test_checkpoint_resume_property(every, n_agents, driver, streaming, pick):
        """Checkpoint at a random window cadence, resume from a random saved
        step, on both in-process drivers, with and without the streaming
        trace drain: resumed final state == uninterrupted == oracle."""
        exec_policy = ExecPolicy(ladder=(4, 16)) if driver == "adaptive" else None
        built = build(
            n_agents,
            exec_policy=exec_policy,
            exec_cap=16 if exec_policy is None else None,
        )
        w, o, e, s = built
        _w, _c, otrace = run_sequential(w, o, e, s)

        def make_engine(ck):
            kw = dict(checkpointer=ck)
            if streaming:
                kw.update(trace_cap=24, drain_every=3, trace_stream=TraceStream())
            else:
                kw.update(trace_cap=4096)
            return Engine(*built, **kw)

        def run(eng, state=None, rung=None):
            if driver == "adaptive":
                return eng.run_adaptive(state=state, rung=rung)
            return eng.run_local(state=state)

        def merged(eng, st):
            if streaming:
                return eng.trace_stream.merged()
            return merged_engine_trace(np.asarray(st.trace), np.asarray(st.trace_n))

        with tempfile.TemporaryDirectory() as tmp:
            ck = SimCheckpointer(tmp, every=every, keep=99)
            eng = make_engine(ck)
            full = run(eng)
            assert merged(eng, full) == otrace
            steps = ck.all_steps()
            assert steps, "run too short for the chosen cadence"
            step = steps[pick % len(steps)]
            eng2 = make_engine(SimCheckpointer(tmp))
            rec = eng2.restore(step=step)
            res = run(eng2, state=rec.state, rung=rec.rung)
            assert tree_eq(res, full)
            assert merged(eng2, res) == otrace
            if streaming:
                drop = int(np.asarray(res.counters)[:, mon.C_TRACE_DROP].sum())
                assert drop == 0


# ------------------------------------------- subprocess kill-and-resume
_KILL_BODY = r"""
tmp = {tmp!r}
world, own, init_ev, spec = t0t1_build(5, pool_cap=128, exec_cap=8,
                                       n_flows=16, second_gen=True)
ts = mon.TraceStream()
ck = SimCheckpointer(tmp, every=6, keep=99, kill_after=18)
eng = Engine(world, own, init_ev, spec, trace_cap=32, drain_every=4,
             trace_stream=ts, checkpointer=ck)
mesh = Mesh(np.array(jax.devices()), ("agents",))
eng.run_distributed(mesh)
print(json.dumps({{"survived": True}}))
"""

_RESUME_BODY = r"""
tmp = {tmp!r}
world, own, init_ev, spec = t0t1_build(5, pool_cap=128, exec_cap=8,
                                       n_flows=16, second_gen=True)
otrace = oracle_trace(pool_cap=128, exec_cap=8, n_flows=16, second_gen=True)
ts = mon.TraceStream()
eng = Engine(world, own, init_ev, spec, trace_cap=32, drain_every=4,
             trace_stream=ts, checkpointer=SimCheckpointer(tmp))
mesh = Mesh(np.array(jax.devices()), ("agents",))  # 2 devices now
rec = eng.restore()
st = eng.run_distributed(mesh, state=rec.state)
# the reference never crashed: a from-scratch streamed run on the SAME
# 2-device mesh — full state bytes (ring content included) must match
ref_ts = mon.TraceStream()
ref_eng = Engine(world, own, init_ev, spec, trace_cap=32, drain_every=4,
                 trace_stream=ref_ts)
ref = ref_eng.run_distributed(mesh)
print(json.dumps({{
    "resumed_step": rec.step,
    "stream_eq_oracle": ts.merged() == otrace,
    "ref_eq_oracle": ref_ts.merged() == otrace,
    "state_eq_ref": tree_eq(st, ref),
    "trace_drop": int(np.asarray(st.counters)[:, mon.C_TRACE_DROP].sum()),
}}))
"""


@pytest.mark.slow
def test_sigkill_and_resume_on_fewer_devices(tmp_path):
    """The headline crash harness: a 4-device streamed+checkpointed run is
    SIGKILLed mid-run (a real, unhandled kill fired right after a committed
    checkpoint); a fresh 2-device process restores the latest checkpoint and
    finishes. The resumed streamed trace must equal the oracle, and the
    world/pool/counter bytes must equal an uninterrupted 2-device run —
    crash, resume, AND reshard, with zero divergence."""
    tmp = str(tmp_path)
    dead = run_killed_child(_KILL_BODY.format(tmp=tmp), n_devices=4)
    assert dead.returncode == -signal.SIGKILL, (dead.returncode, dead.stderr[-2000:])
    assert "survived" not in dead.stdout
    steps = SimCheckpointer(tmp).all_steps()
    assert steps and max(steps) >= 18
    res = run_distributed_child(_RESUME_BODY.format(tmp=tmp), n_devices=2)
    assert res["resumed_step"] >= 18, res
    assert res["stream_eq_oracle"] is True, res
    assert res["ref_eq_oracle"] is True, res
    assert res["state_eq_ref"] is True, res
    assert res["trace_drop"] == 0, res


_RESHARD_BODY = r"""
import tempfile
n = params["n_agents"]
pol_kw = dict(exec_policy=ExecPolicy(ladder=(4, 16))) if params["adaptive"] \
    else dict(exec_cap=8)
built = t0t1_build(n, pool_cap=128, n_flows=16, second_gen=True, **pol_kw)
world, own, init_ev, spec = built
otrace = oracle_trace(pool_cap=128, n_flows=16, second_gen=True, **pol_kw)
mesh_save = Mesh(np.array(jax.devices()[:params["d_save"]]), ("agents",))
mesh_res = Mesh(np.array(jax.devices()[:params["d_resume"]]), ("agents",))


def run(eng, mesh, state=None, rung=None):
    if params["adaptive"]:
        return eng.run_distributed_adaptive(mesh, state=state, rung=rung)
    return eng.run_distributed(mesh, state=state)


ref_eng = Engine(world, own, init_ev, spec, trace_cap=4096)
ref = run(ref_eng, mesh_res)
with tempfile.TemporaryDirectory() as tmp:
    ck = SimCheckpointer(tmp, every=params["every"], keep=99)
    eng = Engine(world, own, init_ev, spec, trace_cap=4096, checkpointer=ck)
    full = run(eng, mesh_save)
    steps = ck.all_steps()
    step = steps[len(steps) // 2]
    eng2 = Engine(world, own, init_ev, spec, trace_cap=4096,
                  checkpointer=SimCheckpointer(tmp))
    rec = eng2.restore(step=step)
    res = run(eng2, mesh_res, state=rec.state, rung=rec.rung)
print(json.dumps({
    "full_eq_ref": tree_eq(full, ref),
    "res_eq_ref": tree_eq(res, ref),
    "ref_eq_oracle": engine_trace(ref) == otrace,
    "res_eq_oracle": engine_trace(res) == otrace,
    "rungs_eq": (not params["adaptive"])
                or (ref_eng.adaptive_rungs[:step] + eng2.adaptive_rungs
                    == ref_eng.adaptive_rungs),
    "info_steps": len(steps),
}))
"""


if HAVE_HYPOTHESIS:
    @pytest.mark.slow
    @settings(max_examples=3, deadline=None)
    @given(
        n_agents=st_.sampled_from([4, 5, 6]),
        d_save=st_.sampled_from([3, 4]),
        d_resume=st_.sampled_from([1, 2, 4]),
        adaptive=st_.booleans(),
        every=st_.integers(min_value=3, max_value=9),
    )
    @example(n_agents=5, d_save=4, d_resume=2, adaptive=True, every=4)
    @example(n_agents=6, d_save=3, d_resume=4, adaptive=False, every=7)
    def test_distributed_checkpoint_reshard_property(
        n_agents, d_save, d_resume, adaptive, every
    ):
        """Distributed drivers under randomized cadence, adaptive ladders,
        non-divisible shard packings, and a device-count change between save
        and resume (both meshes live in one 4-device child): resumed ==
        uninterrupted == oracle, byte-identical."""
        params = dict(
            n_agents=n_agents,
            d_save=d_save,
            d_resume=d_resume,
            adaptive=adaptive,
            every=every,
        )
        body = f"params = {params!r}\n" + _RESHARD_BODY
        res = run_distributed_child(body, n_devices=4)
        assert res["full_eq_ref"] is True, res
        assert res["res_eq_ref"] is True, res
        assert res["ref_eq_oracle"] is True, res
        assert res["res_eq_oracle"] is True, res
        assert res["rungs_eq"] is True, res
