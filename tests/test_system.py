"""End-to-end behaviour of the distributed DES against the sequential oracle.

These are the load-bearing correctness tests for the paper's contribution: the
conservative-window engine (any number of agents) must execute the exact same
event trace as a sequential heapq DES, and replicated component state must end
identical. Covers C1 (LPs), C2 (conservative sync), C4 (replication), C5
(component models incl. the interrupt-based network), C6 (contexts) and the
§4.1 scheduler migration path.
"""
import jax
import numpy as np
import pytest

from conftest import t0t1_builder
from repro.core import (Engine, ScenarioBuilder, events as ev,
                        merged_engine_trace, run_sequential)
from repro.core import monitoring as mon


def run_engine(n_agents, trace_cap=4096, **kw_over):
    b, kw = t0t1_builder()
    kw.update(kw_over)
    world, own, init_ev, spec = b.build(n_agents=n_agents, **kw)
    eng = Engine(world, own, init_ev, spec, trace_cap=trace_cap)
    st = eng.run_local(max_windows=20000)
    return eng, st


@pytest.mark.parametrize("n_agents", [1, 2, 4])
def test_engine_matches_oracle(n_agents, t0t1_oracle):
    ow, oc, otrace = t0t1_oracle
    eng, st = run_engine(n_agents)
    trace = merged_engine_trace(np.asarray(st.trace), np.asarray(st.trace_n))
    assert trace == otrace
    w = jax.tree.map(lambda x: np.asarray(x[0]), st.world)
    np.testing.assert_allclose(np.asarray(ow.sto_used), w.sto_used, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(ow.lp_lvt), w.lp_lvt)
    np.testing.assert_array_equal(np.asarray(ow.cpu_busy), w.cpu_busy)
    np.testing.assert_allclose(np.asarray(ow.flow_rem), w.flow_rem, atol=1e-4)


@pytest.mark.parametrize("n_agents", [2, 4])
def test_no_buffer_drops(n_agents):
    _, st = run_engine(n_agents)
    drops = np.asarray(st.counters)[:, list(mon.DROP_COUNTERS)]
    assert drops.sum() == 0, drops


def test_interrupt_scheme_fires(t0t1_oracle):
    """The paper's Fig-2 mechanism: low bandwidth => overlapping flows =>
    stale (interrupted) completion events and bandwidth re-shares."""
    _, _, _ = t0t1_oracle
    _, st_hi = run_engine(1, trace_cap=1)
    b, kw = t0t1_builder(wan_bw=0.2)    # starve the WAN
    world, own, init_ev, spec = b.build(n_agents=1, **kw)
    st_lo = Engine(world, own, init_ev, spec).run_local(max_windows=20000)
    c_hi = np.asarray(st_hi.counters)[0]
    c_lo = np.asarray(st_lo.counters)[0]
    assert c_lo[mon.C_STALE] > c_hi[mon.C_STALE]
    assert c_lo[mon.C_EVENTS] > c_hi[mon.C_EVENTS]


def test_storage_migration_triggers():
    """C5: db server auto-migrates to mass storage when disk passes 90%."""
    b = ScenarioBuilder()
    sto = b.add_storage(disk_cap=100.0, tape_cap=1000.0, tape_rate=10.0)
    b.add_generator(target_lp=sto, kind=ev.K_DATA_WRITE, payload=[30.0],
                    interval=10, count=5)
    world, own, init_ev, spec = b.build(n_agents=1, lookahead=1, t_end=1000)
    eng = Engine(world, own, init_ev, spec)
    st = eng.run_local()
    c = np.asarray(st.counters)[0]
    assert c[mon.C_MIGRATIONS] >= 1
    w = jax.tree.map(lambda x: np.asarray(x[0]), st.world)
    assert w.sto_used[0, 1] > 0            # tape received data
    assert w.sto_used[0, 0] <= 100.0


def test_job_queueing_fifo():
    """Farm with 1 CPU and burst arrivals must queue and finish all jobs."""
    b = ScenarioBuilder(max_cpu=2, queue_cap=16)
    farm = b.add_farm([5.0])
    for i in range(6):
        b.add_event(time=1, kind=ev.K_JOB_SUBMIT, src=farm, dst=farm,
                    payload=[50.0, 1.0, -1, 0, 0.0])
    world, own, init_ev, spec = b.build(n_agents=1, lookahead=1, t_end=10_000)
    ow, oc, otrace = run_sequential(world, own, init_ev, spec)
    assert int(np.asarray(oc)[mon.C_JOBS_DONE]) == 6
    st = Engine(world, own, init_ev, spec).run_local()
    assert int(np.asarray(st.counters)[0, mon.C_JOBS_DONE]) == 6


def test_contexts_isolated():
    """C6: two simulation runs on the same fleet do not interact; the combined
    engine reproduces the oracle and each context's components evolve as in a
    solo build."""
    def add_run(b, ctx, bw):
        t1 = b.add_regional_center(n_cpu=2, cpu_power=8.0, disk=300.0,
                                   tape=3000.0, tape_rate=5.0, ctx=ctx)
        wan = b.add_net_region(link_bws=[bw], link_lats=[5], ctx=ctx)
        b.add_generator(target_lp=wan, kind=ev.K_FLOW_START,
                        payload=[40.0, 0, -1, -1, t1["farm"], ev.K_JOB_SUBMIT,
                                 t1["storage"], ev.K_DATA_WRITE],
                        interval=25, count=8, ctx=ctx)
        return t1

    b2 = ScenarioBuilder(max_cpu=4, max_flow=16)
    add_run(b2, 0, 2.0)
    add_run(b2, 1, 0.5)
    world, own, init_ev, spec = b2.build(n_agents=2, n_ctx=2, lookahead=2,
                                         t_end=4000, pool_cap=256,
                                         work_per_mb=2.0)
    ow, oc, otrace = run_sequential(world, own, init_ev, spec)
    eng = Engine(world, own, init_ev, spec, trace_cap=8192)
    st = eng.run_local(max_windows=20000)
    trace = merged_engine_trace(np.asarray(st.trace), np.asarray(st.trace_n))
    assert trace == otrace

    # solo build of run-0 must match run-0's component rows in the combined run
    b1 = ScenarioBuilder(max_cpu=4, max_flow=16)
    add_run(b1, 0, 2.0)
    w1, own1, ev1, spec1 = b1.build(n_agents=1, lookahead=2, t_end=4000,
                                    pool_cap=256, work_per_mb=2.0)
    ow1, _, _ = run_sequential(w1, own1, ev1, spec1)
    w = jax.tree.map(lambda x: np.asarray(x[0]), st.world)
    np.testing.assert_allclose(np.asarray(ow1.sto_used)[0], w.sto_used[0],
                               rtol=1e-6)


def test_migration_preserves_execution():
    """§4.1 dynamic re-decomposition: re-homing LPs mid-run (replicated state
    means only pending events move) must not change the simulation."""
    ow, oc, otrace = None, None, None
    b, kw = t0t1_builder()
    world, own, init_ev, spec = b.build(n_agents=1, **kw)
    ow, oc, otrace = run_sequential(world, own, init_ev, spec)

    b, kw = t0t1_builder()
    world, own, init_ev, spec = b.build(n_agents=4, **kw)
    eng = Engine(world, own, init_ev, spec, trace_cap=4096)
    st = eng.init_state()
    for _ in range(10):
        st = eng.step_local(st)
    rng = np.random.RandomState(0)
    new_placement = jax.numpy.asarray(
        rng.randint(0, 4, size=spec.n_lp), jax.numpy.int32)
    st = eng.apply_placement_local(st, new_placement)
    # run to completion
    fn = jax.jit(jax.vmap(eng._run_fn("agents", 20000), axis_name="agents"))
    st = fn(st)
    trace = merged_engine_trace(np.asarray(st.trace), np.asarray(st.trace_n))
    assert trace == otrace
    w = jax.tree.map(lambda x: np.asarray(x[0]), st.world)
    np.testing.assert_allclose(np.asarray(ow.sto_used), w.sto_used, rtol=1e-6)


def test_gvt_monotone_and_safe():
    """C2: GVT never regresses; processed events stay below the horizon."""
    from repro.core import sync
    b, kw = t0t1_builder()
    world, own, init_ev, spec = b.build(n_agents=2, **kw)
    eng = Engine(world, own, init_ev, spec, trace_cap=4096)
    st = eng.init_state()
    last_gvt = -1
    for _ in range(40):
        pool0 = jax.tree.map(lambda x: x[0], st.pool)
        lmin = int(np.asarray(sync.local_min_per_ctx(pool0, 1))[0])
        pool1 = jax.tree.map(lambda x: x[1], st.pool)
        lmin = min(lmin, int(np.asarray(sync.local_min_per_ctx(pool1, 1))[0]))
        if lmin != int(ev.T_INF):
            assert lmin >= last_gvt
            last_gvt = lmin
        st = eng.step_local(st)
    # trace timestamps per-agent must be processed in causal (time,seq) order
    tr = np.asarray(st.trace)
    tn = np.asarray(st.trace_n)
    for a in range(2):
        rows = tr[a, : tn[a]]
        keys = [(int(t), int(s)) for t, s, _, _ in rows]
        assert keys == sorted(keys)
