"""Ensemble driver tests: vmap-over-seeds replicas == individual runs.

``Engine.run_ensemble`` stacks R seeded copies of the initial state and runs
the whole-run while_loop under an outer replica vmap — one fused XLA launch.
The contract: every replica's slice of the (R, A, ...) result is
byte-identical to a ``run_local`` of the same seeded state (jax's while_loop
batching freezes finished replicas with a per-lane select), seeded replicas
are oracle-exact for their seeded world, and per-replica counter totals are
recoverable from the attached :class:`MetricsStream`.
"""

import json

import jax
import numpy as np
import pytest

from conftest import t0t1_builder
from repro.core import Engine, MetricsStream, TraceStream, merged_engine_trace
from repro.core import monitoring as mon
from repro.core import run_sequential
from repro.core.engine import seed_rng_fields
from repro.scenarios.failures import build_failure_scenario


def tree_eq(a, b):
    return bool(
        jax.tree.all(
            jax.tree.map(
                lambda x, y: bool((np.asarray(x) == np.asarray(y)).all()), a, b
            )
        )
    )


@pytest.fixture(scope="module")
def failure_built():
    built, _info = build_failure_scenario(n_farms=2, pool_cap=128)
    return built


def test_replicas_match_individual_runs_and_oracle(failure_built):
    """Each ensemble replica == run_local of the same seeded state, and ==
    the sequential oracle of the correspondingly seeded world."""
    world, own, init_ev, spec = failure_built
    eng = Engine(*failure_built, trace_cap=2048)
    seeds = np.arange(6, dtype=np.int32)
    out = eng.run_ensemble(seeds)
    assert bool(np.asarray(out.done).all())
    solo = Engine(*failure_built, trace_cap=2048)
    seed_one = jax.jit(seed_rng_fields)
    for r in [0, 3, 5]:
        replica = jax.tree.map(lambda x: x[r], out)
        one = solo.run_local(state=seed_one(solo.init_state(), np.int32(seeds[r])))
        assert tree_eq(replica, one), f"replica {r} != individual run"
        # oracle exactness: the same seed jump applied to the unstacked
        # world gives the heapq reference for this replica
        seeded_world = world._replace(
            fp_rng=world.fp_rng + np.int32(seeds[r]) * np.int32(7919)
        )
        _w, _c, otrace = run_sequential(seeded_world, own, init_ev, spec)
        rtrace = merged_engine_trace(
            np.asarray(replica.trace), np.asarray(replica.trace_n)
        )
        assert rtrace == otrace


def test_hundred_seeds_one_launch_metrics_recoverable(failure_built):
    """>= 100 replicas in one launch (the acceptance bar), with per-replica
    counter totals recoverable from the MetricsStream reduction."""
    buf = []

    class Out:
        def write(self, s):
            buf.append(s)

        def flush(self):
            pass

    ms = MetricsStream(interval=1_000_000, out=Out())
    eng = Engine(*failure_built, metrics_stream=ms)
    R = 128
    out = eng.run_ensemble(np.arange(R))
    counters = np.asarray(out.counters)
    assert counters.shape[0] == R and bool(np.asarray(out.done).all())
    assert ms.replica_counters.shape == (R, counters.shape[2])
    # per-replica books recoverable by name, and exact vs the raw result
    reg_events = [ms.replica(r)["EVENTS"] for r in range(R)]
    assert reg_events == list(counters[:, :, mon.C_EVENTS].sum(axis=1))
    # seeds decorrelate the replicas: the window counts actually vary
    windows = np.asarray(out.windows)[:, 0]
    assert len(set(int(x) for x in windows)) > 1
    # the summary JSON line is well-formed and totals the fleet
    rec = json.loads("".join(buf).strip().splitlines()[-1])
    assert rec["ensemble"] == R
    assert rec["counters"]["EVENTS"] == int(counters[:, :, mon.C_EVENTS].sum())
    assert rec["per_replica"]["WINDOWS"]["max"] == int(windows.max())


def test_deterministic_scenario_replicas_identical():
    """A model with no RNG fields yields byte-identical replicas — the
    seed_fn is exact, never a perturbation of non-RNG state."""
    b, kw = t0t1_builder()
    built = b.build(n_agents=2, **kw)
    eng = Engine(*built, trace_cap=2048)
    out = eng.run_ensemble([0, 1, 2])
    r0 = jax.tree.map(lambda x: x[0], out)
    for r in (1, 2):
        assert tree_eq(jax.tree.map(lambda x: x[r], out), r0)


def test_custom_seed_fn():
    """A user seed_fn replaces the default RNG jump."""
    built, _info = build_failure_scenario(n_farms=1, pool_cap=64)
    eng = Engine(*built)

    def sfn(state, seed):
        return state._replace(
            world=state.world._replace(fp_rng=state.world.fp_rng * 0 + seed)
        )

    out = eng.run_ensemble([11, 11, 42], seed_fn=sfn)
    c = np.asarray(out.counters)
    assert (c[0] == c[1]).all()  # same seed, same books


def test_ensemble_rejects_streaming_and_checkpointing(tmp_path):
    from repro.checkpoint import SimCheckpointer

    built, _info = build_failure_scenario(n_farms=1, pool_cap=64)
    eng = Engine(*built, trace_cap=64, trace_stream=TraceStream())
    with pytest.raises(ValueError, match="stream"):
        eng.run_ensemble([0, 1])
    eng2 = Engine(*built, checkpointer=SimCheckpointer(str(tmp_path), every=4))
    with pytest.raises(ValueError, match="checkpoint"):
        eng2.run_ensemble([0, 1])
