"""Host-streaming observability tests: trace drain + metrics snapshots.

The contract under test (docs/architecture.md, "Streaming trace"): with a
:class:`TraceStream` attached, the engine drains its device-side trace ring
to the host at window boundaries, so a run whose total trace exceeds the
in-device ring still completes with ``C_TRACE_DROP == 0`` and the streamed
trace byte-identical to the sequential heapq oracle — under any drain
cadence, ring size >= the exec width, spill pressure, and adaptive width
changes. :class:`MetricsStream` turns the same window boundary into periodic
JSON-lines fleet snapshots named by the registry counter table.
"""

import io
import json

import numpy as np
import pytest

from conftest import t0t1_builder
from repro.core import Engine, MetricsStream, TraceStream, merged_engine_trace
from repro.core import monitoring as mon
from repro.core.policy import ExecPolicy


def build(n_agents, *, pool_cap=128, exec_cap=None, exec_policy=None):
    b, kw = t0t1_builder()
    kw["pool_cap"] = pool_cap
    if exec_cap is not None:
        kw["exec_cap"] = exec_cap
    if exec_policy is not None:
        kw["exec_policy"] = exec_policy
    return b.build(n_agents=n_agents, **kw)


@pytest.fixture(scope="module")
def oracle(t0t1_oracle):
    _w, _c, trace = t0t1_oracle
    return trace


@pytest.fixture(scope="module")
def buffered_ref(oracle):
    """The in-device big-buffer run the stream must match row-for-row."""
    w, o, e, s = build(4, exec_cap=16)
    st = Engine(w, o, e, s, trace_cap=4096).run_local()
    trace = merged_engine_trace(np.asarray(st.trace), np.asarray(st.trace_n))
    assert trace == oracle  # the PR 2-6 contract this PR extends
    return trace


# --------------------------------------------------------------- streaming
def test_stream_past_cap_zero_drop(oracle, buffered_ref):
    """A 48-row ring, per-agent totals well past it: full trace streamed,
    nothing dropped, merged order == in-device == oracle."""
    ts = TraceStream()
    w, o, e, s = build(4, exec_cap=16)
    eng = Engine(w, o, e, s, trace_cap=48, trace_stream=ts, drain_every=4)
    st = eng.run_local()
    c = np.asarray(st.counters)
    assert int(c[:, mon.C_TRACE_DROP].sum()) == 0
    assert int(np.asarray(st.trace_n).sum()) == len(oracle)
    assert ts.n_streamed == len(oracle)
    assert ts.merged() == buffered_ref == oracle


def test_stream_ring_must_hold_one_window():
    """The zero-drop invariant needs ring >= exec width: the driver refuses
    a ring the drain cannot keep ahead of."""
    w, o, e, s = build(2, exec_cap=64)
    eng = Engine(w, o, e, s, trace_cap=32, trace_stream=TraceStream())
    with pytest.raises(ValueError, match="ring too small"):
        eng.run_local()


def test_stream_requires_trace_cap():
    w, o, e, s = build(2)
    with pytest.raises(ValueError, match="trace_cap"):
        Engine(w, o, e, s, trace_stream=TraceStream())
    with pytest.raises(ValueError, match="drain_every"):
        Engine(w, o, e, s, trace_cap=32, drain_every=0)


def test_stream_adaptive_width_changes(oracle):
    """The drain sizes its forced-drain test with the *current* rung width,
    so ladder moves mid-run keep the invariant."""
    ts = TraceStream()
    w, o, e, s = build(4, exec_policy=ExecPolicy(ladder=(4, 8, 16, 32)))
    eng = Engine(w, o, e, s, trace_cap=40, trace_stream=ts, drain_every=3)
    st = eng.run_adaptive()
    assert int(np.asarray(st.counters)[:, mon.C_TRACE_DROP].sum()) == 0
    assert ts.merged() == oracle


def test_stream_with_pallas_trace_rank(oracle):
    """The Pallas prefix-sum hook (kernels.ops.trace_rank) drives the ring
    append to the same bytes as the default XLA cumsum."""
    from repro.kernels import ops

    ts = TraceStream()
    w, o, e, s = build(4, exec_cap=16)
    eng = Engine(
        w,
        o,
        e,
        s,
        trace_cap=48,
        trace_stream=ts,
        drain_every=4,
        trace_fn=ops.trace_rank,
    )
    st = eng.run_local()
    assert int(np.asarray(st.counters)[:, mon.C_TRACE_DROP].sum()) == 0
    assert ts.merged() == oracle


def test_stream_gap_detection():
    """A lost span is loud: reassembly refuses non-contiguous coverage."""
    ts = TraceStream()
    ts.begin(1)
    ring = np.arange(64 * 4, dtype=np.int32).reshape(64, 4)
    ts.on_drain(0, 0, 8, ring)
    ts.on_drain(0, 16, 8, ring)  # [8, 16) never arrived
    ts.finalize(ring[None, :, :], np.array([24]), np.array([24]))
    with pytest.raises(RuntimeError, match="gap"):
        ts.agent_rows(0)


def test_stream_duplicate_spans_idempotent(oracle):
    """Unordered io_callback delivery may replay a span; keyed segments make
    that a no-op."""
    ts = TraceStream()
    w, o, e, s = build(2, exec_cap=16)
    eng = Engine(w, o, e, s, trace_cap=64, trace_stream=ts, drain_every=5)
    eng.run_local()
    segs = {a: dict(d) for a, d in ts._segments.items()}
    for a, d in segs.items():
        for start, rows in d.items():
            ts.on_drain(a, start, rows.shape[0], _ring_of(rows, start))
    assert ts.merged() == oracle


def _ring_of(rows, start, cap=64):
    """A cap-row ring holding ``rows`` at positions (start + i) % cap."""
    ring = np.zeros((cap, 4), np.int32)
    idx = (start + np.arange(rows.shape[0])) % cap
    ring[idx] = rows
    return ring


def test_stream_checkpoint_resume_past_cap_zero_drop(oracle, buffered_ref, tmp_path):
    """Checkpoint-PR satellite: a streamed run checkpointed PAST trace_cap
    and resumed yields ``merged()`` byte-identical to the big-buffer
    reference with zero ``C_TRACE_DROP`` — the checkpoint carries both the
    device ring (+ ``trace_tail`` cursor) and the host-side drained spans,
    which the resume must reassemble because the pre-checkpoint rows no
    longer exist on the device."""
    from repro.checkpoint import SimCheckpointer

    def make(every=0):
        ts = TraceStream()
        w, o, e, s = build(2, exec_cap=16)
        ck = SimCheckpointer(str(tmp_path), every=every, keep=99)
        eng = Engine(
            w, o, e, s, trace_cap=32, trace_stream=ts, drain_every=4, checkpointer=ck
        )
        return ts, eng

    ts, eng = make(every=6)
    st = eng.run_local()
    assert ts.merged() == buffered_ref == oracle
    # find a saved window whose cumulative trace already exceeded the ring
    chosen = None
    for cand in eng.checkpointer.all_steps():
        ts2, eng2 = make()
        rec = eng2.restore(step=cand)
        if int(np.asarray(rec.state.trace_n).max()) > 32:
            chosen = cand
            break
    assert chosen is not None, "no checkpoint past trace_cap — scenario too small"
    st2 = eng2.run_local(state=rec.state)
    assert int(np.asarray(st2.counters)[:, mon.C_TRACE_DROP].sum()) == 0
    assert ts2.merged() == buffered_ref == oracle


def test_observability_concatenates_across_resume(tmp_path):
    """Fleet satellite: MetricsStream interval records AND TraceStream
    segments concatenate *exactly* across a checkpoint/resume boundary —
    the checkpoint carries the host-side emitted records/drained spans
    (``metrics/`` + ``trace_seg/`` leaves), restore stages them, and the
    resumed run emits only the post-checkpoint intervals, so the two runs'
    observability is indistinguishable record-for-record."""
    from repro.checkpoint import SimCheckpointer

    def make(every=0):
        ts, ms = TraceStream(), MetricsStream(interval=4)
        w, o, e, s = build(3, exec_cap=16)
        ck = SimCheckpointer(str(tmp_path), every=every, keep=99)
        eng = Engine(
            w, o, e, s, trace_cap=32, trace_stream=ts, metrics_stream=ms,
            drain_every=4, checkpointer=ck,
        )
        return ts, ms, eng

    ts, ms, eng = make(every=6)
    eng.run_local()
    ref_lines, ref_trace = list(ms.lines), ts.merged()
    steps = eng.checkpointer.all_steps()
    step = steps[len(steps) // 2]
    # non-vacuous: the chosen boundary splits the interval records
    wins = [r["window"] for r in ref_lines if not r.get("final")]
    assert any(w <= step for w in wins) and any(w > step for w in wins)

    ts2, ms2, eng2 = make()
    rec = eng2.restore(step=step)
    eng2.run_local(state=rec.state)
    assert ms2.lines == ref_lines
    assert ts2.merged() == ref_trace


def test_metrics_resume_does_not_rewrite_out(tmp_path):
    """Restored records seed ``lines`` for exact concatenation but are NOT
    re-written to ``out`` — a resumed process's stdout carries only what it
    emitted itself (the pre-crash lines already left the dead process)."""
    from repro.checkpoint import SimCheckpointer

    w, o, e, s = build(2, exec_cap=16)
    ck = SimCheckpointer(str(tmp_path), every=6, keep=99)
    ms = MetricsStream(interval=4, out=io.StringIO())
    Engine(w, o, e, s, metrics_stream=ms, checkpointer=ck).run_local()
    step = ck.all_steps()[0]
    out2 = io.StringIO()
    ms2 = MetricsStream(interval=4, out=out2)
    eng2 = Engine(
        w, o, e, s, metrics_stream=ms2,
        checkpointer=SimCheckpointer(str(tmp_path)),
    )
    rec = eng2.restore(step=step)
    eng2.run_local(state=rec.state)
    emitted = [json.loads(x) for x in out2.getvalue().strip().splitlines()]
    assert emitted == [r for r in ms2.lines if r["window"] > step]


# ----------------------------------------------------------------- metrics
def test_metrics_stream_json_lines(oracle):
    out = io.StringIO()
    ms = MetricsStream(interval=8, out=out)
    w, o, e, s = build(4, exec_cap=16)
    eng = Engine(w, o, e, s, metrics_stream=ms)
    st = eng.run_local()
    lines = [json.loads(x) for x in out.getvalue().strip().splitlines()]
    assert lines and lines == ms.lines
    names = set(eng.registry.counters)
    for rec in lines:
        assert rec["agents"] == 4
        assert set(rec["counters"]) == names
        if not rec.get("final"):
            assert rec["window"] % 8 == 0
    final = lines[-1]
    assert final["final"] is True
    assert final["counters"]["EVENTS"] == len(oracle)
    assert final["gvt"] == int(np.asarray(st.t_now).max())
    assert ms.latest == final
    # monotone within the run
    gvts = [r["gvt"] for r in lines]
    assert gvts == sorted(gvts)


def test_metrics_stream_validation():
    with pytest.raises(ValueError, match="interval"):
        MetricsStream(interval=0)


def test_snapshot_names_and_totals():
    w, o, e, s = build(2, exec_cap=16)
    eng = Engine(w, o, e, s, trace_cap=256)
    st = eng.run_local()
    snap = mon.snapshot(np.asarray(st.counters), eng.registry)
    assert set(snap) == set(eng.registry.counters)
    assert snap["EVENTS"] == int(np.asarray(st.counters)[:, mon.C_EVENTS].sum())
    # registry-free fallback covers exactly the builtins
    assert set(mon.snapshot(np.asarray(st.counters))) == {
        name for name, _ in mon.BUILTIN_COUNTERS
    }


def test_counter_class():
    assert mon.counter_class(mon.C_POOL_OCC) == "gauge"
    assert mon.counter_class(mon.C_DROP_POOL) == "drop"
    assert mon.counter_class(mon.C_RING_WRAP) == "pool-diag"
    assert mon.counter_class(mon.C_BATCH_ROWS) == "batch-diag"
    assert mon.counter_class(mon.C_EVENTS) == "counter"
    assert mon.counter_class(mon.N_COUNTERS + 3) == "counter"
    for idx in mon.FLEET_COUNTERS:
        assert mon.counter_class(idx) == "fleet"
    assert mon.FLEET_COUNTERS == (
        mon.C_PREEMPT,
        mon.C_RESUME,
        mon.C_RESHARD,
    )


def test_metrics_stream_book_overlay():
    """Fleet counters are booked host-side (``MetricsStream.book``) and
    merged into every emitted record — the in-graph vector never carries
    them, so a resumed EngineState stays byte-identical."""
    ms = MetricsStream(interval=8)
    ms.book("PREEMPT")
    ms.book("RESUME", 2)
    w, o, e, s = build(2, exec_cap=16)
    st = Engine(w, o, e, s, metrics_stream=ms).run_local()
    for rec in ms.lines:
        assert rec["counters"]["PREEMPT"] == 1
        assert rec["counters"]["RESUME"] == 2
        assert rec["counters"]["RESHARD"] == 0
    c = np.asarray(st.counters)
    assert int(c[:, list(mon.FLEET_COUNTERS)].sum()) == 0


def test_counter_docs_follow_registry():
    from repro.core.components import BUILTIN

    reg = BUILTIN.extend()
    idx = reg.counter("MY_METRIC", "something the extension counts")
    assert reg.counters["MY_METRIC"] == idx
    assert reg.counter_docs["MY_METRIC"] == "something the extension counts"
    assert reg.counter_docs["EVENTS"] == dict(mon.BUILTIN_COUNTERS)["EVENTS"]
    # the builtin registry is untouched
    assert "MY_METRIC" not in BUILTIN.counters


def test_gen_counter_docs_up_to_date():
    """The committed docs table matches the declarations (the CI drift gate,
    runnable locally)."""
    import pathlib
    import subprocess
    import sys

    root = pathlib.Path(__file__).resolve().parent.parent
    out = subprocess.run(
        [sys.executable, str(root / "tools" / "gen_counter_docs.py"), "--check"],
        capture_output=True,
        text=True,
    )
    assert out.returncode == 0, out.stdout + out.stderr


# ------------------------------------------------------ hypothesis property
try:
    from hypothesis import given, settings
    from hypothesis import strategies as hst

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised by the no-hypothesis job
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    stream_params = hst.fixed_dictionaries(
        dict(
            drain_every=hst.integers(1, 24),
            trace_cap=hst.sampled_from([40, 48, 64, 96]),
            width=hst.sampled_from([8, 16, 32]),
            adaptive=hst.booleans(),
            metrics_interval=hst.integers(1, 40),
        )
    )

    @settings(max_examples=8, deadline=None)
    @given(stream_params)
    def test_streamed_equals_buffered_equals_oracle(p, oracle, buffered_ref):
        """The tentpole property: for any drain cadence, ring size >= width,
        static or adaptive width, the streamed trace is byte-identical to
        the in-device big-buffer trace and to the sequential oracle, with
        C_TRACE_DROP == 0 — spill and ring wrap included (width 8 spills
        heavily; cap 40 vs per-agent totals forces many wraps)."""
        ts = TraceStream()
        ms = MetricsStream(interval=p["metrics_interval"])
        if p["adaptive"]:
            ladder = tuple(sorted({4, p["width"]}))
            w, o, e, s = build(4, exec_policy=ExecPolicy(ladder=ladder))
        else:
            w, o, e, s = build(4, exec_cap=p["width"])
        eng = Engine(
            w,
            o,
            e,
            s,
            trace_cap=p["trace_cap"],
            trace_stream=ts,
            metrics_stream=ms,
            drain_every=p["drain_every"],
        )
        st = eng.run_adaptive() if p["adaptive"] else eng.run_local()
        c = np.asarray(st.counters)
        assert int(c[:, mon.C_TRACE_DROP].sum()) == 0
        assert ts.merged() == buffered_ref == oracle
        assert ms.latest["counters"]["EVENTS"] == len(oracle)
