"""Serving engine: batched prefill/decode over the request queue."""
import dataclasses

import jax
import numpy as np

from repro.configs.registry import smoke_config
from repro.models.model import build_model
from repro.serve.engine import Request, ServeEngine


def test_serve_batched_requests():
    cfg = dataclasses.replace(smoke_config("deepseek-7b"), dtype="float32")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_slots=4, prompt_len=16)
    reqs = [Request(rid=i, tokens=list(range(1, 8 + i)), max_new=6)
            for i in range(4)]
    eng.run(reqs, max_ticks=16)
    for r in reqs:
        assert r.done and len(r.out) == 6
        assert all(0 <= t < cfg.vocab for t in r.out)


def test_serve_greedy_matches_manual_decode():
    """Engine decode path == manual prefill+decode loop (same model calls)."""
    cfg = dataclasses.replace(smoke_config("smollm-135m"), dtype="float32")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(1))
    prompt = list(range(2, 12))
    pad = 16

    eng = ServeEngine(model, params, batch_slots=1, prompt_len=pad)
    req = Request(rid=0, tokens=prompt, max_new=5)
    eng.run([req], max_ticks=8)

    import jax.numpy as jnp
    toks = np.zeros((1, pad), np.int32)
    toks[0, pad - len(prompt):] = prompt
    logits, state = jax.jit(model.prefill_fn)(params, {"tokens":
                                                       jnp.asarray(toks)})
    out = [int(np.argmax(np.asarray(logits)[0]))]
    length = pad
    for _ in range(4):
        logits, state = jax.jit(model.decode_fn)(
            params, state, jnp.asarray([[out[-1]]], jnp.int32),
            jnp.int32(length))
        length += 1
        out.append(int(np.argmax(np.asarray(logits)[0])))
    assert req.out == out
