"""Workload bridge (DESIGN.md §2) + compressed collective tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.workload import CellModel, simulate_training
from repro.train.compression import compressed_psum


def test_workload_sim_tracks_analytic():
    """DES-predicted step time within 25% of the analytic roofline sum
    (queueing/latency overheads are real and positive)."""
    cell = CellModel(n_pods=2, t_compute_s=0.05, dcn_bytes_per_pod=2e9,
                     n_steps=6)
    out = simulate_training(cell)
    assert out["steps_done"] >= cell.n_steps - 1
    ratio = out["simulated_step_s"] / out["analytic_step_s"]
    assert 0.75 < ratio < 1.25, out


def test_workload_sim_sees_stragglers():
    base = simulate_training(CellModel(n_pods=2, t_compute_s=0.05,
                                       dcn_bytes_per_pod=2e9, n_steps=6))
    slow = simulate_training(CellModel(n_pods=2, t_compute_s=0.05,
                                       dcn_bytes_per_pod=2e9, n_steps=6,
                                       slow_pod_factor=1.5))
    assert slow["simulated_step_s"] > base["simulated_step_s"] * 1.05


def test_compressed_psum_matches_psum():
    """int8 collective ~= float psum (within quantization error bound)."""
    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(rng, (8, 64)) * 3.0

    def f(xi):
        return compressed_psum(xi, "i")

    got = jax.vmap(f, axis_name="i")(x)
    want = jnp.broadcast_to(jnp.sum(x, axis=0), x.shape)
    amax = float(jnp.max(jnp.abs(x)))
    bound = 8 * (amax / 127.0) * 0.5 + 1e-6     # n_shards * scale/2
    assert float(jnp.max(jnp.abs(got - want))) <= bound
    # all shards agree exactly (it is a collective)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(got[1]))
