"""Compacted windowed execution (engine step 4).

The engine gathers only the exec_cap earliest safe slots per conservative
window; safe events beyond exec_cap spill to later windows. These tests pin the
two correctness claims: the executed trace stays byte-identical to the
sequential oracle on both the spill and no-spill paths, and processed-event /
final-world accounting is invariant to exec_cap. Overflow counters
(C_DROP_POOL, C_DROP_ROUTE, C_EXEC_SPILL) are exercised under forced overflow.
"""
import jax
import numpy as np
import pytest

from conftest import t0t1_builder
from repro.core import (Engine, ScenarioBuilder, events as ev,
                        merged_engine_trace, run_sequential)
from repro.core import monitoring as mon


def run_t0t1(n_agents, exec_cap, **kw_over):
    b, kw = t0t1_builder()
    kw.update(kw_over)
    world, own, init_ev, spec = b.build(n_agents=n_agents, exec_cap=exec_cap,
                                        **kw)
    eng = Engine(world, own, init_ev, spec, trace_cap=4096)
    return eng, eng.run_local(max_windows=20000)


def assert_world_equal(wa, wb):
    for name, a, b in zip(wa._fields, wa, wb):
        a, b = np.asarray(a), np.asarray(b)
        if np.issubdtype(a.dtype, np.floating):
            np.testing.assert_allclose(a, b, atol=1e-6, err_msg=name)
        else:
            np.testing.assert_array_equal(a, b, err_msg=name)


@pytest.mark.parametrize("n_agents", [1, 2])
def test_spill_path_matches_oracle(n_agents, t0t1_oracle):
    """exec_cap < per-window safe count: spilled events execute in later
    windows, yet the merged trace and final world are oracle-identical."""
    ow, oc, otrace = t0t1_oracle
    _, st = run_t0t1(n_agents, exec_cap=1)
    c = np.asarray(st.counters).sum(axis=0)
    assert c[mon.C_EXEC_SPILL] > 0          # the spill path actually ran
    trace = merged_engine_trace(np.asarray(st.trace), np.asarray(st.trace_n))
    assert trace == otrace
    w = jax.tree.map(lambda x: np.asarray(x[0]), st.world)
    np.testing.assert_allclose(np.asarray(ow.sto_used), w.sto_used, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(ow.lp_lvt), w.lp_lvt)


@pytest.mark.parametrize("n_agents", [1, 2])
def test_no_spill_path_matches_oracle(n_agents, t0t1_oracle):
    """exec_cap >= pool_cap: compaction is the identity prefix (seed behavior)."""
    ow, oc, otrace = t0t1_oracle
    _, st = run_t0t1(n_agents, exec_cap=256)   # == pool_cap in this scenario
    c = np.asarray(st.counters).sum(axis=0)
    assert c[mon.C_EXEC_SPILL] == 0
    trace = merged_engine_trace(np.asarray(st.trace), np.asarray(st.trace_n))
    assert trace == otrace
    w = jax.tree.map(lambda x: np.asarray(x[0]), st.world)
    np.testing.assert_allclose(np.asarray(ow.sto_used), w.sto_used, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(ow.lp_lvt), w.lp_lvt)


@pytest.fixture(scope="module")
def full_cap_state():
    """exec_cap == pool_cap reference run, shared across invariance cases."""
    _, st = run_t0t1(2, exec_cap=256)
    return st


@pytest.mark.parametrize("exec_cap", [1, 2, 7, 64])
def test_exec_cap_invariance(exec_cap, full_cap_state):
    """Total processed events and the final world state do not depend on
    exec_cap — only window count (and spill accounting) may differ."""
    ref_st = full_cap_state
    ref_c = np.asarray(ref_st.counters).sum(axis=0)
    _, st = run_t0t1(2, exec_cap=exec_cap)
    c = np.asarray(st.counters).sum(axis=0)
    assert c[mon.C_EVENTS] == ref_c[mon.C_EVENTS]
    assert not np.asarray(st.pool.valid).any()      # both drained the pool
    assert_world_equal(jax.tree.map(lambda x: x[0], ref_st.world),
                       jax.tree.map(lambda x: x[0], st.world))


def test_exec_spill_counter_under_forced_overflow():
    """6 same-tick events with exec_cap=1 drain one per window: spill sums
    5+4+3+2+1 and every event still executes."""
    b = ScenarioBuilder(max_cpu=2)
    farm = b.add_farm([5.0])
    for i in range(6):
        b.add_event(time=1, kind=ev.K_NOOP, src=farm, dst=farm)
    world, own, init_ev, spec = b.build(n_agents=1, lookahead=1, t_end=10,
                                        pool_cap=32, exec_cap=1)
    st = Engine(world, own, init_ev, spec).run_local(max_windows=100)
    c = np.asarray(st.counters)[0]
    assert c[mon.C_EVENTS] == 6
    assert c[mon.C_EXEC_SPILL] == 15


def test_drop_pool_counter_under_tiny_emit_cap():
    """emit_cap=1 cannot hold a generator's (target, next-tick) pair: the
    overflowing emit is counted in C_DROP_POOL, never silently lost."""
    b = ScenarioBuilder(max_cpu=2)
    farm = b.add_farm([5.0])
    b.add_generator(target_lp=farm, kind=ev.K_NOOP, payload=[], interval=5,
                    count=4)
    world, own, init_ev, spec = b.build(n_agents=1, lookahead=2, t_end=100,
                                        pool_cap=32, emit_cap=1)
    st = Engine(world, own, init_ev, spec).run_local(max_windows=200)
    c = np.asarray(st.counters)[0]
    assert c[mon.C_DROP_POOL] > 0


def test_drop_route_counter_under_tiny_route_cap():
    """Three generators on agent 0 all emitting to agent 1 in the same window
    overflow a route_cap=1 bucket; the drops are counted in C_DROP_ROUTE."""
    b = ScenarioBuilder(max_cpu=2)
    farm = b.add_farm([5.0])
    for _ in range(3):
        b.add_generator(target_lp=farm, kind=ev.K_NOOP, payload=[], interval=5,
                        count=4)
    world, own, init_ev, spec = b.build(
        n_agents=2, lookahead=2, t_end=100, pool_cap=32, route_cap=1,
        placement=[1, 0, 0, 0])    # farm on agent 1, generators on agent 0
    st = Engine(world, own, init_ev, spec).run_local(max_windows=200)
    c = np.asarray(st.counters).sum(axis=0)
    assert c[mon.C_DROP_ROUTE] > 0
