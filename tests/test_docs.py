"""Docs stay honest: links resolve and the documented interfaces exist.

The CI `docs` job runs the same checker as a standalone script and executes
examples/quickstart.py; this tier-1 mirror catches rot locally without
needing the workflow.
"""
import importlib.util
import pathlib

REPO = pathlib.Path(__file__).resolve().parents[1]


def _load_checker():
    spec = importlib.util.spec_from_file_location(
        "check_docs", REPO / "tools" / "check_docs.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_doc_links_resolve():
    checker = _load_checker()
    files = list(checker.iter_doc_files(REPO))
    # README plus the architecture + benchmarks books, at minimum
    names = {f.name for f in files}
    assert {"README.md", "architecture.md", "benchmarks.md"} <= names
    errors = [e for f in files for e in checker.check_file(f)]
    assert not errors, "\n".join(errors)


def test_architecture_counter_table_is_complete():
    """docs/architecture.md documents every monitoring counter by name."""
    from repro.core import monitoring as mon
    text = (REPO / "docs" / "architecture.md").read_text()
    counters = [name for name in dir(mon) if name.startswith("C_")]
    assert len(counters) == mon.N_COUNTERS
    missing = [c for c in counters if f"`{c}`" not in text]
    assert not missing, f"undocumented counters: {missing}"


def test_architecture_documents_delta_schema_fields():
    """The delta-schema table stays in sync with handlers.DELTA_SCHEMA."""
    from repro.core import handlers as hd
    text = (REPO / "docs" / "architecture.md").read_text()
    combined = "`flow_active/rem/rate/tlast`" in text

    def documented(f: str) -> bool:
        if f"`{f}`" in text:
            return True
        return combined and f in ("flow_active", "flow_rem", "flow_rate", "flow_tlast")

    missing = [f for f in (*hd.DELTA_SCHEMA, *hd.ROW_FIELDS) if not documented(f)]
    assert not missing, f"undocumented delta fields: {missing}"
