"""Per-row segment-scatter handler contract (the PR 3 delta rewrite).

Handlers return typed ``WorldDelta``s — one declared component row per table
plus new row values — and the batched dispatcher merges them with per-field
row scatters (``spec.merge_mode="delta"``) instead of the PR 2 whole-table
element-wise merge (kept as ``merge_mode="dense"``). These tests pin:

* the delta primitives (``empty_delta`` identity, ``apply_delta`` row scope),
* delta == dense == sequential on fixed and hypothesis-random scenarios,
* the rows-keyed conflict mask batching strictly more slots than the PR 2
  conservative duplicate-dst mask while staying oracle-exact,
* the C_BATCH_ROWS scatter-volume counter (the adaptive-exec_cap signal).
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import t0t1_builder
from repro.core import Engine, ScenarioBuilder, events as ev, run_sequential
from repro.core import handlers as hd
from repro.core import monitoring as mon
from test_batched_dispatch import assert_states_identical, engine_trace, run_pair


def run_mode(world, own, init_ev, spec, merge_mode, max_windows=20000):
    spec_m = dataclasses.replace(spec, merge_mode=merge_mode)
    eng = Engine(world, own, init_ev, spec_m, trace_cap=4096)
    return eng.run_local(max_windows=max_windows)


# --------------------------------------------------------------- primitives
def small_world():
    b = ScenarioBuilder(max_cpu=3, queue_cap=4, max_link=2, max_flow=4)
    b.add_farm([2.0, 3.0])
    b.add_farm([4.0])
    b.add_storage(100.0, 1000.0, 5.0)
    world, _own, _init, _spec = b.build(n_agents=1, lookahead=1, t_end=10)
    return world


def test_empty_delta_is_identity():
    world = small_world()
    out = hd.apply_delta(world, hd.empty_delta(world))
    for name, a, b in zip(world._fields, world, out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)


def test_apply_delta_writes_only_the_declared_row():
    world = small_world()
    delta = hd.empty_delta(world)._replace(
        farm_row=jnp.int32(1),
        cpu_busy=jnp.ones_like(world.cpu_busy[1]),
        cpu_mem=world.cpu_mem[1] + 2.5,
        jobq=world.jobq[1],
        jobq_n=jnp.int32(3),
    )
    out = hd.apply_delta(world, delta)
    np.testing.assert_array_equal(
        np.asarray(out.cpu_busy[0]), np.asarray(world.cpu_busy[0])
    )
    np.testing.assert_array_equal(np.asarray(out.cpu_busy[1]), 1)
    assert int(out.jobq_n[1]) == 3
    assert int(out.jobq_n[0]) == 0
    # undeclared tables are untouched
    np.testing.assert_array_equal(np.asarray(out.sto_used), np.asarray(world.sto_used))


def test_delta_schema_covers_every_replicated_mutable_field():
    """The typed schema must stay in sync with the owner-wins sync list:
    every field a handler may write is either in DELTA_SCHEMA or one of the
    engine-owned per-LP columns."""
    engine_owned = {"lp_state", "lp_lvt"}
    immutable = {
        "lp_kind",
        "lp_agent",
        "lp_res",
        "lp_ctx",
        "cpu_power",
        "link_bw",
        "link_lat",
        "sto_cap",
        "sto_rate",
        "gen_interval",
        "gen_target",
        "gen_kind",
        "gen_payload",
    }
    from repro.core.components import World
    assert set(World._fields) == set(hd.DELTA_SCHEMA) | engine_owned | immutable
    assert set(hd.DELTA_SCHEMA.values()) == set(hd.ROW_FIELDS)


# ------------------------------------------------- merge-mode equivalence
@pytest.mark.parametrize("merge_mode", ["delta", "dense"])
def test_merge_modes_match_oracle_and_sequential(merge_mode, t0t1_oracle):
    """Both batched merges are byte-identical to the sequential fold and the
    heapq oracle on the mixed-kind T0/T1 study."""
    _ow, _oc, otrace = t0t1_oracle
    b, kw = t0t1_builder()
    world, own, init_ev, spec = b.build(n_agents=1, **kw)
    st_m = run_mode(world, own, init_ev, spec, merge_mode)
    spec_s = dataclasses.replace(spec, batched_dispatch=False)
    st_s = run_mode(world, own, init_ev, spec_s, "delta")
    assert engine_trace(st_m) == otrace
    assert_states_identical(st_m, st_s)


def check_delta_equals_dense(p):
    """Property body: per-row scatter results == whole-table merge results."""
    b = ScenarioBuilder(max_cpu=4, queue_cap=8, max_link=4, max_flow=16)
    t1 = b.add_regional_center(
        n_cpu=2, cpu_power=p["p1"], disk=250.0, tape=2500.0, tape_rate=5.0
    )
    wan = b.add_net_region(link_bws=[p["bw0"], p["bw1"]], link_lats=[5, 5])
    payload = [
        p["size"],
        0,
        -1,
        -1,
        t1["farm"],
        ev.K_JOB_SUBMIT,
        t1["storage"],
        ev.K_DATA_WRITE,
    ]
    b.add_generator(
        target_lp=wan,
        kind=ev.K_FLOW_START,
        payload=payload,
        interval=p["interval"],
        count=p["count"],
    )
    world, own, init_ev, spec = b.build(
        n_agents=2,
        lookahead=p["lookahead"],
        t_end=3000,
        pool_cap=256,
        exec_cap=p["exec_cap"],
        work_per_mb=2.0,
    )
    st_delta = run_mode(world, own, init_ev, spec, "delta")
    st_dense = run_mode(world, own, init_ev, spec, "dense")
    assert_states_identical(st_delta, st_dense)
    cd = np.asarray(st_delta.counters).sum(axis=0)
    cx = np.asarray(st_dense.counters).sum(axis=0)
    # even the batch diagnostics agree between the two batched merges (only
    # the sequential path is allowed to differ on those)
    np.testing.assert_array_equal(cd, cx)


def test_delta_equals_dense_fixed_examples():
    """Seeded spot-checks of the property (runs without hypothesis)."""
    rng = np.random.RandomState(1)
    for _ in range(2):
        p = dict(
            p1=float(rng.uniform(1.0, 20.0)),
            bw0=float(rng.uniform(0.1, 8.0)),
            bw1=float(rng.uniform(0.1, 8.0)),
            size=float(rng.uniform(5.0, 120.0)),
            interval=int(rng.randint(5, 60)),
            count=int(rng.randint(2, 10)),
            lookahead=int(rng.randint(1, 4)),
            exec_cap=int(rng.choice([1, 3, 17, 256])),
        )
        check_delta_equals_dense(p)


# --------------------------------------------------- conflict-mask tightening
def _pr2_conservative_mask(safe: np.ndarray, dst: np.ndarray) -> np.ndarray:
    """The retired PR 2 duplicate-dst component of the conflict mask."""
    out = np.zeros_like(safe)
    for i, (s, d) in enumerate(zip(safe, dst)):
        if s and np.sum(safe & (dst == d)) > 1:
            out[i] = True
    return out


def test_rows_keyed_mask_batches_strictly_more_than_dup_dst():
    """Duplicate-dst NOOPs share no component row, so the rows-keyed mask runs
    the whole window batched where the PR 2 mask serialized most of it — and
    the result stays byte-identical to the oracle."""
    b = ScenarioBuilder(max_cpu=2)
    farm0 = b.add_farm([5.0])
    farm1 = b.add_farm([5.0])
    sinks = [b.add_idle_lp() for _ in range(3)]
    for _ in range(6):
        b.add_event(time=1, kind=ev.K_NOOP, src=farm0, dst=farm0)
        b.add_event(time=1, kind=ev.K_NOOP, src=farm1, dst=farm1)
    for lp in sinks:
        b.add_event(time=1, kind=ev.K_NOOP, src=lp, dst=lp)
    world, own, init_ev, spec = b.build(
        n_agents=1, lookahead=1, t_end=10, pool_cap=64, exec_cap=32
    )
    _ow, _oc, otrace = run_sequential(world, own, init_ev, spec)
    st_b, st_s = run_pair(world, own, init_ev, spec)
    c = np.asarray(st_b.counters)[0]
    # new mask: the whole window executes in the one vmapped call
    assert c[mon.C_BATCH_FALLBACK] == 0
    assert c[mon.C_BATCH_EXEC] == c[mon.C_EVENTS] == 15
    # the PR 2 mask would have serialized the 12 duplicate-dst slots
    safe = np.asarray(init_ev.valid)
    dst = np.asarray(init_ev.dst)
    old_batched = int(np.sum(safe & ~_pr2_conservative_mask(safe, dst)))
    assert old_batched == 3
    assert int(c[mon.C_BATCH_EXEC]) > old_batched  # strictly more slots batched
    # ... and exactness is untouched
    assert engine_trace(st_b) == otrace
    assert_states_identical(st_b, st_s)


# --------------------------------------------------------- C_BATCH_ROWS
def test_batch_rows_counts_scattered_component_rows():
    """One window: 2 DATA_WRITEs declare 2 storage rows; 3 NOOPs declare none."""
    b = ScenarioBuilder(max_cpu=2)
    sto0 = b.add_storage(500.0, 5000.0, 5.0)
    sto1 = b.add_storage(400.0, 4000.0, 5.0)
    sinks = [b.add_idle_lp() for _ in range(3)]
    b.add_event(time=1, kind=ev.K_DATA_WRITE, src=sto0, dst=sto0, payload=[1.0])
    b.add_event(time=1, kind=ev.K_DATA_WRITE, src=sto1, dst=sto1, payload=[2.0])
    for lp in sinks:
        b.add_event(time=1, kind=ev.K_NOOP, src=lp, dst=lp)
    world, own, init_ev, spec = b.build(n_agents=1, lookahead=1, t_end=10, pool_cap=64)
    st = Engine(world, own, init_ev, spec).run_local()
    c = np.asarray(st.counters)[0]
    assert c[mon.C_BATCH_EXEC] == 5
    assert c[mon.C_BATCH_ROWS] == 2


def test_batch_rows_bounded_by_batched_events(t0t1_oracle):
    """Across a mixed-kind run: every batched event scatters at most one row,
    and the sequential path never bumps the counter."""
    b, kw = t0t1_builder()
    world, own, init_ev, spec = b.build(n_agents=1, **kw)
    st_b, st_s = run_pair(world, own, init_ev, spec)
    cb = np.asarray(st_b.counters).sum(axis=0)
    assert 0 < cb[mon.C_BATCH_ROWS] <= cb[mon.C_BATCH_EXEC]
    cs = np.asarray(st_s.counters).sum(axis=0)
    assert cs[mon.C_BATCH_ROWS] == 0


# ------------------------------------------------------ hypothesis property
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    scenario_params = st.fixed_dictionaries(
        dict(
            p1=st.floats(1.0, 20.0),
            bw0=st.floats(0.1, 8.0),
            bw1=st.floats(0.1, 8.0),
            size=st.floats(5.0, 120.0),
            interval=st.integers(5, 60),
            count=st.integers(2, 10),
            lookahead=st.integers(1, 4),
            exec_cap=st.sampled_from([1, 3, 17, 256]),
        )
    )

    @settings(max_examples=6, deadline=None)
    @given(scenario_params)
    def test_delta_equals_dense_property(p):
        """Per-row scatter results == whole-table merge results (traces,
        counters, world, pool) on randomized scenarios."""
        check_delta_equals_dense(p)
