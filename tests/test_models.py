"""Per-architecture smoke tests + decode/prefill consistency for all families."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.model as MM
from repro.configs.base import SHAPES, TrainConfig, applicable_shapes
from repro.configs.registry import ARCHS, get_config, smoke_config
from repro.models.model import build_model
from repro.train.loop import make_train_step
from repro.train.optimizer import init_opt_state

RNG = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, s=64, with_targets=True):
    tok = jax.random.randint(RNG, (b, s), 0, cfg.vocab)
    batch = {"tokens": tok}
    if cfg.family == "encdec":
        dec = jax.random.randint(RNG, (b, 8), 0, cfg.vocab)
        batch = {"frames": jax.random.normal(RNG, (b, s, cfg.d_model),
                                             jnp.float32),
                 "tokens": dec}
        if with_targets:
            batch["targets"] = dec
        return batch
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            RNG, (b, 16, cfg.d_model), jnp.float32)
        pos = jnp.arange(s, dtype=jnp.int32)[None].repeat(b, 0)
        batch["positions3"] = jnp.stack([pos, pos, pos])
    if with_targets:
        batch["targets"] = tok
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch, monkeypatch):
    monkeypatch.setattr(MM, "VLM_PATCHES", 16)
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params, names = model.init(RNG)
    # every param leaf has a matching logical-name tuple
    flat_p = jax.tree.leaves(params)
    flat_n = jax.tree.flatten(
        names, is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(s, str) for s in x))[0]
    assert len(flat_p) == len(flat_n)
    for p, n in zip(flat_p, flat_n):
        assert p.ndim == len(n), (p.shape, n)

    batch = make_batch(cfg)
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss))

    step = jax.jit(make_train_step(model, TrainConfig(learning_rate=1e-3)))
    opt = init_opt_state(params)
    p2, opt2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert int(opt2.step) == 1
    # params actually moved
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch, monkeypatch):
    """prefill(s) + decode == prefill(s+1): the KV-cache/recurrent-state path
    reproduces the full forward, for every architecture family."""
    monkeypatch.setattr(MM, "VLM_PATCHES", 16)
    # capacity_factor high enough to be dropless: token drops depend on the
    # whole batch's routing, which legitimately differs between prefill(s) and
    # prefill(s+1) — the test targets cache/state semantics, not drop policy.
    cfg = dataclasses.replace(smoke_config(arch), dtype="float32",
                              cache_headroom=8, capacity_factor=4.0)
    model = build_model(cfg)
    params, _ = model.init(RNG)
    b, s = 2, 48
    if cfg.family == "encdec":
        frames = jax.random.normal(RNG, (b, 64, cfg.d_model), jnp.float32)
        dec = jax.random.randint(RNG, (b, 9), 0, cfg.vocab)
        batch_s = {"frames": frames, "tokens": dec[:, :8]}
        batch_s1 = {"frames": frames, "tokens": dec}
    else:
        tok = jax.random.randint(RNG, (b, s + 1), 0, cfg.vocab)
        batch_s = {"tokens": tok[:, :s]}
        batch_s1 = {"tokens": tok}
        if cfg.family == "vlm":
            pe = jax.random.normal(RNG, (b, 16, cfg.d_model), jnp.float32)
            pos = jnp.arange(s, dtype=jnp.int32)[None].repeat(b, 0)
            pos1 = jnp.arange(s + 1, dtype=jnp.int32)[None].repeat(b, 0)
            batch_s = {**batch_s, "patch_embeds": pe,
                       "positions3": jnp.stack([pos] * 3)}
            batch_s1 = {**batch_s1, "patch_embeds": pe,
                        "positions3": jnp.stack([pos1] * 3)}

    logits_s, state = jax.jit(model.prefill_fn)(params, batch_s)
    next_tok = (batch_s1["tokens"][:, -1:])
    length = jnp.int32(8 if cfg.family == "encdec" else s)
    logits_d, _ = jax.jit(model.decode_fn)(params, state, next_tok, length)
    logits_full, _ = jax.jit(model.prefill_fn)(params, batch_s1)

    got = np.asarray(logits_d)
    want = np.asarray(logits_full)
    # window/SWA archs drop the oldest key when the cache slides: compare only
    # when semantics align (cache >= context used by the full forward)
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_cover_all_applicable_shapes(arch):
    cfg = get_config(arch)
    model = build_model(cfg)
    for sname in applicable_shapes(cfg):
        shape = SHAPES[sname]
        specs = model.input_specs(shape)
        assert "tokens" in specs
        for v in specs.values():
            assert all(d > 0 for d in v.shape)
        if shape.mode == "decode":
            st = model.decode_state_specs(shape)
            assert st is not None
            leaves = [x for x in jax.tree.leaves(st)
                      if hasattr(x, "shape")]
            assert leaves


def test_long_500k_skips_are_exactly_the_quadratic_archs():
    subq = {a for a in ARCHS if "long_500k" in applicable_shapes(get_config(a))}
    assert subq == {"rwkv6-7b", "hymba-1.5b", "mixtral-8x22b"}


def test_loss_decreases_on_structured_data():
    """~3-layer model learns the synthetic Markov stream (data pipeline signal)."""
    from repro.data import pipeline as dp
    cfg = dataclasses.replace(smoke_config("smollm-135m"), n_layers=2,
                              vocab=64, dtype="float32")
    model = build_model(cfg)
    params, _ = model.init(RNG)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(model, TrainConfig(learning_rate=3e-3,
                                                      warmup_steps=5)))
    dcfg = dp.DataConfig(vocab=64, seq_len=64, global_batch=8)
    losses = []
    for i in range(30):
        batch = dp.batch_for_shard(dcfg, i, 0, 1)
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= top_k renormalized routing, most tokens route."""
    cfg = dataclasses.replace(smoke_config("mixtral-8x22b"), dtype="float32",
                              capacity_factor=2.0)
    model = build_model(cfg)
    params, _ = model.init(RNG)
    batch = make_batch(cfg, b=2, s=64)
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss))
    assert float(metrics["aux"]) >= 1.0 - 1e-3   # >= 1 by Cauchy-Schwarz
