"""Distributed-path tests: shard_map engine == oracle (subprocess, 4 devices),
elastic re-mesh + checkpoint continuity, event-pool overflow accounting."""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import events as ev


@pytest.mark.slow
def test_shard_map_engine_matches_oracle_subprocess():
    """The real collective path (lax.pmin/all_to_all under shard_map over 4
    host devices) executes the exact oracle trace."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, json
from jax.sharding import Mesh
from repro.core import Engine, ScenarioBuilder, events as ev, run_sequential, \
    merged_engine_trace

def build(n_agents):
    b = ScenarioBuilder(max_cpu=4, queue_cap=8, max_link=4, max_flow=16)
    t0 = b.add_regional_center(n_cpu=2, cpu_power=10.0, disk=500.0,
                               tape=5000.0, tape_rate=5.0)
    t1 = b.add_regional_center(n_cpu=2, cpu_power=8.0, disk=300.0,
                               tape=3000.0, tape_rate=5.0)
    wan = b.add_net_region(link_bws=[2.0, 2.0], link_lats=[5, 5])
    b.add_generator(target_lp=wan, kind=ev.K_FLOW_START,
                    payload=[40.0, 0, -1, -1, t1["farm"], ev.K_JOB_SUBMIT,
                             t1["storage"], ev.K_DATA_WRITE],
                    interval=25, count=12, start=0)
    return b.build(n_agents=n_agents, lookahead=2, t_end=5000, pool_cap=256,
                   work_per_mb=2.0)

w, o, e, s = build(1)
_, _, otrace = run_sequential(w, o, e, s)
w, o, e, s = build(4)
eng = Engine(w, o, e, s, trace_cap=4096)
mesh = Mesh(np.array(jax.devices()), ("agents",))
st = eng.run_distributed(mesh, max_windows=20000)
trace = merged_engine_trace(np.asarray(st.trace), np.asarray(st.trace_n))
print(json.dumps({"match": trace == otrace, "n": len(trace)}))
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["match"] and res["n"] > 0


def test_elastic_failure_recovery_continuity(tmp_path):
    """Fleet shrink mid-run: checkpoint -> remesh plan -> restore -> continue
    with the re-sharded stateless pipeline; training proceeds and the global
    batch stream is unchanged."""
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.configs.base import TrainConfig
    from repro.configs.registry import smoke_config
    from repro.data import pipeline as dp
    from repro.ft import elastic
    from repro.models.model import build_model
    from repro.train.loop import make_train_step
    from repro.train.optimizer import init_opt_state

    cfg = dataclasses.replace(smoke_config("smollm-135m"), dtype="float32")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    tc = TrainConfig(learning_rate=1e-3)
    step = jax.jit(make_train_step(model, tc))
    dcfg = dp.DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
    ck = Checkpointer(str(tmp_path))

    # healthy fleet: 4 logical shards
    for i in range(3):
        batches = [dp.batch_for_shard(dcfg, i, s, 4) for s in range(4)]
        glob = {k: jnp.concatenate([b[k] for b in batches])
                for k in batches[0]}
        params, opt, m = step(params, opt, glob)
    ck.save(3, (params, opt), blocking=True)

    # lose half the fleet: remesh, restore, resume with 2 shards
    plan = elastic.plan_remesh(2, model_parallel=1)
    assert elastic.validate_plan(plan, 2)
    n_shards = plan.n_devices
    step_no, (params, opt) = ck.restore((params, opt))
    assert step_no == 3
    for i in range(3, 6):
        batches = [dp.batch_for_shard(dcfg, i, s, n_shards)
                   for s in range(n_shards)]
        glob = {k: jnp.concatenate([b[k] for b in batches])
                for k in batches[0]}
        # identical global stream despite re-sharding
        ref = dp.batch_for_shard(dcfg, i, 0, 1)
        np.testing.assert_array_equal(np.asarray(glob["tokens"]),
                                      np.asarray(ref["tokens"]))
        params, opt, m = step(params, opt, glob)
    assert np.isfinite(float(m["loss"]))


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 40), st.integers(1, 40), st.integers(0, 2**31 - 1))
def test_event_pool_insert_overflow_accounting(n_live, n_new, seed):
    """insert() fills free slots deterministically and counts every drop."""
    cap = 32
    rng = np.random.RandomState(seed)
    pool = ev.empty_pool(cap)
    pre = ev.empty_batch(max(n_live, 1))
    pre = pre._replace(
        time=jnp.asarray(rng.randint(0, 100, max(n_live, 1)), jnp.int32),
        valid=jnp.asarray([True] * n_live + [False] * (max(n_live, 1) - n_live)))
    pool, d0 = ev.insert(pool, pre)
    live0 = int(np.asarray(pool.valid).sum())
    assert live0 == min(n_live, cap)
    assert int(d0) == max(0, n_live - cap)

    batch = ev.empty_batch(max(n_new, 1))
    batch = batch._replace(
        time=jnp.asarray(rng.randint(0, 100, max(n_new, 1)), jnp.int32),
        valid=jnp.asarray([True] * n_new + [False] * (max(n_new, 1) - n_new)))
    pool2, dropped = ev.insert(pool, batch)
    live = int(np.asarray(pool2.valid).sum())
    assert live == min(live0 + n_new, cap)
    assert int(dropped) == max(0, live0 + n_new - cap)
    # free slots carry T_INF so min-reductions never need a mask
    t = np.asarray(pool2.time)
    assert np.all(t[~np.asarray(pool2.valid)] == 2**31 - 1)
