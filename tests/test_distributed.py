"""Distributed-path tests: shard_map engine == oracle (subprocess, 4 devices),
the randomized scale-out equivalence property, elastic re-mesh + checkpoint
continuity, event-pool overflow accounting."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import example, given, settings, strategies as st

from distributed_harness import run_distributed_child
from repro.core import events as ev


@pytest.mark.slow
def test_shard_map_engine_matches_oracle_subprocess():
    """The real collective path (lax.pmin/all_to_all under shard_map over 4
    host devices) executes the exact oracle trace."""
    res = run_distributed_child(r"""
otrace = oracle_trace()
w, o, e, s = t0t1_build(4)
eng = Engine(w, o, e, s, trace_cap=4096)
mesh = Mesh(np.array(jax.devices()), ("agents",))
st = eng.run_distributed(mesh, max_windows=20000)
trace = engine_trace(st)
print(json.dumps({"match": trace == otrace, "n": len(trace)}))
""")
    assert res["match"] and res["n"] > 0


@pytest.mark.slow
def test_shard_map_fused_select_matches_oracle_subprocess():
    """The fused superstep megakernel under the real collective path: fused
    run_distributed (including the non-divisible 3-agents-on-4-devices
    packing) == fused run_local == the stitched distributed engine == the
    heapq oracle, byte-exactly in full state; the fused adaptive-width
    driver executes the oracle trace too."""
    res = run_distributed_child(r"""
otrace = oracle_trace()
checks = {}
mesh = Mesh(np.array(jax.devices()), ("agents",))
for n in (3, 4):
    fused = t0t1_build(n, fused_select=True)
    eng_f = Engine(*fused, trace_cap=4096)
    st_f = eng_f.run_distributed(mesh, max_windows=20000)
    checks[f"fused_dist_trace_is_oracle_n{n}"] = engine_trace(st_f) == otrace
    st_l = eng_f.run_local(max_windows=20000)
    checks[f"fused_dist_local_state_equal_n{n}"] = tree_eq(st_f, st_l)
    st_s = Engine(*t0t1_build(n), trace_cap=4096).run_distributed(
        mesh, max_windows=20000)
    checks[f"fused_matches_stitched_n{n}"] = tree_eq(st_f, st_s)
st_a = Engine(*t0t1_build(6, fused_select=True),
              trace_cap=4096).run_distributed_adaptive(
    mesh, max_windows=20000, policy=ExecPolicy(ladder=(1, 4, 16)))
checks["fused_adaptive_trace_is_oracle"] = engine_trace(st_a) == otrace
print(json.dumps(checks))
""")
    failed = {k: v for k, v in res.items() if v is not True}
    assert not failed, failed


# The pinned acceptance cases: one with cross-shard event migration, one with
# the adaptive per-shard width ladder actually moving rungs (verified: this
# scenario spills at width 1 and climbs through every rung).
_MIGRATE_CASE = dict(n_agents=6, pool_cap=256, n_flows=12, interval=25,
                     second_gen=False, ladder=None, migrate=True,
                     mig_window=20)
_ADAPTIVE_CASE = dict(n_agents=6, pool_cap=256, n_flows=12, interval=5,
                      second_gen=True, ladder=(1, 4, 16), migrate=False,
                      mig_window=20)


@pytest.mark.slow
@settings(max_examples=3, deadline=None)
@example(**_MIGRATE_CASE)
@example(**_ADAPTIVE_CASE)
@given(n_agents=st.sampled_from([3, 5, 6, 7]),
       pool_cap=st.sampled_from([48, 256]),
       n_flows=st.sampled_from([8, 12]),
       interval=st.sampled_from([5, 25]),
       second_gen=st.booleans(),
       ladder=st.sampled_from([None, (1, 4, 16), (2, 8, 32)]),
       migrate=st.booleans(),
       mig_window=st.integers(5, 40))
def test_distributed_scale_out_equivalence_property(n_agents, pool_cap,
                                                    n_flows, interval,
                                                    second_gen, ladder,
                                                    migrate, mig_window):
    """Randomized scale-out specs — agent counts not divisible by the device
    count, mixed generators, small pool caps, adaptive ladders, mid-run
    cross-shard migration — all satisfy distributed == run_local ==
    run_adaptive == oracle on traces, counters, and final world (the static
    and adaptive pairs byte-identical in full state; every driver's merged
    trace byte-identical to the sequential heapq oracle; zero drop counters
    as the exactness precondition)."""
    params = dict(n_agents=n_agents, pool_cap=pool_cap, n_flows=n_flows,
                  interval=interval, second_gen=second_gen,
                  ladder=list(ladder) if ladder else None, migrate=migrate,
                  mig_window=mig_window)
    res = run_distributed_child(f"params = {params!r}\n" + r"""
n = params["n_agents"]
bkw = dict(pool_cap=params["pool_cap"], n_flows=params["n_flows"],
           interval=params["interval"], second_gen=params["second_gen"])
otrace = oracle_trace(**bkw)
w, o, e, s = t0t1_build(n, **bkw)
eng = Engine(w, o, e, s, trace_cap=4096)
mesh = Mesh(np.array(jax.devices()), ("agents",))
checks = {}
state_d = state_l = None
if params["migrate"]:
    # run a few windows distributed, swap the first and last agents'
    # LPs (cross-shard for any n > K), then continue both drivers from
    # the migrated state
    axes = eng._dist_axes(mesh)
    stp = eng._pad_state(eng.init_state(), axes.size)
    step = eng._dist_window_fn(mesh, s.exec_cap)
    for _ in range(params["mig_window"]):
        stp = step(stp)
    mid = eng._slice_state(stp)
    la = np.asarray(mid.world.lp_agent[0])
    hi = n - 1
    new_la = np.where(la == 0, hi, np.where(la == hi, 0, la)).astype(np.int32)
    state_d = eng.apply_placement_distributed(mid, new_la, mesh)
    state_l = eng.apply_placement_local(mid, new_la)
    checks["migrated_states_equal"] = tree_eq(state_d, state_l)
    cnt = np.asarray(state_d.counters)
    checks["migrate_out_in_balanced"] = (
        int(cnt[:, mon.C_MIGRATE_OUT].sum())
        == int(cnt[:, mon.C_MIGRATE_IN].sum()))
st_d = eng.run_distributed(mesh, max_windows=20000, state=state_d)
st_l = eng.run_local(max_windows=20000, state=state_l)
checks["static_full_state_equal"] = tree_eq(st_d, st_l)
checks["static_trace_is_oracle"] = engine_trace(st_d) == otrace
if params["ladder"]:
    p = ExecPolicy(ladder=tuple(params["ladder"]))
    st_a = eng.run_adaptive(max_windows=20000, policy=p, state=state_l)
    rungs_a = eng.adaptive_rungs
    st_da = eng.run_distributed_adaptive(mesh, max_windows=20000, policy=p,
                                         state=state_d)
    rungs_da = eng.adaptive_rungs
    checks["adaptive_full_state_equal"] = tree_eq(st_a, st_da)
    checks["adaptive_rungs_lockstep"] = rungs_a == rungs_da
    checks["adaptive_trace_is_oracle"] = engine_trace(st_da) == otrace
    checks["adaptive_final_world_matches_static"] = tree_eq(
        st_da.world, st_d.world)
    checks["info_adaptive_engaged"] = len(set(rungs_a)) > 1
cnt = np.asarray(st_d.counters)
checks["no_drops"] = (int(cnt[:, mon.C_DROP_POOL].sum()) == 0
                      and int(cnt[:, mon.C_DROP_ROUTE].sum()) == 0)
print(json.dumps(checks))
""")
    failed = {k: v for k, v in res.items()
              if not k.startswith("info_") and v is not True}
    assert not failed, (failed, params)
    if params == {**_ADAPTIVE_CASE,
                  "ladder": list(_ADAPTIVE_CASE["ladder"])}:
        assert res["info_adaptive_engaged"], res


def test_elastic_failure_recovery_continuity(tmp_path):
    """Fleet shrink mid-run: checkpoint -> remesh plan -> restore -> continue
    with the re-sharded stateless pipeline; training proceeds and the global
    batch stream is unchanged."""
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.configs.base import TrainConfig
    from repro.configs.registry import smoke_config
    from repro.data import pipeline as dp
    from repro.ft import elastic
    from repro.models.model import build_model
    from repro.train.loop import make_train_step
    from repro.train.optimizer import init_opt_state

    cfg = dataclasses.replace(smoke_config("smollm-135m"), dtype="float32")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    tc = TrainConfig(learning_rate=1e-3)
    step = jax.jit(make_train_step(model, tc))
    dcfg = dp.DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
    ck = Checkpointer(str(tmp_path))

    # healthy fleet: 4 logical shards
    for i in range(3):
        batches = [dp.batch_for_shard(dcfg, i, s, 4) for s in range(4)]
        glob = {k: jnp.concatenate([b[k] for b in batches])
                for k in batches[0]}
        params, opt, m = step(params, opt, glob)
    ck.save(3, (params, opt), blocking=True)

    # lose half the fleet: remesh, restore, resume with 2 shards
    plan = elastic.plan_remesh(2, model_parallel=1)
    assert elastic.validate_plan(plan, 2)
    n_shards = plan.n_devices
    step_no, (params, opt) = ck.restore((params, opt))
    assert step_no == 3
    for i in range(3, 6):
        batches = [dp.batch_for_shard(dcfg, i, s, n_shards)
                   for s in range(n_shards)]
        glob = {k: jnp.concatenate([b[k] for b in batches])
                for k in batches[0]}
        # identical global stream despite re-sharding
        ref = dp.batch_for_shard(dcfg, i, 0, 1)
        np.testing.assert_array_equal(np.asarray(glob["tokens"]),
                                      np.asarray(ref["tokens"]))
        params, opt, m = step(params, opt, glob)
    assert np.isfinite(float(m["loss"]))


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 40), st.integers(1, 40), st.integers(0, 2**31 - 1))
def test_event_pool_insert_overflow_accounting(n_live, n_new, seed):
    """insert() fills free slots deterministically and counts every drop."""
    cap = 32
    rng = np.random.RandomState(seed)
    pool = ev.empty_pool(cap)
    pre = ev.empty_batch(max(n_live, 1))
    pre = pre._replace(
        time=jnp.asarray(rng.randint(0, 100, max(n_live, 1)), jnp.int32),
        valid=jnp.asarray([True] * n_live + [False] * (max(n_live, 1) - n_live)))
    pool, d0 = ev.insert(pool, pre)
    live0 = int(np.asarray(pool.valid).sum())
    assert live0 == min(n_live, cap)
    assert int(d0) == max(0, n_live - cap)

    batch = ev.empty_batch(max(n_new, 1))
    batch = batch._replace(
        time=jnp.asarray(rng.randint(0, 100, max(n_new, 1)), jnp.int32),
        valid=jnp.asarray([True] * n_new + [False] * (max(n_new, 1) - n_new)))
    pool2, dropped = ev.insert(pool, batch)
    live = int(np.asarray(pool2.valid).sum())
    assert live == min(live0 + n_new, cap)
    assert int(dropped) == max(0, live0 + n_new - cap)
    # free slots carry T_INF so min-reductions never need a mask
    t = np.asarray(pool2.time)
    assert np.all(t[~np.asarray(pool2.valid)] == 2**31 - 1)
