"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 device; only the
dry-run (and the subprocess tests that exec it) get placeholder devices."""
import pytest

from repro.core import ScenarioBuilder


def t0t1_builder(*, wan_bw=2.0, n_flows=12, interval=25, flow_mb=40.0,
                 lookahead=2):
    """The paper's T0/T1 replication study, small: production at T0 generates
    WAN transfers; arrival triggers analysis jobs at T1; results hit storage."""
    from repro.core.components import DATA_WRITE, FLOW_START, JOB_SUBMIT

    b = ScenarioBuilder(max_cpu=4, queue_cap=8, max_link=4, max_flow=16)
    t0 = b.add_regional_center(n_cpu=2, cpu_power=10.0, disk=500.0, tape=5000.0,
                               tape_rate=5.0)
    t1 = b.add_regional_center(n_cpu=2, cpu_power=8.0, disk=300.0, tape=3000.0,
                               tape_rate=5.0)
    wan = b.add_net_region(link_bws=[wan_bw, wan_bw], link_lats=[5, 5])
    b.add_generator(
        target_lp=wan, kind=FLOW_START,
        payload=FLOW_START.pack(size=flow_mb, l0=0, notify_lp=t1["farm"],
                                notify_kind=JOB_SUBMIT.id,
                                notify2_lp=t1["storage"],
                                notify2_kind=DATA_WRITE.id),
        interval=interval, count=n_flows, start=0)
    return b, dict(lookahead=lookahead, t_end=5000, pool_cap=256,
                   work_per_mb=2.0)


@pytest.fixture(scope="session")
def t0t1_oracle():
    from repro.core import run_sequential
    b, kw = t0t1_builder()
    world, own, init_ev, spec = b.build(n_agents=1, **kw)
    return run_sequential(world, own, init_ev, spec)
