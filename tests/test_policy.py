"""Monitoring-driven adaptive exec width (core/policy.py + Engine.run_adaptive).

Pins the PR 5 acceptance claim: on a spill-heavy scenario the adaptive ladder
completes in *fewer windows* than the static exec_cap=256 default while the
merged trace and the final world state stay byte-identical to the sequential
oracle — spilling is exact for any width sequence, so the policy trades only
window count. Plus unit coverage for the ladder decision function and the
policy plumbing (spec normalization, single-rung equivalence, jit cache
reuse).
"""

import numpy as np
import pytest

from repro.core import (
    Engine,
    ScenarioBuilder,
    events as ev,
    merged_engine_trace,
    run_sequential,
)
from repro.core import monitoring as mon
from repro.core import policy as pol
from conftest import t0t1_builder

LADDER = pol.ExecPolicy(ladder=(64, 256, 1024))


def stats(processed=0, spilled=0, rows=0, occupancy=0.0):
    return pol.WindowStats(
        processed=processed, spilled=spilled, rows=rows, occupancy=occupancy
    )


# ------------------------------------------------------------ decision unit


def test_grow_on_spill_pressure():
    assert pol.choose_rung(LADDER, 1, stats(processed=256, spilled=100)) == 2


def test_grow_near_pool_saturation():
    assert pol.choose_rung(LADDER, 0, stats(processed=10, occupancy=0.9)) == 1


def test_shrink_on_sparse_window():
    assert pol.choose_rung(LADDER, 2, stats(processed=5, rows=5)) == 1


def test_hold_on_moderate_load():
    s = stats(processed=200, rows=200)  # too big for the lower rung
    assert pol.choose_rung(LADDER, 1, s) == 1


def test_scatter_volume_blocks_shrink():
    """C_BATCH_ROWS is a utilization signal: heavy scatter, no shrink."""
    s = stats(processed=5, rows=200)
    assert pol.choose_rung(LADDER, 2, s) == 2


def test_clamped_at_ladder_ends():
    assert pol.choose_rung(LADDER, 2, stats(spilled=10_000)) == 2
    assert pol.choose_rung(LADDER, 0, stats()) == 0


def test_ladder_validation():
    with pytest.raises(ValueError, match="ascending"):
        pol.ExecPolicy(ladder=(64, 64))
    with pytest.raises(ValueError, match="non-empty"):
        pol.ExecPolicy(ladder=())
    with pytest.raises(ValueError, match="init_rung"):
        pol.ExecPolicy(ladder=(8,), init_rung=3)
    assert pol.normalize(17).ladder == (17,)
    assert pol.normalize(LADDER) is LADDER


def test_default_ladder_shape():
    lad = pol.default_ladder(4096)
    assert lad[0] == 64 and 256 in lad and lad[-1] == 4096
    assert all(b > a for a, b in zip(lad, lad[1:]))


def test_window_stats_extraction():
    prev = np.zeros((2, mon.N_COUNTERS), np.int32)
    cur = np.zeros((2, mon.N_COUNTERS), np.int32)
    cur[0, mon.C_EVENTS] = 10
    cur[1, mon.C_EVENTS] = 30
    cur[1, mon.C_EXEC_SPILL] = 5
    cur[0, mon.C_POOL_OCC] = 96
    s = pol.window_stats(prev, cur, pool_cap=128)
    assert s.processed == 30 and s.spilled == 5 and s.occupancy == 0.75


# -------------------------------------------------- spec / builder plumbing


def test_spec_exec_policy_accepts_int_and_ladder():
    from repro.core.registry import RegistryError

    b, kw = t0t1_builder()
    *_, spec = b.build(n_agents=1, exec_cap=17, **kw)
    assert spec.exec_policy == 17 and spec.exec_cap == 17
    b, kw = t0t1_builder()
    *_, spec = b.build(n_agents=1, exec_policy=LADDER, **kw)
    assert spec.exec_cap == 64  # init rung width
    b, kw = t0t1_builder()
    with pytest.raises(RegistryError, match="not both"):
        b.build(n_agents=1, exec_cap=4, exec_policy=LADDER, **kw)


# ---------------------------------------------------------------- acceptance


def spill_heavy(width=512, n_ticks=3, lookahead=4, pool_cap=2048, **kw):
    """Every window offers ``width`` same-tick events: static 256 spills."""
    b = ScenarioBuilder(max_cpu=1, queue_cap=2, max_link=1, max_flow=2)
    sinks = [b.add_idle_lp() for _ in range(width)]
    for t in range(n_ticks):
        for lp in sinks:
            b.add_event(time=1 + lookahead * t, kind=ev.K_NOOP, src=lp, dst=lp)
    return b.build(
        n_agents=1,
        lookahead=lookahead,
        t_end=lookahead * (n_ticks + 1) + 2,
        pool_cap=pool_cap,
        emit_cap=64,
        **kw,
    )


def test_adaptive_beats_static_windows_and_stays_oracle_exact():
    """The acceptance criterion: fewer windows than static exec_cap=256,
    byte-identical merged trace + final world vs the sequential oracle."""
    built_s = spill_heavy(exec_cap=256)
    world, own, init_ev, spec_s = built_s
    ow, _oc, otrace = run_sequential(world, own, init_ev, spec_s)
    st_s = Engine(world, own, init_ev, spec_s, trace_cap=4096).run_local()

    ladder = pol.ExecPolicy(ladder=(256, 512))
    world, own, init_ev, spec_a = spill_heavy(exec_policy=ladder)
    eng = Engine(world, own, init_ev, spec_a, trace_cap=4096)
    st_a = eng.run_adaptive()

    w_static = int(np.asarray(st_s.windows)[0])
    w_adapt = int(np.asarray(st_a.windows)[0])
    assert w_adapt < w_static
    assert max(eng.adaptive_rungs) == 1  # the ladder actually grew

    tr_a = merged_engine_trace(np.asarray(st_a.trace), np.asarray(st_a.trace_n))
    tr_s = merged_engine_trace(np.asarray(st_s.trace), np.asarray(st_s.trace_n))
    assert tr_a == tr_s == otrace
    for name, a, b in zip(st_a.world._fields, st_a.world, st_s.world):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)
    # same events processed, strictly less spill
    ca = np.asarray(st_a.counters)[0]
    cs = np.asarray(st_s.counters)[0]
    assert ca[mon.C_EVENTS] == cs[mon.C_EVENTS]
    assert ca[mon.C_EXEC_SPILL] < cs[mon.C_EXEC_SPILL]


def test_adaptive_shrinks_back_on_sparse_tail():
    """A dense burst followed by a sparse tail: the ladder grows for the
    burst, returns to the bottom rung on the tail — and stays oracle-exact."""
    lookahead = 4
    b = ScenarioBuilder(max_cpu=1, queue_cap=2, max_link=1, max_flow=2)
    sinks = [b.add_idle_lp() for _ in range(64)]
    for lp in sinks:  # dense same-tick burst
        b.add_event(time=1, kind=ev.K_NOOP, src=lp, dst=lp)
    for i in range(8):  # sparse one-event tail
        t = 100 + 4 * lookahead * i
        b.add_event(time=t, kind=ev.K_NOOP, src=sinks[0], dst=sinks[0])
    world, own, init_ev, spec = b.build(
        n_agents=1,
        lookahead=lookahead,
        t_end=100 + 4 * lookahead * 9,
        pool_cap=256,
        emit_cap=16,
        exec_policy=pol.ExecPolicy(ladder=(8, 32, 64)),
    )
    _ow, _oc, otrace = run_sequential(world, own, init_ev, spec)
    eng = Engine(world, own, init_ev, spec, trace_cap=4096)
    st = eng.run_adaptive()
    tr = merged_engine_trace(np.asarray(st.trace), np.asarray(st.trace_n))
    assert tr == otrace
    assert max(eng.adaptive_rungs) > 0  # grew for the burst
    assert eng.adaptive_rungs[-1] == 0  # drained back down


def test_single_rung_adaptive_equals_static_run():
    """A one-rung ladder is the static engine, window for window."""
    built = spill_heavy(width=64, exec_cap=256)
    world, own, init_ev, spec = built
    st_s = Engine(world, own, init_ev, spec, trace_cap=4096).run_local()
    eng = Engine(world, own, init_ev, spec, trace_cap=4096)
    st_a = eng.run_adaptive(policy=256)
    np.testing.assert_array_equal(np.asarray(st_s.windows), np.asarray(st_a.windows))
    np.testing.assert_array_equal(
        np.asarray(st_s.counters), np.asarray(st_a.counters)
    )
    for name, a, b in zip(st_s.world._fields, st_s.world, st_a.world):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)


def test_rung_programs_are_jit_cached():
    world, own, init_ev, spec = spill_heavy(
        width=64, exec_policy=pol.ExecPolicy(ladder=(32, 64))
    )
    eng = Engine(world, own, init_ev, spec)
    eng.run_adaptive()
    cached = {k for k in eng._jit_cache if isinstance(k, tuple) and k[0] == "window"}
    assert cached <= {("window", 32), ("window", 64)} and cached
    before = {k: id(v) for k, v in eng._jit_cache.items()}
    eng.run_adaptive()  # second run: no new entries, no recompiles
    assert {k: id(v) for k, v in eng._jit_cache.items()} == before
