"""Declarative component & handler registry (PR 4).

Pins the api_redesign contract:

* the generated tables are *identical* to the PR 3 hand-written surface
  (World/WorldDelta/WorldOwnership layouts, DELTA_SCHEMA, KIND_TABLE, kind
  ids) — literal snapshots, so a registry regression cannot silently reshape
  the engine;
* registry validation rejects malformed models (duplicate kinds/components,
  field collisions, bad row shapes, non-mutable writes, missing whole-row
  fields, unknown tables/handlers);
* registry-generated dispatch matches the sequential oracle and the
  sequential engine path byte-for-byte on the seed scenarios (fixed +
  hypothesis), i.e. the refactor changed zero semantics;
* a component defined entirely outside core (the replica cache in
  repro/scenarios/cache.py) runs batched, conflict-masked, synced, and
  byte-identical to the oracle — the seam the PR exists for;
* trace-buffer overflow is counted (C_TRACE_DROP) and oracle-equivalence
  comparisons fail loudly instead of comparing truncated traces.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import t0t1_builder
from repro.core import Engine, events as ev, merged_engine_trace, run_sequential
from repro.core import handlers as hd
from repro.core import monitoring as mon
from repro.core.components import (
    BUILTIN,
    World,
    WorldOwnership,
    register_builtin_model,
)
from repro.core.registry import (
    FieldSpec,
    PayloadSpec,
    Registry,
    RegistryError,
    ScenarioBuilderBase,
)
from repro.scenarios.cache import (
    CACHE_LOOKUP,
    CACHE_REGISTRY,
    CacheScenarioBuilder,
    build_churn_scenario,
)
from test_batched_dispatch import assert_states_identical, engine_trace, run_pair

# ---------------------------------------------------------------------------
# Generated tables == the PR 3 hand-written surface (literal snapshots)
# ---------------------------------------------------------------------------

PR3_WORLD_FIELDS = (
    "lp_kind",
    "lp_agent",
    "lp_res",
    "lp_state",
    "lp_lvt",
    "lp_ctx",
    "cpu_power",
    "cpu_busy",
    "cpu_mem",
    "jobq",
    "jobq_n",
    "link_bw",
    "link_lat",
    "flow_active",
    "flow_rem",
    "flow_rate",
    "flow_tlast",
    "flow_links",
    "flow_notify",
    "net_gen",
    "sto_cap",
    "sto_used",
    "sto_rate",
    "sto_flag",
    "gen_interval",
    "gen_left",
    "gen_target",
    "gen_kind",
    "gen_payload",
)
PR3_DELTA_FIELDS = (
    "farm_row",
    "cpu_busy",
    "cpu_mem",
    "jobq",
    "jobq_n",
    "net_row",
    "flow_active",
    "flow_rem",
    "flow_rate",
    "flow_tlast",
    "flow_links",
    "flow_notify",
    "net_gen",
    "sto_row",
    "sto_used",
    "sto_flag",
    "gen_row",
    "gen_left",
)
PR3_DELTA_SCHEMA = {
    "cpu_busy": "farm_row",
    "cpu_mem": "farm_row",
    "jobq": "farm_row",
    "jobq_n": "farm_row",
    "flow_active": "net_row",
    "flow_rem": "net_row",
    "flow_rate": "net_row",
    "flow_tlast": "net_row",
    "flow_links": "net_row",
    "flow_notify": "net_row",
    "net_gen": "net_row",
    "sto_used": "sto_row",
    "sto_flag": "sto_row",
    "gen_left": "gen_row",
}
PR3_KIND_TABLE = (0, 2, 2, 1, 1, 3, 3, 4)
PR3_KIND_IDS = dict(
    K_NOOP=0,
    K_FLOW_START=1,
    K_FLOW_END=2,
    K_JOB_SUBMIT=3,
    K_JOB_END=4,
    K_DATA_WRITE=5,
    K_MIGRATE=6,
    K_GEN_TICK=7,
)


def test_generated_structs_match_pr3_handwritten_layout():
    assert World._fields == PR3_WORLD_FIELDS
    assert hd.WorldDelta._fields == PR3_DELTA_FIELDS
    assert WorldOwnership._fields == ("farm_lp", "net_lp", "sto_lp", "gen_lp")
    assert hd.DELTA_SCHEMA == PR3_DELTA_SCHEMA
    assert tuple(ev.KIND_TABLE) == PR3_KIND_TABLE
    assert ev.N_KINDS == 8 and ev.N_TABLES == 5
    for name, kid in PR3_KIND_IDS.items():
        assert getattr(ev, name) == kid


def test_fresh_registry_regenerates_identical_tables():
    """The drift gate's core claim: re-running the declarations on a fresh
    registry reproduces exactly what core exports."""
    fresh = Registry()
    register_builtin_model(fresh)
    assert fresh.kind_table == BUILTIN.kind_table
    assert fresh.delta_schema == BUILTIN.delta_schema
    assert fresh.world_struct()._fields == World._fields
    assert fresh.delta_struct()._fields == hd.WorldDelta._fields
    assert fresh.sync_plan() == BUILTIN.sync_plan()


def test_check_api_drift_gate_passes():
    import importlib.util
    import pathlib

    path = pathlib.Path(__file__).resolve().parents[1] / "tools/check_api.py"
    spec = importlib.util.spec_from_file_location("check_api", path)
    m = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(m)
    assert m.check() == []


# ---------------------------------------------------------------------------
# Validation errors
# ---------------------------------------------------------------------------


def _mini_registry():
    r = Registry()
    r.dim("ways", 4)
    r.component(
        "box",
        fields=dict(
            box_cap=FieldSpec((), jnp.float32),
            box_used=FieldSpec((), jnp.float32, mutable=True),
            box_tags=FieldSpec(("ways",), jnp.int32, mutable=True, fill=-1),
        ),
    )
    return r


def test_duplicate_component_rejected():
    r = _mini_registry()
    with pytest.raises(RegistryError, match="duplicate component"):
        r.component("box", fields=dict(x=FieldSpec((), jnp.int32)))


def test_duplicate_kind_rejected():
    r = _mini_registry()
    r.kind("PUT", table="box")
    with pytest.raises(RegistryError, match="duplicate event kind"):
        r.kind("PUT", table="box")


def test_field_collision_across_components_rejected():
    """World is one flat structure-of-arrays: field names are global."""
    r = _mini_registry()
    dup = dict(box_used=FieldSpec((), jnp.float32, mutable=True))
    with pytest.raises(RegistryError, match="collides"):
        r.component("box2", fields=dup)
    with pytest.raises(RegistryError, match="collides"):
        r.component("box3", fields=dict(lp_kind=FieldSpec((), jnp.int32)))


def test_kind_with_unknown_table_fails_at_seal():
    r = _mini_registry()
    r.kind("PUT", table="nonexistent")
    with pytest.raises(RegistryError, match="not a registered component"):
        r.world_struct()


def test_unknown_dim_in_field_shape_rejected():
    r = Registry()
    bad = dict(x=FieldSpec(("no_such_dim",), jnp.int32))
    with pytest.raises(RegistryError, match="unknown dim"):
        r.component("box", fields=bad)


def test_mutable_float_field_with_nonzero_fill_rejected():
    """Nonzero fills survive sync via an int shift encoding; floats would
    lose byte-exactness, so the declaration is rejected up front."""
    r = Registry()
    bad = dict(x=FieldSpec((), jnp.float32, mutable=True, fill=-1.0))
    with pytest.raises(RegistryError, match="fill=0"):
        r.component("box", fields=bad)


def test_handler_registration_validation():
    r = _mini_registry()
    put = r.kind("PUT", table="box")
    with pytest.raises(RegistryError, match="unknown event kind"):
        r.on("GET")

    @r.on(put)
    def h_put(env, world, counters, e):  # pragma: no cover - stub
        return env.empty_delta(world), counters, None

    with pytest.raises(RegistryError, match="already has handler"):
        r.on(put)(h_put)


def test_missing_handler_fails_make_handlers():
    r = _mini_registry()
    r.kind("PUT", table="box")
    with pytest.raises(RegistryError, match="no handler registered"):
        r.make_handlers(lookahead=1)


def test_sealed_registry_rejects_new_declarations_but_extend_works():
    r = _mini_registry()
    r.world_struct()  # seals structure
    with pytest.raises(RegistryError, match="sealed"):
        r.component("late", fields=dict(x=FieldSpec((), jnp.int32)))
    with pytest.raises(RegistryError, match="sealed"):
        r.kind("LATE")
    r2 = r.extend()
    r2.component("late", fields=dict(late_x=FieldSpec((), jnp.int32)))
    assert "late" in r2.components and "late" not in r.components


def test_payload_spec_validation():
    with pytest.raises(RegistryError, match="at most"):
        PayloadSpec(*[f"f{i}" for i in range(9)])
    with pytest.raises(RegistryError, match="duplicate payload field"):
        PayloadSpec("a", ("a", 1.0))
    p = PayloadSpec("size", ("lp", -1))
    np.testing.assert_array_equal(p.pack(size=3.0), [3.0, -1.0])
    with pytest.raises(RegistryError, match="unknown payload field"):
        p.pack(bogus=1.0)
    assert p.index("lp") == 1
    with pytest.raises(RegistryError, match="float32 or int32"):
        PayloadSpec(("x", 0, jnp.float64))


def test_builder_row_validation():
    from repro.core.registry import ScenarioBuilderBase

    class Generic(ScenarioBuilderBase):
        _registry = CACHE_REGISTRY

    b = CacheScenarioBuilder(cache_ways=4)
    with pytest.raises(RegistryError, match="unknown builder dim"):
        Generic(no_such_dim=3)
    with pytest.raises(RegistryError, match="unknown field"):
        b.add_component("cache", bogus=1)
    with pytest.raises(RegistryError, match="exceeds the declared dim"):
        b.add_cache(cache_keys=[1, 2, 3, 4, 5])  # ways=4
    with pytest.raises(RegistryError, match="rank-0"):
        b.add_cache(cache_ptr=[1, 2])  # scalar field, 1-D value
    with pytest.raises(RegistryError, match="unknown component"):
        b.add_component("nope")


def test_make_delta_enforces_the_delta_contract():
    built, _caches = build_churn_scenario(n_caches=2, n_rounds=1)
    world = built[0]
    reg = CACHE_REGISTRY
    full = dict(
        cache_keys=world.cache_keys[0],
        cache_ptr=jnp.int32(0),
        cache_hits=jnp.int32(0),
        cache_miss=jnp.int32(0),
    )
    d = reg.make_delta(world, "cache", 0, **full)
    assert int(d.cache_row) == 0
    # writing an immutable field is an error, not a silent scatter
    with pytest.raises(RegistryError, match="non-mutable"):
        reg.make_delta(world, "cache", 0, cache_hit_lat=jnp.int32(2), **full)
    # the whole-row-write half of the contract: every mutable field
    with pytest.raises(RegistryError, match="whole-row"):
        reg.make_delta(world, "cache", 0, cache_hits=jnp.int32(1))
    with pytest.raises(RegistryError, match="unknown component"):
        reg.make_delta(world, "disk", 0)


def test_counter_declaration_and_validation():
    """Registry.counter: builtin seed + extension appends + validation."""
    r = _mini_registry()
    assert r.n_counters == mon.N_COUNTERS
    assert r.counters["EVENTS"] == mon.C_EVENTS
    idx = r.counter("BOX_PUTS", "puts served")
    assert idx == mon.N_COUNTERS and r.counter_index("BOX_PUTS") == idx
    with pytest.raises(RegistryError, match="duplicate counter"):
        r.counter("BOX_PUTS")
    with pytest.raises(RegistryError, match="duplicate counter"):
        r.counter("EVENTS")  # builtin names are taken
    with pytest.raises(RegistryError, match="identifier"):
        r.counter("not a name")
    with pytest.raises(RegistryError, match="unknown counter"):
        r.counter_index("NOPE")
    # extend() inherits declared counters; sealing closes declaration
    child = r.extend()
    assert child.counter_index("BOX_PUTS") == idx
    r.world_struct()
    with pytest.raises(RegistryError, match="sealed"):
        r.counter("LATE")


def test_cache_declared_counters_flow_through_engine_and_oracle():
    """The outside-core cache counters (no monitoring.py edit) count the
    same events in the engine (batched + sequential) and the oracle."""
    from repro.scenarios.cache import C_CACHE_FILLS, C_CACHE_LOOKUPS

    built, _caches = build_churn_scenario(
        n_caches=4, n_keys=3, n_rounds=5, cache_ways=8
    )
    world, own, init_ev, spec = built
    assert CACHE_REGISTRY.n_counters == mon.N_COUNTERS + 2
    _ow, oc, _otrace = run_sequential(world, own, init_ev, spec)
    st_b, st_s = run_cache_pair(built)
    for st_x in (st_b, st_s):
        c = np.asarray(st_x.counters)[0]
        assert c.shape[0] == CACHE_REGISTRY.n_counters
        assert c[C_CACHE_LOOKUPS] == 4 * 5  # one lookup per round
        assert c[C_CACHE_FILLS] == 4 * 3  # one fill per cold miss
    oc = np.asarray(oc)
    assert oc[C_CACHE_LOOKUPS] == 20 and oc[C_CACHE_FILLS] == 12


# ---------------------------------------------------------------------------
# Payload dtype views: int columns survive the float32 lanes bit-exact
# ---------------------------------------------------------------------------


def test_payload_dtype_views_declaration():
    p = PayloadSpec(("token", 0, jnp.int32), "size", ("lp", -1))
    assert p.dtypes["token"] == jnp.dtype(jnp.int32)
    assert p.dtypes["size"] == jnp.dtype(jnp.float32)
    big = (1 << 31) - 1
    row = p.pack(token=big, size=2.5)
    assert row.dtype == np.float32
    # bit-exact decode from the packed float lanes (host + traced)
    assert int(np.asarray(p.get(jnp.asarray(row), "token"))) == big
    np.testing.assert_allclose(np.asarray(p.get(jnp.asarray(row), "size")), 2.5)
    row_j = p.pack_jax(token=jnp.int32(-123456789), size=1.0)
    assert row_j.shape == (ev.PAYLOAD,)
    assert int(np.asarray(p.get(row_j, "token"))) == -123456789


def test_31bit_int_payload_survives_engine_and_oracle():
    """The PR 5 acceptance test for dtype views: a 31-bit id — whose bit
    pattern is a float32 NaN — rides an event payload through the builder,
    the batched engine, routing, and the heapq oracle without losing a bit.
    (Numerically, float32 would round any int above 2^24.)"""
    reg = BUILTIN.extend()
    reg.component(
        "idsink",
        fields=dict(
            sink_token=FieldSpec((), jnp.int32, mutable=True),
            sink_n=FieldSpec((), jnp.int32, mutable=True),
        ),
    )
    payload = PayloadSpec(("token", 0, jnp.int32), "weight")
    put = reg.kind("TOKEN_PUT", table="idsink", payload=payload)

    @reg.on(put)
    def h_token_put(env, world, counters, e):
        s = world.lp_res[e.dst]
        delta = env.delta(
            world,
            "idsink",
            s,
            sink_token=payload.get(e.payload, "token"),
            sink_n=world.sink_n[s] + 1,
        )
        return delta, counters, hd.no_emits()

    class B(ScenarioBuilderBase):
        _registry = reg

    tokens = [(1 << 31) - 1, 0x7F800001, 16777217, -5]
    b = B()
    sinks = [b.add_component("idsink") for _ in tokens]
    for lp, tok in zip(sinks, tokens):
        b.add_event(
            time=1 + lp,
            kind=put,
            src=lp,
            dst=lp,
            payload=payload.pack(token=tok, weight=1.0),
        )
    world, own, init_ev, spec = b.build(
        n_agents=2, lookahead=1, t_end=50, pool_cap=64
    )
    ow, _oc, otrace = run_sequential(world, own, init_ev, spec)
    st = Engine(world, own, init_ev, spec, trace_cap=64).run_local()
    w = jax.tree.map(lambda x: np.asarray(x[0]), st.world)
    np.testing.assert_array_equal(w.sink_token, tokens)
    np.testing.assert_array_equal(np.asarray(ow.sink_token), tokens)
    np.testing.assert_array_equal(w.sink_n, 1)
    trace = merged_engine_trace(np.asarray(st.trace), np.asarray(st.trace_n))
    assert trace == otrace


# ---------------------------------------------------------------------------
# Registry-generated dispatch == oracle / sequential path on seed scenarios
# ---------------------------------------------------------------------------


def check_registry_dispatch_matches_reference(p):
    """Property body: the generated dispatch table (batched + sequential)
    reproduces the heapq oracle's trace and final world bytes."""
    b, kw = t0t1_builder(
        wan_bw=p["bw"],
        n_flows=p["count"],
        interval=p["interval"],
        lookahead=p["lookahead"],
    )
    kw = {**kw, "exec_cap": p["exec_cap"]}
    world, own, init_ev, spec = b.build(n_agents=p["n_agents"], **kw)
    _ow, _oc, otrace = run_sequential(world, own, init_ev, spec)
    st_b, st_s = run_pair(world, own, init_ev, spec)
    assert engine_trace(st_b) == otrace
    assert_states_identical(st_b, st_s)


def test_registry_dispatch_matches_reference_fixed():
    check_registry_dispatch_matches_reference(
        dict(bw=2.0, count=12, interval=25, lookahead=2, n_agents=1, exec_cap=256)
    )
    check_registry_dispatch_matches_reference(
        dict(bw=0.5, count=8, interval=9, lookahead=1, n_agents=2, exec_cap=7)
    )


# ---------------------------------------------------------------------------
# The cache component: defined entirely outside core
# ---------------------------------------------------------------------------


def test_cache_registry_extends_builtin_without_touching_it():
    assert "cache" in CACHE_REGISTRY.components
    assert "cache" not in BUILTIN.components  # core untouched
    assert CACHE_REGISTRY.n_kinds == BUILTIN.n_kinds + 2
    assert CACHE_REGISTRY.kind_table[: BUILTIN.n_kinds] == BUILTIN.kind_table
    # the generated World grows the cache table after the builtin fields
    wf = CACHE_REGISTRY.world_struct()._fields
    assert wf[: len(World._fields)] == World._fields
    assert "cache_keys" in wf and "cache_keys" not in World._fields


def run_cache_pair(built, trace_cap=4096, max_windows=20000):
    world, own, init_ev, spec = built
    eng_b = Engine(world, own, init_ev, spec, trace_cap=trace_cap)
    st_b = eng_b.run_local(max_windows=max_windows)
    spec_s = dataclasses.replace(spec, batched_dispatch=False)
    eng_s = Engine(world, own, init_ev, spec_s, trace_cap=trace_cap)
    st_s = eng_s.run_local(max_windows=max_windows)
    return st_b, st_s


def test_cache_matches_oracle_and_counts_hits():
    built, caches = build_churn_scenario(
        n_caches=4,
        n_keys=3,
        n_rounds=5,
        cache_ways=8,
    )
    world, own, init_ev, spec = built
    _ow, _oc, otrace = run_sequential(world, own, init_ev, spec)
    st_b, st_s = run_cache_pair(built)
    assert engine_trace(st_b) == otrace
    assert_states_identical(st_b, st_s)
    c = np.asarray(st_b.counters)[0]
    assert c[mon.C_BATCH_FALLBACK] == 0  # distinct rows batch clean
    w = jax.tree.map(lambda x: np.asarray(x[0]), st_b.world)
    # keys cycle 0,1,2,0,1 -> 3 cold misses then 2 hits per cache
    np.testing.assert_array_equal(w.cache_miss[:4], 3)
    np.testing.assert_array_equal(w.cache_hits[:4], 2)


def test_cache_same_row_lookups_serialize_and_stay_exact():
    """Two same-window lookups on one cache row are a genuine RMW collision:
    the rows-keyed conflict mask must route them through the sequential
    fallback, and the result still matches the oracle byte-for-byte."""
    b = CacheScenarioBuilder(cache_ways=4, max_cpu=1)
    sink = b.add_idle_lp()
    cache = b.add_cache(cache_hit_lat=1, cache_miss_lat=4)
    for k in (7, 7, 9):
        payload = CACHE_LOOKUP.pack(key=k, size=1.0)
        b.add_event(time=1, kind=CACHE_LOOKUP, src=sink, dst=cache, payload=payload)
    built = b.build(n_agents=1, lookahead=2, t_end=60, pool_cap=64)
    world, own, init_ev, spec = built
    _ow, _oc, otrace = run_sequential(world, own, init_ev, spec)
    st_b, st_s = run_cache_pair(built)
    c = np.asarray(st_b.counters)[0]
    assert c[mon.C_BATCH_FALLBACK] >= 3
    assert engine_trace(st_b) == otrace
    assert_states_identical(st_b, st_s)
    w = jax.tree.map(lambda x: np.asarray(x[0]), st_b.world)
    # dup-key fills are idempotent: key 7 cached once
    assert int(np.sum(w.cache_keys[0] == 7)) == 1


def test_cache_multi_agent_owner_wins_sync():
    """The generated sync plan covers the extension fields (incl. the -1
    fill shift for cache_keys) — a 2-agent run stays oracle-exact."""
    built, _caches = build_churn_scenario(
        n_caches=5,
        n_keys=2,
        n_rounds=4,
        n_agents=2,
    )
    world, own, init_ev, spec = built
    ow, _oc, otrace = run_sequential(world, own, init_ev, spec)
    st_b, st_s = run_cache_pair(built)
    assert engine_trace(st_b) == otrace
    assert_states_identical(st_b, st_s)
    w = jax.tree.map(lambda x: np.asarray(x[0]), st_b.world)
    np.testing.assert_array_equal(np.asarray(ow.cache_keys), w.cache_keys)


# ---------------------------------------------------------------------------
# Trace-buffer overflow: counted + loud
# ---------------------------------------------------------------------------


def test_trace_overflow_is_counted_and_fails_loudly():
    b, kw = t0t1_builder()
    world, own, init_ev, spec = b.build(n_agents=1, **kw)
    st = Engine(world, own, init_ev, spec, trace_cap=8).run_local()
    c = np.asarray(st.counters)[0]
    n_lost = int(c[mon.C_EVENTS]) - 8
    assert int(c[mon.C_TRACE_DROP]) == n_lost > 0
    with pytest.raises(RuntimeError, match="trace buffer overflowed"):
        merged_engine_trace(np.asarray(st.trace), np.asarray(st.trace_n))
    # sequential path counts the same drops (non-diagnostic counter)
    spec_s = dataclasses.replace(spec, batched_dispatch=False)
    st_s = Engine(world, own, init_ev, spec_s, trace_cap=8).run_local()
    assert int(np.asarray(st_s.counters)[0, mon.C_TRACE_DROP]) == n_lost


def test_no_trace_drop_when_buffer_covers_the_run(t0t1_oracle):
    _ow, _oc, otrace = t0t1_oracle
    b, kw = t0t1_builder()
    world, own, init_ev, spec = b.build(n_agents=1, **kw)
    st = Engine(world, own, init_ev, spec, trace_cap=4096).run_local()
    assert int(np.asarray(st.counters)[0, mon.C_TRACE_DROP]) == 0
    assert engine_trace(st) == otrace


# ---------------------------------------------------------------------------
# Hypothesis properties
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    seed_params = st.fixed_dictionaries(
        dict(
            bw=st.floats(0.25, 8.0),
            count=st.integers(2, 12),
            interval=st.integers(5, 40),
            lookahead=st.integers(1, 4),
            n_agents=st.sampled_from([1, 2]),
            exec_cap=st.sampled_from([3, 17, 256]),
        )
    )

    @settings(max_examples=6, deadline=None)
    @given(seed_params)
    def test_registry_dispatch_matches_reference_property(p):
        """Registry-generated dispatch == oracle + sequential path on
        randomized seed scenarios (traces, counters, world/pool bytes)."""
        check_registry_dispatch_matches_reference(p)

    cache_params = st.fixed_dictionaries(
        dict(
            n_caches=st.integers(1, 6),
            n_keys=st.integers(1, 6),
            n_rounds=st.integers(1, 6),
            cache_ways=st.sampled_from([2, 4, 8]),
            hit_lat=st.integers(1, 3),
            miss_lat=st.integers(4, 9),
            n_agents=st.sampled_from([1, 2]),
        )
    )

    @settings(max_examples=6, deadline=None)
    @given(cache_params)
    def test_cache_component_matches_oracle_property(p):
        """The outside-core cache component is byte-identical to the heapq
        oracle under batched and sequential dispatch on randomized churn
        scenarios (the PR's acceptance property)."""
        built, _caches = build_churn_scenario(**p)
        world, own, init_ev, spec = built
        _ow, _oc, otrace = run_sequential(world, own, init_ev, spec)
        st_b, st_s = run_cache_pair(built)
        assert engine_trace(st_b) == otrace
        assert_states_identical(st_b, st_s)
