"""Shared subprocess scaffolding for multi-device distributed tests.

The real collective path (``lax.pmin`` / staged ``all_to_all`` under
``shard_map``) needs more than one device, and this container has one CPU; a
fresh interpreter with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
set *before* jax imports is the only way to get an N-device fleet. Every
distributed test therefore ships a small script to a child process and reads
one JSON line back. This module is that plumbing, shared by
``test_distributed.py`` (hypothesis property) and
``test_distributed_scale.py`` (deterministic scenarios) so each test is just
its body.

``HEADER`` gives child scripts a common prelude: the forced device count, the
usual imports, and ``t0t1_build`` — the two-regional-centers + WAN scenario
(paper fig 1) every oracle-equivalence test runs, parameterized enough to
reach the interesting regimes (agent counts not divisible by the device
count, mixed generators, spill-inducing pool caps).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

N_DEVICES = 4

HEADER = """\
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
import json
import numpy as np
import jax
from jax.sharding import Mesh
from repro.core import Engine, ScenarioBuilder, events as ev, \\
    merged_engine_trace, run_sequential
from repro.core import monitoring as mon
from repro.core.policy import ExecPolicy
from repro.checkpoint import SimCheckpointer

N_DEVICES = {n}


def t0t1_build(n_agents, *, pool_cap=256, n_flows=12, interval=25,
               flow_mb=40.0, lookahead=2, t_end=5000, second_gen=False,
               exec_policy=None, exec_cap=None, fused_select=False):
    b = ScenarioBuilder(max_cpu=4, queue_cap=8, max_link=4, max_flow=16)
    t0 = b.add_regional_center(n_cpu=2, cpu_power=10.0, disk=500.0,
                               tape=5000.0, tape_rate=5.0)
    t1 = b.add_regional_center(n_cpu=2, cpu_power=8.0, disk=300.0,
                               tape=3000.0, tape_rate=5.0)
    wan = b.add_net_region(link_bws=[2.0, 2.0], link_lats=[5, 5])
    b.add_generator(target_lp=wan, kind=ev.K_FLOW_START,
                    payload=[flow_mb, 0, -1, -1, t1["farm"], ev.K_JOB_SUBMIT,
                             t1["storage"], ev.K_DATA_WRITE],
                    interval=interval, count=n_flows, start=0)
    if second_gen:
        b.add_generator(target_lp=wan, kind=ev.K_FLOW_START,
                        payload=[flow_mb / 2, 1, -1, -1, t0["farm"],
                                 ev.K_JOB_SUBMIT, t0["storage"],
                                 ev.K_DATA_WRITE],
                        interval=max(interval - 8, 3), count=n_flows, start=3)
    kw = dict(n_agents=n_agents, lookahead=lookahead, t_end=t_end,
              pool_cap=pool_cap, work_per_mb=2.0, fused_select=fused_select)
    if exec_policy is not None:
        kw["exec_policy"] = exec_policy
    if exec_cap is not None:
        kw["exec_cap"] = exec_cap
    return b.build(**kw)


def oracle_trace(**build_kw):
    w, o, e, s = t0t1_build(1, **build_kw)
    _, _, trace = run_sequential(w, o, e, s)
    return trace


def engine_trace(st):
    return merged_engine_trace(np.asarray(st.trace), np.asarray(st.trace_n))


def tree_eq(a, b):
    return bool(jax.tree.all(jax.tree.map(
        lambda x, y: bool((np.asarray(x) == np.asarray(y)).all()), a, b)))
"""


def run_distributed_child(
    body: str, n_devices: int = N_DEVICES, timeout: int = 600
) -> dict:
    """Run ``HEADER + body`` in a fresh interpreter with an n-device fleet.

    The body must ``print(json.dumps({...}))`` as its last stdout line; that
    object is returned. Any nonzero exit fails the calling test with the
    child's stderr tail attached.
    """
    code = HEADER.format(n=n_devices) + "\n" + body
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def run_killed_child(
    body: str, n_devices: int = N_DEVICES, timeout: int = 600
) -> subprocess.CompletedProcess:
    """Run a child that is *expected to die by SIGKILL* (kill-and-resume
    harness): same plumbing as :func:`run_distributed_child`, but the raw
    CompletedProcess comes back instead of parsed JSON — the caller asserts
    ``returncode == -signal.SIGKILL`` and then resumes from whatever the
    child checkpointed before it was killed."""
    code = HEADER.format(n=n_devices) + "\n" + body
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    return subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )
