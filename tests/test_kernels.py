"""Per-kernel allclose sweeps against the ref.py oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.network import incidence, maxmin_rates as mm_ref
from repro.kernels import ops, ref
from repro.kernels.event_select import (select_events as select_raw,
                                        sort_events as sort_raw)
from repro.kernels.flash_attention import flash_attention as fa_raw
from repro.models.linear_rnn import gla_ref


@pytest.mark.parametrize("bh,bkv,sq,skv,d,causal,win", [
    (4, 2, 128, 128, 64, True, 0),
    (8, 8, 256, 256, 32, True, 64),
    (2, 1, 128, 256, 128, False, 0),
    (6, 3, 64, 64, 16, True, 0),
    (2, 2, 512, 512, 64, True, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(bh, bkv, sq, skv, d, causal, win, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (bh, sq, d), dtype)
    k = jax.random.normal(ks[1], (bkv, skv, d), dtype)
    v = jax.random.normal(ks[2], (bkv, skv, d), dtype)
    out = fa_raw(q, k, v, causal=causal, window=win, block_q=64, block_k=64,
                 interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=win)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("bh,s,dk,dv,chunk", [
    (4, 128, 16, 32, 32),
    (2, 256, 64, 64, 64),
    (6, 64, 8, 8, 16),
])
@pytest.mark.parametrize("mode", ["k", "v"])
def test_gla_kernels_sweep(bh, s, dk, dv, chunk, mode):
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    q = jax.random.normal(ks[0], (bh, s, dk)) * 0.5
    k = jax.random.normal(ks[1], (bh, s, dk)) * 0.5
    v = jax.random.normal(ks[2], (bh, s, dv)) * 0.5
    dshape = (bh, s, dk) if mode == "k" else (bh, s, dv)
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], dshape) * 0.5 - 1.0))
    u = jax.random.normal(ks[4], (bh, dk)) * 0.3

    if mode == "k":
        out, state = ops.rwkv6_scan(q, k, v, w, u, chunk=chunk)
        bonus = u
    else:
        out, state = ops.ssd_scan(q, k, v, w, chunk=chunk)
        bonus = None
    # oracle uses (b=1, s, h=bh, d) layout
    tr = lambda x: x.swapaxes(0, 1)[None]
    want, wstate = gla_ref(tr(q), tr(k), tr(v), tr(w), bonus=bonus, mode=mode)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(want[0].swapaxes(0, 1)),
                               atol=5e-5, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(wstate[0]),
                               atol=5e-5, rtol=5e-4)


@pytest.mark.parametrize("n,tmax", [(64, 8), (1000, 50), (4096, 3), (513, 10**6)])
def test_event_sort_sweep(n, tmax):
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    tk = jax.random.randint(ks[0], (n,), 0, tmax)
    sq = jax.random.randint(ks[1], (n,), 0, 2**20)
    p1 = np.asarray(sort_raw(tk, sq, interpret=True))
    p2 = np.asarray(ref.sort_events_ref(tk, sq))
    tk, sq = np.asarray(tk), np.asarray(sq)
    # identical key sequences (permutations may differ only on exact ties,
    # which the index tie-break makes impossible)
    np.testing.assert_array_equal(tk[p1], tk[p2])
    np.testing.assert_array_equal(sq[p1], sq[p2])
    assert sorted(p1.tolist()) == list(range(n))


@pytest.mark.parametrize("n,m,tmax", [(64, 16, 8), (513, 64, 10**6),
                                      (1000, 1000, 50), (128, 7, 3),
                                      (256, 1, 5)])
def test_event_select_compaction_sweep(n, m, tmax):
    """select_events == sort prefix, with unsafe slots keyed T_INF as in the
    engine's compacted window."""
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    tk = jax.random.randint(ks[0], (n,), 0, tmax)
    safe = jax.random.bernoulli(ks[1], 0.3, (n,))
    tk = jnp.where(safe, tk, jnp.int32(2**31 - 1))
    sq = jax.random.randint(ks[2], (n,), 0, 2**20)
    got = np.asarray(select_raw(tk, sq, m, interpret=True))
    want = np.asarray(ref.select_events_ref(tk, sq, m))
    assert got.shape == (min(m, n),)
    tk, sq = np.asarray(tk), np.asarray(sq)
    np.testing.assert_array_equal(tk[got], tk[want])
    np.testing.assert_array_equal(sq[got], sq[want])
    assert len(set(got.tolist())) == got.shape[0]   # distinct gather indices


@pytest.mark.parametrize("n,density,seed", [(64, 0.5, 0), (256, 0.9, 1),
                                            (513, 0.2, 2), (1024, 0.0, 3),
                                            (37, 1.0, 4), (1, 1.0, 5)])
def test_group_by_kind_sweep(n, density, seed):
    """Pallas segment-rank grouping == XLA ref == engine default, exactly."""
    from repro.core import events as ev
    from repro.core.engine import group_by_kind_xla
    from repro.kernels.event_select import group_by_kind as group_raw
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    kind = jax.random.randint(ks[0], (n,), 0, ev.N_KINDS)
    active = jax.random.bernoulli(ks[1], density, (n,))
    got = group_raw(kind, active, ev.N_KINDS, interpret=True)
    want = ref.group_by_kind_ref(kind, active, ev.N_KINDS)
    engine_default = group_by_kind_xla(kind, active)
    for g, w, e in zip(got, want, engine_default):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
        np.testing.assert_array_equal(np.asarray(w), np.asarray(e))
    order, rank, counts = (np.asarray(x) for x in got)
    kind, active = np.asarray(kind), np.asarray(active)
    assert sorted(order.tolist()) == list(range(n))   # a permutation
    # active rows grouped first, by ascending kind, stable in position
    grouped = [(kind[i], i) for i in order if active[i]]
    assert grouped == sorted(grouped)
    assert len(grouped) == int(counts.sum())
    for k in range(ev.N_KINDS):
        assert counts[k] == int((active & (kind == k)).sum())
    # rank counts up from 0 within each grouped segment
    keys = np.where(active[order], kind[order], ev.N_KINDS)
    expect_rank = np.zeros(n, np.int32)
    seen: dict = {}
    for j in range(n):
        expect_rank[j] = seen.get(keys[j], 0)
        seen[keys[j]] = expect_rank[j] + 1
    np.testing.assert_array_equal(rank, expect_rank)


def test_group_by_kind_ops_wrapper():
    from repro.core import events as ev
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    kind = jax.random.randint(ks[0], (128,), 0, ev.N_KINDS)
    active = jax.random.bernoulli(ks[1], 0.6, (128,))
    got = ops.group_by_kind(kind, active, n_kinds=ev.N_KINDS)
    want = ref.group_by_kind_ref(kind, active, ev.N_KINDS)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("f,l,seed", [(8, 2, 0), (24, 6, 1), (48, 8, 2),
                                      (16, 1, 3)])
def test_waterfill_sweep(f, l, seed):
    rng = np.random.RandomState(seed)
    routes = rng.randint(-1, l, size=(f, 3)).astype(np.int32)
    routes[:, 0] = rng.randint(0, l, size=f)
    inc = incidence(jnp.asarray(routes), l)
    bw = jnp.asarray((rng.rand(l) * 10 + 0.1).astype(np.float32))
    act = jnp.asarray(rng.rand(f) > 0.3)
    got = ops.maxmin_rates(inc, bw, act)
    want = mm_ref(inc, bw, act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_flash_attention_matches_model_path():
    """kernel == the XLA chunked-attention path used by the model zoo."""
    from repro.models.layers import _chunked_attention
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    b, s, h, kv, hd = 2, 128, 4, 2, 32
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    xla = _chunked_attention(q, k, v, causal=True, window=0, q_offset=0,
                             kv_len_valid=jnp.int32(s), chunk_q=64, chunk_kv=64)
    # kernel layout: (BH, s, d) with GQA via BH//BKV
    qk = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kk = k.transpose(0, 2, 1, 3).reshape(b * kv, s, hd)
    vv = v.transpose(0, 2, 1, 3).reshape(b * kv, s, hd)
    ker = fa_raw(qk, kk, vv, causal=True, block_q=64, block_k=64,
                 interpret=True)
    ker = ker.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(xla), np.asarray(ker), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("n,n_buckets,density,seed", [
    (64, 4, 0.5, 0), (256, 8, 0.9, 1), (513, 3, 0.2, 2), (1024, 16, 0.0, 3),
    (37, 1, 1.0, 4), (1, 2, 1.0, 5), (128, 128, 0.7, 6),
])
def test_route_rank_sweep(n, n_buckets, density, seed):
    """Pallas predecessor-count ranks == XLA ref == engine default == the
    sequential numpy count, exactly (the emit-routing pack of the engine's
    all_to_all exchange and the migration re-home)."""
    from repro.core.engine import route_rank_xla
    from repro.kernels.event_select import route_rank as route_raw
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    dst = jax.random.randint(ks[0], (n,), 0, n_buckets).astype(jnp.int32)
    # invalid rows route to the drop bucket (== n_buckets), as in the engine
    valid = jax.random.bernoulli(ks[1], density, (n,))
    dst = jnp.where(valid, dst, jnp.int32(n_buckets))
    got = np.asarray(route_raw(dst, interpret=True))
    want = np.asarray(ref.route_rank_ref(dst))
    engine_default = np.asarray(route_rank_xla(dst))
    d = np.asarray(dst)
    seen: dict = {}
    expect = np.zeros(n, np.int32)
    for i in range(n):
        expect[i] = seen.get(d[i], 0)
        seen[d[i]] = expect[i] + 1
    np.testing.assert_array_equal(got, expect)
    np.testing.assert_array_equal(want, expect)
    np.testing.assert_array_equal(engine_default, expect)


def test_route_rank_ops_wrapper():
    from repro.kernels.ref import route_rank_ref
    dst = jax.random.randint(jax.random.PRNGKey(9), (200,), 0, 7)
    dst = dst.astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(ops.route_rank(dst)),
                                  np.asarray(route_rank_ref(dst)))


@pytest.mark.parametrize("n,density,seed", [
    (64, 0.5, 0), (256, 0.9, 1), (513, 0.2, 2), (1024, 0.0, 3),
    (37, 1.0, 4), (1, 1.0, 5), (128, 0.03, 6),
])
def test_trace_rank_sweep(n, density, seed):
    """Pallas prefix-sum trace ranks == XLA ref == the sequential numpy
    exclusive count, exactly (the trace-ring append position math of the
    streaming drain)."""
    from repro.kernels.event_select import trace_rank as trace_raw
    mask = jax.random.bernoulli(jax.random.PRNGKey(seed), density, (n,))
    got = np.asarray(trace_raw(mask, interpret=True))
    want = np.asarray(ref.trace_rank_ref(mask))
    m = np.asarray(mask)
    expect = np.cumsum(m.astype(np.int32)) - m.astype(np.int32)
    np.testing.assert_array_equal(got, expect)
    np.testing.assert_array_equal(want, expect)


def test_trace_rank_ops_wrapper():
    from repro.kernels.ref import trace_rank_ref
    mask = jax.random.bernoulli(jax.random.PRNGKey(11), 0.6, (200,))
    np.testing.assert_array_equal(np.asarray(ops.trace_rank(mask)),
                                  np.asarray(trace_rank_ref(mask)))


def _fused_inputs(cap, density, tail, seed, n_tables=4, n_res=8):
    """A randomized (pool_cap,) event pool for the fused front-end: time_key
    carries T_INF on unsafe slots exactly as the engine's compacted window
    does, and the conflict key columns are pool-wide gathers."""
    from repro.core import events as ev
    ks = jax.random.split(jax.random.PRNGKey(seed), 12)
    valid = jax.random.bernoulli(ks[0], 0.8, (cap,))
    safe = valid & jax.random.bernoulli(ks[1], density, (cap,))
    tk = jax.random.randint(ks[2], (cap,), 0, 50)
    tk = jnp.where(safe, tk, jnp.int32(2**31 - 1))
    return dict(
        time_key=tk,
        seq=jax.random.randint(ks[3], (cap,), 0, 2**20),
        safe=safe,
        time=jax.random.randint(ks[4], (cap,), 0, 50),
        kind=jax.random.randint(ks[5], (cap,), 0, ev.N_KINDS),
        src=jax.random.randint(ks[6], (cap,), 0, 16),
        dst=jax.random.randint(ks[7], (cap,), 0, 16),
        ctx=jax.random.randint(ks[8], (cap,), 0, 100),
        payload=jax.random.normal(ks[9], (cap, ev.PAYLOAD)),
        valid=valid,
        table_id=jax.random.randint(ks[10], (cap,), 0, n_tables),
        res=jax.random.randint(ks[11], (cap,), 0, n_res),
        free_tail=jnp.int32(tail))


def _assert_fused_equal(got, want):
    """All FusedSelect fields byte-equal (rel_pos only where exec_safe — the
    engine's release scatter drops unsafe rows either way)."""
    es = np.asarray(want.exec_safe)
    for name in got._fields:
        g, w = np.asarray(getattr(got, name)), np.asarray(getattr(want, name))
        if name == "rel_pos":
            g, w = g[es], w[es]
        np.testing.assert_array_equal(g, w, err_msg=name)


@pytest.mark.parametrize("cap,xcap,density,tail,seed", [
    (64, 16, 0.5, 0, 0),       # basic dense-ish window
    (37, 64, 0.7, 30, 1),      # non-pow2 pool, exec_cap > pool_cap
    (256, 256, 0.9, 250, 2),   # exec_cap == pool_cap, ring cursor wraps
    (128, 1, 0.4, 0, 3),       # single-lane window
    (512, 64, 1.0, 500, 4),    # all slots safe, ring cursor wraps
    (128, 32, 0.0, 5, 5),      # no safe slots (empty window / spill shape)
])
def test_fused_select_sweep(cap, xcap, density, tail, seed):
    """The superstep megakernel == the XLA-stitched engine twin == the ref
    oracle on every FusedSelect field, exactly — over non-pow2 pools,
    ring-wrap cursors, all-safe and none-safe windows."""
    from repro.core.engine import fused_select_xla
    from repro.kernels.event_select import fused_select as fused_raw
    from repro.core import events as ev
    inp = _fused_inputs(cap, density, tail, seed)
    kw = dict(n_kinds=ev.N_KINDS, n_res=8, n_tables=4)
    got = fused_raw(*inp.values(), xcap, **kw, interpret=True)
    want = ref.fused_select_ref(*inp.values(), xcap, **kw)
    stitched = fused_select_xla(*inp.values(), xcap, **kw)
    _assert_fused_equal(got, want)
    _assert_fused_equal(stitched, want)
    # window shape + selection sanity
    m = max(min(xcap, cap), 1)
    assert got.exec_idx.shape == (m,)
    idx = np.asarray(got.exec_idx)
    assert len(set(idx.tolist())) == m          # distinct gather slots
    assert (idx >= 0).all() and (idx < cap).all()
    assert int(np.asarray(got.exec_safe).sum()) <= int(np.asarray(
        inp["safe"]).sum())


def test_fused_select_ops_wrapper():
    """The jitted ops dispatch returns the same FusedSelect as the raw
    interpret call (CPU resolves to interpret=True either way)."""
    from repro.kernels.event_select import fused_select as fused_raw
    from repro.core import events as ev
    inp = _fused_inputs(96, 0.6, 90, 13)
    kw = dict(n_kinds=ev.N_KINDS, n_res=8, n_tables=4)
    got = ops.fused_select(*inp.values(), 32, **kw)
    want = fused_raw(*inp.values(), 32, **kw, interpret=True)
    _assert_fused_equal(got, want)
