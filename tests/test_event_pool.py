"""Event-pool lifecycle subsystem (PR 5): free-list ring vs the scan reference.

Unit coverage for the ring invariants (insert/release round trips, FIFO slot
reuse, overflow accounting, wrap detection, canonical rebuild) plus the
engine-level equivalence the tentpole claims: the ring fast path and the
retained ``insert_ref`` scan path are *semantically identical* — same traces,
same counters (modulo the ring-wrap diagnostic), same world bytes, same live
pool events — and both match the sequential oracle. The hypothesis property
``ring == ref == oracle`` runs under the CI jax matrix.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import t0t1_builder
from repro.core import Engine, events as ev, merged_engine_trace, run_sequential
from repro.core import monitoring as mon

NON_RING = [i for i in range(mon.N_COUNTERS) if i not in mon.POOL_DIAG_COUNTERS]


def rows(n, t0=5, seq0=0):
    return [dict(time=t0 + i, seq=seq0 + i, kind=0, src=0, dst=0) for i in range(n)]


def live_events(pool):
    """The pool's live (time, seq) content, layout-independent."""
    p = jax.tree.map(np.asarray, pool)
    out = [
        (int(p.time[i]), int(p.seq[i]), int(p.kind[i]), int(p.dst[i]))
        for i in range(p.valid.shape[0])
        if p.valid[i]
    ]
    return sorted(out)


def check_ring_invariant(pool):
    """Ring positions head..head+count-1 hold exactly the free slot ids."""
    p = jax.tree.map(np.asarray, pool)
    cap = p.valid.shape[0]
    count = int(p.free_count)
    assert count == cap - int(p.valid.sum())
    idx = (int(p.free_head) + np.arange(count)) % cap
    listed = sorted(int(s) for s in p.free_ring[idx])
    assert listed == sorted(np.where(~p.valid)[0].tolist())
    assert int(p.free_tail) == (int(p.free_head) + count) % cap


# ---------------------------------------------------------------- unit: ring


def test_insert_assigns_ring_slots_and_counts_drops():
    pool = ev.empty_pool(8)
    pool, d = ev.insert(pool, ev.batch_from_rows(rows(3)))
    assert int(d) == 0
    check_ring_invariant(pool)
    np.testing.assert_array_equal(np.asarray(pool.valid), [1, 1, 1, 0, 0, 0, 0, 0])
    # overflow: 8 more into 5 free slots -> 3 counted drops
    pool, d = ev.insert(pool, ev.batch_from_rows(rows(8, t0=50, seq0=10)))
    assert int(d) == 3
    assert int(pool.free_count) == 0
    check_ring_invariant(pool)


def test_release_reuses_slots_fifo():
    pool, _ = ev.insert(ev.empty_pool(8), ev.batch_from_rows(rows(8)))
    slots = jnp.asarray([2, 5, 0], jnp.int32)
    pool = ev.release(pool, slots, jnp.asarray([True, True, True]))
    check_ring_invariant(pool)
    assert int(ev.occupancy(pool)) == 5
    # next inserts take the released slots in release order: 2, 5, 0
    pool1, _ = ev.insert(pool, ev.batch_from_rows(rows(1, t0=90, seq0=90)))
    new_slot = np.where(np.asarray(pool1.valid) & ~np.asarray(pool.valid))[0]
    assert new_slot.tolist() == [2]
    pool3, _ = ev.insert(pool, ev.batch_from_rows(rows(3, t0=90, seq0=90)))
    check_ring_invariant(pool3)
    assert np.asarray(pool3.valid).all()


def test_release_mask_skips_rows():
    pool, _ = ev.insert(ev.empty_pool(8), ev.batch_from_rows(rows(4)))
    slots = jnp.asarray([1, 3], jnp.int32)
    pool = ev.release(pool, slots, jnp.asarray([True, False]))
    check_ring_invariant(pool)
    np.testing.assert_array_equal(np.asarray(pool.valid), [1, 0, 1, 1, 0, 0, 0, 0])


def test_ring_wrap_and_full_cycle():
    """Churn a tiny pool far past cap: cursors wrap, invariant holds."""
    pool, _ = ev.insert(ev.empty_pool(4), ev.batch_from_rows(rows(3)))
    for i in range(7):
        live = np.where(np.asarray(pool.valid))[0]
        first = jnp.asarray(live[:1].astype(np.int32))
        pool = ev.release(pool, first, jnp.asarray([True]))
        batch = ev.batch_from_rows(rows(1, t0=100 + i, seq0=100 + i))
        pool, d = ev.insert(pool, batch)
        assert int(d) == 0
        check_ring_invariant(pool)
    assert int(ev.occupancy(pool)) == 3


def test_rebuild_and_pop_mask_canonicalize():
    pool, _ = ev.insert(ev.empty_pool(8), ev.batch_from_rows(rows(6)))
    pool = ev.pop_mask(pool, jnp.asarray([1, 0, 1, 0, 1, 0, 0, 0], bool))
    check_ring_invariant(pool)
    assert int(pool.free_head) == 0  # canonical layout
    # canonical order: freed slots ascending first
    n_free = int(pool.free_count)
    ring = np.asarray(pool.free_ring)[:n_free]
    assert ring.tolist() == sorted(ring.tolist())


def test_insert_ref_semantics_match_ring():
    """Same events kept/dropped, same drop counts — only layout may differ."""
    pool_a, _ = ev.insert(ev.empty_pool(8), ev.batch_from_rows(rows(5)))
    pool_b, _ = ev.insert_ref(ev.empty_pool(8), ev.batch_from_rows(rows(5)))
    batch = ev.batch_from_rows(rows(6, t0=40, seq0=40))
    out_a, d_a = ev.insert(pool_a, batch)
    out_b, d_b = ev.insert_ref(pool_b, batch)
    assert int(d_a) == int(d_b) == 3
    assert live_events(out_a) == live_events(out_b)
    assert int(out_a.free_count) == int(out_b.free_count) == 0


def test_occupancy_is_exact_on_every_path():
    pool = ev.empty_pool(8)
    assert int(ev.occupancy(pool)) == 0
    pool, _ = ev.insert_ref(pool, ev.batch_from_rows(rows(5)))
    assert int(ev.occupancy(pool)) == 5
    pool = ev.pop_mask_ref(pool, jnp.asarray([1, 1, 0, 0, 0, 0, 0, 0], bool))
    assert int(ev.occupancy(pool)) == 3


# -------------------------------------------------------- ring_slots kernel


def test_ring_slots_kernel_matches_reference():
    from repro.kernels import ops
    from repro.kernels.ref import ring_slots_ref

    rng = np.random.RandomState(7)
    for cap, n in [(64, 16), (128, 128), (1024, 100), (4096, 512)]:
        ring = jnp.asarray(rng.permutation(cap).astype(np.int32))
        head = jnp.int32(rng.randint(0, cap))
        want = jnp.asarray(rng.rand(n) < 0.6)
        got = np.asarray(ops.ring_slots(ring, head, want))
        ref = np.asarray(ring_slots_ref(ring, head, want))
        m = np.asarray(want)
        np.testing.assert_array_equal(got[m], ref[m])


def test_insert_with_kernel_slot_fn_is_identical():
    from repro.kernels import ops

    pool, _ = ev.insert(ev.empty_pool(64), ev.batch_from_rows(rows(20)))
    seven = jnp.arange(7, dtype=jnp.int32)
    pool = ev.release(pool, seven, jnp.ones((7,), bool))
    batch = ev.batch_from_rows(rows(12, t0=70, seq0=70))
    a, da = ev.insert(pool, batch)
    b, db = ev.insert(pool, batch, slot_fn=ops.ring_slots)
    assert int(da) == int(db)
    for name, x, y in zip(a._fields, a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), err_msg=name)


# --------------------------------------------- engine: ring == ref == oracle


def run_modes(world, own, init_ev, spec, max_windows=20000):
    eng_ring = Engine(world, own, init_ev, spec, trace_cap=4096)
    st_ring = eng_ring.run_local(max_windows=max_windows)
    spec_ref = dataclasses.replace(spec, insert_mode="ref")
    eng_ref = Engine(world, own, init_ev, spec_ref, trace_cap=4096)
    st_ref = eng_ref.run_local(max_windows=max_windows)
    return st_ring, st_ref


def assert_ring_ref_oracle(built):
    world, own, init_ev, spec = built
    _ow, _oc, otrace = run_sequential(world, own, init_ev, spec)
    st_ring, st_ref = run_modes(world, own, init_ev, spec)
    tr_ring = merged_engine_trace(
        np.asarray(st_ring.trace), np.asarray(st_ring.trace_n)
    )
    tr_ref = merged_engine_trace(np.asarray(st_ref.trace), np.asarray(st_ref.trace_n))
    assert tr_ring == tr_ref == otrace
    # world bytes + windows identical; counters identical modulo ring diag
    for name, a, b in zip(st_ring.world._fields, st_ring.world, st_ref.world):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)
    np.testing.assert_array_equal(
        np.asarray(st_ring.windows), np.asarray(st_ref.windows)
    )
    np.testing.assert_array_equal(
        np.asarray(st_ring.counters)[:, NON_RING],
        np.asarray(st_ref.counters)[:, NON_RING],
    )
    # live pool content equal event-by-event (layout may differ)
    n_agents = np.asarray(st_ring.pool.valid).shape[0]
    for a in range(n_agents):
        ring_live = live_events(jax.tree.map(lambda x: x[a], st_ring.pool))
        ref_live = live_events(jax.tree.map(lambda x: x[a], st_ref.pool))
        assert ring_live == ref_live


@pytest.mark.parametrize("n_agents", [1, 2])
def test_ring_matches_ref_and_oracle_t0t1(n_agents):
    b, kw = t0t1_builder()
    assert_ring_ref_oracle(b.build(n_agents=n_agents, **kw))


def test_ring_under_spill_and_tiny_pool():
    """Heavy churn in a small pool: slots recycle constantly, wraps occur."""
    b, kw = t0t1_builder(n_flows=8, interval=9)
    kw.update(pool_cap=32, exec_cap=2)
    built = b.build(n_agents=1, **kw)
    assert_ring_ref_oracle(built)
    world, own, init_ev, spec = built
    st = Engine(world, own, init_ev, spec, trace_cap=4096).run_local()
    assert int(np.asarray(st.counters)[0, mon.C_RING_WRAP]) > 0


def test_gauges_track_pool_levels():
    b, kw = t0t1_builder()
    world, own, init_ev, spec = b.build(n_agents=1, **kw)
    eng = Engine(world, own, init_ev, spec)
    st = eng.init_state()
    st = eng.step_local(st)
    c = np.asarray(st.counters)[0]
    occ = int(np.asarray(st.pool.valid[0]).sum())
    assert c[mon.C_POOL_OCC] == occ
    assert c[mon.C_POOL_FREE] == spec.pool_cap - occ


# ------------------------------------------------------ hypothesis property

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    pool_params = st.fixed_dictionaries(
        dict(
            bw=st.floats(0.25, 8.0),
            count=st.integers(2, 12),
            interval=st.integers(5, 40),
            lookahead=st.integers(1, 4),
            n_agents=st.sampled_from([1, 2]),
            exec_cap=st.sampled_from([3, 17, 256]),
            pool_cap=st.sampled_from([48, 256]),
        )
    )

    @settings(max_examples=6, deadline=None)
    @given(pool_params)
    def test_ring_ref_oracle_property(p):
        """The PR 5 acceptance property: ring == ref == oracle on randomized
        seed scenarios (traces, counters, world bytes, live pool content)."""
        b, kw = t0t1_builder(
            wan_bw=p["bw"],
            n_flows=p["count"],
            interval=p["interval"],
            lookahead=p["lookahead"],
        )
        kw.update(exec_cap=p["exec_cap"], pool_cap=p["pool_cap"])
        assert_ring_ref_oracle(b.build(n_agents=p["n_agents"], **kw))


# ----------------------------------------------- donor-side migration pops


def test_extract_masks_routable_rows():
    """extract() is the donor half of migration: valid exactly where live
    and masked, all slot data passed through untouched."""
    pool, _ = ev.insert(ev.empty_pool(8), ev.batch_from_rows(rows(5)))
    mask = jnp.asarray([1, 0, 1, 0, 1, 1, 1, 1], bool)
    batch = ev.extract(pool, mask)
    np.testing.assert_array_equal(
        np.asarray(batch.valid), np.asarray(pool.valid & mask)
    )
    np.testing.assert_array_equal(np.asarray(batch.time), np.asarray(pool.time))
    np.testing.assert_array_equal(np.asarray(batch.seq), np.asarray(pool.seq))


def test_pop_mask_after_ring_wraparound():
    """Donor-side pop on a wrapped ring: pop_mask's rebuild canonicalizes the
    lifecycle state, so post-migration inserts land exactly like inserts into
    a freshly built pool with the same live events."""
    # churn a small pool until the ring cursors wrap
    pool, _ = ev.insert(ev.empty_pool(8), ev.batch_from_rows(rows(6)))
    for i in range(9):
        live = np.where(np.asarray(pool.valid))[0]
        first = jnp.asarray(live[:1].astype(np.int32))
        pool = ev.release(pool, first, jnp.asarray([True]))
        pool, d = ev.insert(
            pool, ev.batch_from_rows(rows(1, t0=100 + i, seq0=100 + i))
        )
        assert int(d) == 0
    assert int(pool.free_head) != 0  # the ring really wrapped
    # donor pop: ship out half the live slots
    keep = np.asarray(pool.valid).copy()
    keep[np.where(keep)[0][::2]] = False
    moving = jnp.asarray(~keep & np.asarray(pool.valid))
    popped = ev.pop_mask(pool, moving)
    check_ring_invariant(popped)
    assert int(popped.free_head) == 0  # canonical after rebuild
    # survivors are exactly the unmoved live events
    m = np.asarray(moving)
    p = jax.tree.map(np.asarray, pool)
    kept = sorted(
        (int(p.time[i]), int(p.seq[i]), int(p.kind[i]), int(p.dst[i]))
        for i in np.where(np.asarray(pool.valid) & ~m)[0]
    )
    assert live_events(popped) == kept
    # canonical ring == ascending free slots: the ring fast path now takes
    # exactly the slots the reference rank scan would
    batch = ev.batch_from_rows(rows(3, t0=500, seq0=500))
    out_a, d_a = ev.insert(popped, batch)
    out_b, d_b = ev.insert_ref(popped, batch)
    assert int(d_a) == int(d_b) == 0
    np.testing.assert_array_equal(np.asarray(out_a.valid), np.asarray(out_b.valid))
    assert live_events(out_a) == live_events(out_b)


def test_pop_mask_zero_migration_is_lossless():
    """An all-false donor mask (no events move) keeps every live slot's data
    and occupancy; only the ring is canonicalized."""
    pool, _ = ev.insert(ev.empty_pool(8), ev.batch_from_rows(rows(5)))
    popped = ev.pop_mask(pool, jnp.zeros((8,), bool))
    check_ring_invariant(popped)
    assert live_events(popped) == live_events(pool)
    assert int(popped.free_count) == int(pool.free_count)
    np.testing.assert_array_equal(np.asarray(popped.valid), np.asarray(pool.valid))
