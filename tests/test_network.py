"""Max–min fairness properties (hypothesis) for the interrupt-based traffic model."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.network import (completion_times, incidence, maxmin_rates,
                                progress_flows)

flows = st.integers(2, 12)
links = st.integers(1, 5)


@st.composite
def problem(draw):
    f = draw(flows)
    l = draw(links)
    rng = np.random.RandomState(draw(st.integers(0, 2**31 - 1)))
    routes = rng.randint(-1, l, size=(f, 3)).astype(np.int32)
    # each active flow needs >= 1 real hop
    routes[:, 0] = rng.randint(0, l, size=f)
    bw = (rng.rand(l) * 10 + 0.1).astype(np.float32)
    active = rng.rand(f) > 0.3
    return routes, bw, active


@settings(max_examples=40, deadline=None)
@given(problem())
def test_maxmin_invariants(p):
    routes, bw, active = p
    inc = incidence(jnp.asarray(routes), bw.shape[0])
    rates = np.asarray(maxmin_rates(inc, jnp.asarray(bw), jnp.asarray(active)))
    inc_n = np.asarray(inc)

    # inactive flows get zero
    assert np.all(rates[~active] == 0)
    # nonnegative
    assert np.all(rates >= 0)
    # link capacities respected (small epsilon for f32)
    link_load = inc_n[active].T @ rates[active] if active.any() else np.zeros(
        bw.shape)
    assert np.all(link_load <= bw * (1 + 1e-4) + 1e-4)
    # max-min: every active flow is bottlenecked — it crosses some link that is
    # (a) saturated and (b) where it gets >= the share of every other flow
    for i in np.where(active)[0]:
        ok = False
        for l_ in np.where(inc_n[i] > 0)[0]:
            others = [j for j in np.where(active)[0] if inc_n[j, l_] > 0]
            saturated = (inc_n[:, l_][active] @ rates[active]
                         >= bw[l_] * (1 - 1e-3) - 1e-4)
            if saturated and all(rates[i] >= rates[j] * (1 - 1e-3) - 1e-4
                                 for j in others):
                ok = True
                break
        assert ok, (i, rates, bw, inc_n)


def test_progress_and_completion():
    rem = jnp.asarray([10.0, 5.0, 7.0])
    rate = jnp.asarray([1.0, 0.0, 2.0])
    tlast = jnp.asarray([0, 0, 0], jnp.int32)
    active = jnp.asarray([True, True, False])
    rem2, tlast2 = progress_flows(rem, rate, tlast, active, jnp.int32(3))
    np.testing.assert_allclose(np.asarray(rem2), [7.0, 5.0, 7.0])
    t_fin = completion_times(rem2, rate, tlast2, active)
    assert int(t_fin[0]) == 3 + 7          # ceil(7/1)
    assert int(t_fin[1]) > 10**8           # starved flow: effectively never
    assert int(t_fin[2]) > 10**8           # inactive
