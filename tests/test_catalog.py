"""Declarative scenario catalog tests: registry semantics + CLI round-trip.

The contract (docs/scenario_api.md, "Scenario catalog"): a catalog entry is
a frozen named declaration whose ``resolve(overrides)`` coerces string
overrides to the declared defaults' types and is loud about undeclared
keys; every registered entry round-trips ``name -> spec -> run`` through
``simulate run <name>`` with the fleet orchestrator as the single entry
point.
"""

import sys

import numpy as np
import pytest

from repro.core import monitoring as mon
from repro.fleet import FleetPolicy, Orchestrator
from repro.launch import simulate
from repro.scenarios import catalog
from repro.scenarios.catalog import CatalogError, ScenarioDef

# small override sets so every entry runs in test time
SMALL = {
    "t0t1": {"n_flows": "4", "t_end": "4000"},
    "cache_churn": {"n_caches": "2", "n_rounds": "2"},
    "failure_farm": {"n_farms": "2", "n_bursts": "2", "jobs_per_farm": "1"},
    "ensemble_farm": {"replicas": "2", "n_bursts": "2"},
}


# ----------------------------------------------------------- registry API
def test_names_sorted_and_builtin_entries_present():
    ns = catalog.names()
    assert list(ns) == sorted(ns)
    for name in ("t0t1", "cache_churn", "failure_farm", "ensemble_farm"):
        assert name in ns


def test_get_unknown_is_loud():
    with pytest.raises(CatalogError, match="unknown scenario"):
        catalog.get("nope")


def test_register_duplicate_rejected():
    sd = ScenarioDef(name="t0t1", doc="dup", build=lambda: None)
    with pytest.raises(CatalogError, match="already registered"):
        catalog.register(sd)


def test_ensemble_entry_must_declare_replicas():
    sd = ScenarioDef(
        name="_bad_ensemble", doc="x", build=lambda: None, driver="ensemble"
    )
    with pytest.raises(CatalogError, match="replicas"):
        catalog.register(sd)
    assert "_bad_ensemble" not in catalog.names()


def test_override_coercion_and_rejection():
    sd = catalog.get("t0t1")
    built, params = sd.resolve(
        {"wan_bw": "0.5", "n_flows": "4", "t_end": "3000"}
    )
    assert params["wan_bw"] == 0.5 and isinstance(params["wan_bw"], float)
    assert params["n_flows"] == 4 and isinstance(params["n_flows"], int)
    assert len(built) == 4  # (world, own, init_events, spec)
    with pytest.raises(CatalogError, match="no parameter"):
        sd.resolve({"bogus": 1})
    with pytest.raises(CatalogError, match="cannot parse"):
        sd.resolve({"n_flows": "abc"})


def test_defaults_are_copies():
    sd = catalog.get("t0t1")
    d = sd.defaults()
    d["wan_bw"] = -1
    assert sd.defaults()["wan_bw"] != -1


# --------------------------------------- name -> spec -> run round-trips
def test_every_entry_runs_through_orchestrator():
    """The acceptance bar: each catalog entry resolves and completes a run
    through the orchestrator (the ensemble convention strips replicas/seed0
    from the build kwargs and sizes the seed vector instead)."""
    for name in catalog.names():
        sd = catalog.get(name)
        built, params = sd.resolve(SMALL.get(name, {}))
        seeds = None
        if sd.driver == "ensemble":
            seeds = np.arange(
                params["seed0"],
                params["seed0"] + params["replicas"],
                dtype=np.int32,
            )
        pol = FleetPolicy(driver=sd.driver)
        res = Orchestrator(pol).run(built, seeds=seeds)
        assert res.attempts == 1, name
        cn = np.asarray(res.state.counters)
        assert int(cn[..., mon.C_EVENTS].sum()) > 0, name
        assert bool(np.asarray(res.state.done).all()), name


# ------------------------------------------------------------------- CLI
def _main(argv, monkeypatch):
    monkeypatch.setattr(sys, "argv", ["simulate"] + argv)
    simulate.main()


def test_cli_list(capsys, monkeypatch):
    _main(["run", "--list"], monkeypatch)
    out = capsys.readouterr().out
    for name in catalog.names():
        assert name in out
    assert "params:" in out


def test_cli_round_trip_t0t1(capsys, monkeypatch):
    _main(
        ["run", "t0t1", "--set", "n_flows=4", "--set", "t_end=4000"],
        monkeypatch,
    )
    out = capsys.readouterr().out
    assert "[run] t0t1 driver=local" in out
    assert "attempts=1" in out and "preempt=0" in out


def test_cli_round_trip_ensemble(capsys, monkeypatch):
    _main(
        ["run", "ensemble_farm", "--set", "replicas=2", "--set",
         "n_bursts=2"],
        monkeypatch,
    )
    out = capsys.readouterr().out
    assert "[run] ensemble_farm driver=ensemble" in out


def test_cli_errors_are_systemexit(monkeypatch):
    with pytest.raises(SystemExit, match="unknown scenario"):
        _main(["run", "nope"], monkeypatch)
    with pytest.raises(SystemExit, match="no parameter"):
        _main(["run", "t0t1", "--set", "bogus=1"], monkeypatch)
    with pytest.raises(SystemExit, match="K=V"):
        _main(["run", "t0t1", "--set", "novalue"], monkeypatch)
    with pytest.raises(SystemExit, match="scenario name"):
        _main(["run"], monkeypatch)
    with pytest.raises(SystemExit, match="preempt-survivors"):
        _main(["run", "t0t1", "--preempt-at-window", "4"], monkeypatch)
