"""Migration accounting: C_MIGRATE_OUT/C_MIGRATE_IN balance globally and
receiving-pool overflow lands in C_DROP_POOL, loudly — on the fast vmap
driver (single device), so the books are audited on every install, and
across a checkpoint/restore boundary (the resumed run may reshard onto a
different device count; the books must still balance globally)."""

import jax.numpy as jnp
import numpy as np
import pytest

from distributed_harness import run_distributed_child
from repro.checkpoint import SimCheckpointer
from repro.core import Engine, ScenarioBuilder, events as ev
from repro.core import monitoring as mon


def _idle_scenario(n_idle=12, n_agents=3, pool_cap=8):
    """n_idle bare LPs round-robined over the agents, one pending NOOP each
    (t >= t_end, so nothing executes — the pools just hold freight for the
    migration to move)."""
    b = ScenarioBuilder()
    lps = [b.add_idle_lp() for _ in range(n_idle)]
    for i, lp in enumerate(lps):
        b.add_event(time=50 + i, kind=ev.K_NOOP, src=lp, dst=lp)
    return b.build(n_agents=n_agents, lookahead=1, t_end=10, pool_cap=pool_cap)


def _counters(st):
    return np.asarray(st.counters)


def test_migrate_counters_balance_globally():
    """Every event shipped by a donor is booked received somewhere:
    sum(C_MIGRATE_OUT) == sum(C_MIGRATE_IN), both nonzero for a real move."""
    w, o, e, s = _idle_scenario()
    eng = Engine(w, o, e, s)
    st = eng.init_state()
    # move agent 2's four LPs to agent 0 (0+4 -> 8 == pool_cap: no overflow)
    la = np.asarray(st.world.lp_agent[0])
    new_la = np.where(la == 2, 0, la).astype(np.int32)
    out = eng.apply_placement_local(st, jnp.asarray(new_la))
    cnt = _counters(out)
    assert cnt[:, mon.C_MIGRATE_OUT].sum() == cnt[:, mon.C_MIGRATE_IN].sum() == 4
    # donors book OUT, receivers book IN — not the same rows
    assert cnt[2, mon.C_MIGRATE_OUT] == 4 and cnt[2, mon.C_MIGRATE_IN] == 0
    assert cnt[0, mon.C_MIGRATE_IN] == 4 and cnt[0, mon.C_MIGRATE_OUT] == 0
    assert cnt[:, mon.C_DROP_POOL].sum() == 0
    # the freight actually moved pools
    occ = [int(np.asarray(out.pool.valid[a]).sum()) for a in range(3)]
    assert occ == [8, 4, 0]


def test_migrate_receiver_overflow_is_counted():
    """A receiving pool that cannot hold the freight drops the excess into
    C_DROP_POOL (never silently); the out/in books still balance because IN
    is counted pre-insert."""
    w, o, e, s = _idle_scenario(n_idle=12, n_agents=3, pool_cap=8)
    eng = Engine(w, o, e, s)
    st = eng.init_state()
    # all 12 LPs onto agent 0: 4 resident + 8 received > pool_cap 8
    new_la = np.zeros(12, np.int32)
    out = eng.apply_placement_local(st, jnp.asarray(new_la))
    cnt = _counters(out)
    assert cnt[:, mon.C_MIGRATE_OUT].sum() == cnt[:, mon.C_MIGRATE_IN].sum() == 8
    assert cnt[0, mon.C_DROP_POOL] == 4  # loud, on the receiver
    assert cnt[1:, mon.C_DROP_POOL].sum() == 0  # donors drop nothing
    assert int(np.asarray(out.pool.valid[0]).sum()) == 8  # full, not corrupt


def test_migrate_identity_placement_moves_nothing():
    """A no-op placement books zero migration traffic and keeps every pool's
    live events bit-identical (only the ring is canonicalized)."""
    w, o, e, s = _idle_scenario()
    eng = Engine(w, o, e, s)
    st = eng.init_state()
    out = eng.apply_placement_local(st, st.world.lp_agent[0])
    cnt = _counters(out)
    assert cnt[:, mon.C_MIGRATE_OUT].sum() == 0
    assert cnt[:, mon.C_MIGRATE_IN].sum() == 0
    assert cnt[:, mon.C_DROP_POOL].sum() == 0
    np.testing.assert_array_equal(
        np.asarray(out.pool.valid), np.asarray(st.pool.valid)
    )
    np.testing.assert_array_equal(np.asarray(out.pool.time), np.asarray(st.pool.time))


def test_migrate_books_survive_checkpoint_restore(tmp_path):
    """A checkpoint taken right after the migration install (the all_to_all
    stages' window) round-trips the books bit-exact: the restored state's
    OUT/IN sums still balance and a second placement on the restored state
    keeps balancing cumulatively."""
    w, o, e, s = _idle_scenario()
    eng = Engine(w, o, e, s)
    st = eng.init_state()
    la = np.asarray(st.world.lp_agent[0])
    new_la = np.where(la == 2, 0, la).astype(np.int32)
    out = eng.apply_placement_local(st, jnp.asarray(new_la))
    ck = SimCheckpointer(str(tmp_path))
    ck.save_sim(0, out, engine=eng)
    eng2 = Engine(w, o, e, s, checkpointer=SimCheckpointer(str(tmp_path)))
    rec = eng2.restore()
    cnt = _counters(rec.state)
    assert cnt[:, mon.C_MIGRATE_OUT].sum() == cnt[:, mon.C_MIGRATE_IN].sum() == 4
    # migrate back on the restored state: cumulative books stay balanced
    back = eng2.apply_placement_local(rec.state, st.world.lp_agent[0])
    cnt2 = _counters(back)
    assert (cnt2[:, mon.C_MIGRATE_OUT].sum()
            == cnt2[:, mon.C_MIGRATE_IN].sum() == 8)


_RESHARD_BOOKS_BODY = r"""
otrace = oracle_trace()
world, own, init_ev, spec = t0t1_build(4)
eng = Engine(world, own, init_ev, spec, trace_cap=4096)
mesh4 = Mesh(np.array(jax.devices()), ("agents",))
st0 = eng.init_state()
la = np.asarray(st0.world.lp_agent[0])
src = int(np.asarray(st0.pool.valid).sum(axis=1).argmax())
dst = 0 if src != 0 else 3
new_la = np.where(la == src, dst,
                  np.where(la == dst, src, la)).astype(np.int32)
migrated = eng.apply_placement_distributed(st0, new_la, mesh4)
ck = SimCheckpointer(tmp)
ck.save_sim(0, migrated, engine=eng)  # between migration and continuation
eng2 = Engine(world, own, init_ev, spec, trace_cap=4096,
              checkpointer=SimCheckpointer(tmp))
rec = eng2.restore()
mesh2 = Mesh(np.array(jax.devices()[:2]), ("agents",))  # reshard 4 -> 2
st = eng2.run_distributed(mesh2, state=rec.state)
cnt = np.asarray(st.counters)
out_sum = int(cnt[:, mon.C_MIGRATE_OUT].sum())
in_sum = int(cnt[:, mon.C_MIGRATE_IN].sum())
print(json.dumps({
    "books_balance": out_sum == in_sum,
    "moved_something": out_sum > 0,
    "trace_eq_oracle": engine_trace(st) == otrace,
    "info_out": out_sum,
}))
"""


@pytest.mark.slow
def test_migrate_books_balance_across_reshard_subprocess(tmp_path):
    """Satellite of the checkpoint PR: a 4-device run whose placement was
    migrated through the staged all_to_all is checkpointed between the
    migration window and the continuation; the resumed run reshards onto 2
    devices. The global OUT/IN books must still balance (and be nonzero),
    and the continuation must execute the exact oracle trace."""
    body = f"tmp = {str(tmp_path)!r}\n" + _RESHARD_BOOKS_BODY
    res = run_distributed_child(body, n_devices=4)
    assert res["books_balance"] is True, res
    assert res["moved_something"] is True, res
    assert res["trace_eq_oracle"] is True, res


def test_migrate_counters_are_registered():
    """The new counters ride the declarative registry: names resolve and the
    monitoring constants agree with the registered order."""
    names = [n for n, _ in mon.BUILTIN_COUNTERS]
    assert names.index("MIGRATE_OUT") == mon.C_MIGRATE_OUT
    assert names.index("MIGRATE_IN") == mon.C_MIGRATE_IN
    assert mon.N_COUNTERS == len(names)
