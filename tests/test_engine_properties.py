"""Hypothesis property tests: engine == oracle over randomized scenarios.

The scenario STRUCTURE is fixed (same array shapes => one jit compilation,
cached across examples); hypothesis drives every parameter: CPU powers, link
bandwidths/latencies, generator rates/sizes, placement, lookahead.
"""
import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (Engine, ScenarioBuilder, events as ev,
                        merged_engine_trace, run_sequential)
from repro.core import monitoring as mon

scenario_params = st.fixed_dictionaries(dict(
    p0=st.floats(1.0, 20.0),
    p1=st.floats(1.0, 20.0),
    bw0=st.floats(0.1, 8.0),
    bw1=st.floats(0.1, 8.0),
    lat=st.integers(1, 20),
    interval=st.integers(5, 60),
    size=st.floats(5.0, 120.0),
    count=st.integers(2, 10),
    lookahead=st.integers(1, 4),
    wpm=st.floats(0.5, 4.0),
    seed=st.integers(0, 2**31 - 1),
))


def build(p, n_agents, **kw):
    b = ScenarioBuilder(max_cpu=4, queue_cap=8, max_link=4, max_flow=16)
    t0 = b.add_regional_center(n_cpu=2, cpu_power=p["p0"], disk=400.0,
                               tape=4000.0, tape_rate=5.0)
    t1 = b.add_regional_center(n_cpu=2, cpu_power=p["p1"], disk=250.0,
                               tape=2500.0, tape_rate=5.0)
    wan = b.add_net_region(link_bws=[p["bw0"], p["bw1"]],
                           link_lats=[p["lat"], p["lat"]])
    b.add_generator(target_lp=wan, kind=ev.K_FLOW_START,
                    payload=[p["size"], 0, -1, -1, t1["farm"],
                             ev.K_JOB_SUBMIT, t1["storage"], ev.K_DATA_WRITE],
                    interval=p["interval"], count=p["count"])
    rng = np.random.RandomState(p["seed"])
    placement = rng.randint(0, n_agents, size=len(b._lps))
    return b.build(n_agents=n_agents, lookahead=p["lookahead"], t_end=4000,
                   pool_cap=256, work_per_mb=p["wpm"],
                   placement=placement if n_agents > 1 else None, **kw)


@settings(max_examples=12, deadline=None)
@given(scenario_params)
def test_random_scenarios_match_oracle(p):
    world, own, init_ev, spec = build(p, 1)
    ow, oc, otrace = run_sequential(world, own, init_ev, spec)

    world, own, init_ev, spec = build(p, 2)
    eng = Engine(world, own, init_ev, spec, trace_cap=4096)
    stt = eng.run_local(max_windows=20000)
    trace = merged_engine_trace(np.asarray(stt.trace), np.asarray(stt.trace_n))
    assert trace == otrace
    w = jax.tree.map(lambda x: np.asarray(x[0]), stt.world)
    np.testing.assert_allclose(np.asarray(ow.sto_used), w.sto_used, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(ow.lp_lvt), w.lp_lvt)
    # conservative engine must never drop anything at these sizes
    drops = np.asarray(stt.counters)[:, list(mon.DROP_COUNTERS)]
    assert drops.sum() == 0


@settings(max_examples=6, deadline=None)
@given(scenario_params)
def test_fused_select_matches_oracle(p):
    """The fused superstep megakernel engine (spec.fused_select=True, the
    interpret-Pallas path on CPU) == the batched-dispatch stitched engine ==
    the sequential fold == the heapq oracle, byte-exactly — trace, world,
    and drop counters."""
    world, own, init_ev, spec = build(p, 1)
    ow, _oc, otrace = run_sequential(world, own, init_ev, spec)

    fused = build(p, 2, fused_select=True)
    assert fused[3].fused_select
    stf = Engine(*fused, trace_cap=4096).run_local(max_windows=20000)
    trace_f = merged_engine_trace(np.asarray(stf.trace),
                                  np.asarray(stf.trace_n))
    assert trace_f == otrace

    # the megakernel under the sequential fold (batched_dispatch=False uses
    # fused select/gather/release but folds handlers one by one)
    seq = build(p, 2, fused_select=True, batched_dispatch=False)
    sts = Engine(*seq, trace_cap=4096).run_local(max_windows=20000)
    trace_s = merged_engine_trace(np.asarray(sts.trace),
                                  np.asarray(sts.trace_n))
    assert trace_s == otrace

    w = jax.tree.map(lambda x: np.asarray(x[0]), stf.world)
    np.testing.assert_allclose(np.asarray(ow.sto_used), w.sto_used, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(ow.lp_lvt), w.lp_lvt)
    drops = np.asarray(stf.counters)[:, list(mon.DROP_COUNTERS)]
    assert drops.sum() == 0


@settings(max_examples=8, deadline=None)
@given(scenario_params)
def test_lookahead_invariance_of_flow_accounting(p):
    """Changing lookahead reorders windows but conserves flow accounting:
    every started flow completes (or is still in flight at t_end)."""
    world, own, init_ev, spec = build(p, 2)
    stt = Engine(world, own, init_ev, spec).run_local(max_windows=20000)
    c = np.asarray(stt.counters).sum(axis=0)
    assert c[mon.C_FLOWS_DONE] <= c[mon.C_FLOWS_STARTED]
    w = jax.tree.map(lambda x: np.asarray(x[0]), stt.world)
    in_flight = int(w.flow_active.sum())
    assert c[mon.C_FLOWS_STARTED] == c[mon.C_FLOWS_DONE] + in_flight
