"""Dry-run machinery tests.

The 512-device production sweep runs out-of-band (launch/dryrun.py, results/);
here we validate (a) the loop-aware HLO cost accounting against analytic counts,
(b) the sharding-rule resolution, and (c) — in a subprocess so this process
keeps its single device — that a small arch lowers + compiles under the
production rules on an 8-device mesh with collectives present.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.sharding import DEFAULT_RULES, spec_for
from repro.roofline.hlocount import stablehlo_costs
from repro.roofline.analysis import model_flops
from repro.configs.base import SHAPES
from repro.configs.registry import get_config


def test_stablehlo_costs_scan_exact():
    def f(x, w):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        x, _ = jax.lax.scan(body, x, w)
        return x
    x = jax.ShapeDtypeStruct((8, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((30, 256, 256), jnp.float32)
    c = stablehlo_costs(jax.jit(f).lower(x, w).as_text())
    assert c["flops"] == 30 * 2 * 8 * 256 * 256


def test_stablehlo_costs_grad_remat_multiplier():
    def h(x, w):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        x, _ = jax.lax.scan(jax.checkpoint(body), x, w)
        return jnp.sum(x)
    g = jax.grad(h, argnums=1)
    c = stablehlo_costs(jax.jit(g).lower(
        jnp.zeros((8, 256)), jnp.zeros((30, 256, 256))).as_text())
    base = 30 * 2 * 8 * 256 * 256
    assert c["flops"] == 4 * base          # fwd + remat-fwd + 2x bwd


def test_spec_for_divisibility_fallbacks():
    mesh = {"data": 16, "model": 16}
    # heads=9 not divisible -> unsharded; mlp divisible -> model
    s = spec_for((1536,), ("mlp",), DEFAULT_RULES, mesh)
    assert s == jax.sharding.PartitionSpec("model")
    s = spec_for((9, 64), ("heads", "head"), DEFAULT_RULES, mesh)
    assert s == jax.sharding.PartitionSpec(None, None)
    # batch folds pod+data when both present
    mesh3 = {"pod": 2, "data": 16, "model": 16}
    s = spec_for((256, 4096), ("batch", "seq"), DEFAULT_RULES, mesh3)
    assert s == jax.sharding.PartitionSpec(("pod", "data"), None)
    # one mesh axis never used twice in a tensor
    s = spec_for((32, 32), ("heads", "mlp"), DEFAULT_RULES, mesh)
    assert s == jax.sharding.PartitionSpec("model", None)


def test_model_flops_conventions():
    cfg = get_config("deepseek-7b")
    t = model_flops(cfg, SHAPES["train_4k"])
    # 6 * N * D around the nominal 7B params x 1M tokens = 4.2e16
    assert 2e16 < t < 8e16
    d = model_flops(cfg, SHAPES["decode_32k"])
    assert d == 2.0 * cfg.param_count * 128


@pytest.mark.slow
def test_subprocess_small_mesh_compile():
    """smollm train lowers+compiles on an 8-device (4,2) mesh with the
    production sharding rules; collectives appear in the compiled module."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, json
from repro.configs.base import SHAPES, TrainConfig
from repro.configs.registry import smoke_config
import dataclasses
from repro.models.model import build_model
from repro.models import sharding as sh
import repro.launch.dryrun as D
from repro.train.loop import make_train_step
from repro.train.optimizer import init_opt_state

cfg = dataclasses.replace(smoke_config("smollm-135m"), n_layers=4, d_model=128,
                          n_heads=4, n_kv=2, d_ff=256, vocab=512, head_dim=32)
model = build_model(cfg)
mesh = jax.make_mesh((4, 2), ("data", "model"))
rules = D.RULE_VARIANTS["baseline"]
holder = {}
def _v(r):
    vals, names = model.init(r)
    holder["n"] = names
    return vals
p_sds = jax.eval_shape(_v, jax.random.PRNGKey(0))
p_sh = D.shardings_for(p_sds, holder["n"], mesh, rules)
shape = dataclasses.replace(SHAPES["train_4k"], seq_len=256, global_batch=8)
b_sds = model.input_specs(shape)
b_sh = D.shardings_for(b_sds, D._input_names(b_sds), mesh, rules)
o_sds = jax.eval_shape(init_opt_state, p_sds)
o_sh = D.shardings_for(o_sds, type(o_sds)(step=(), m=holder["n"], v=holder["n"]),
                       mesh, rules)
with sh.sharding_ctx(mesh, rules):
    step = make_train_step(model, TrainConfig())
    comp = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                   donate_argnums=(0, 1)).lower(p_sds, o_sds, b_sds).compile()
text = comp.as_text()
print(json.dumps({
    "ok": True,
    "has_collectives": any(k in text for k in
                           ("all-reduce", "all-gather", "reduce-scatter")),
}))
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok"] and res["has_collectives"]
