"""Paper §4.1 scheduling algorithm: APSP correctness, placement properties,
run clustering, monitoring-driven rebalance."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import scheduler as sched


def floyd_warshall(w):
    d = w.copy()
    n = d.shape[0]
    for k in range(n):
        for i in range(n):
            for j in range(n):
                d[i, j] = min(d[i, j], d[i, k] + d[k, j])
    return d


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 9), st.integers(0, 2**31 - 1))
def test_apsp_matches_floyd_warshall(n, seed):
    rng = np.random.RandomState(seed)
    perf = rng.rand(n).astype(np.float32) * 10
    w = np.asarray(sched.performance_graph(jnp.asarray(perf)))
    d_ref = floyd_warshall(w.astype(np.float64))
    d = np.asarray(sched.apsp(jnp.asarray(w)))
    np.testing.assert_allclose(d, d_ref, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 8), st.integers(0, 2**31 - 1))
def test_choose_agent_is_paper_formula(n, seed):
    rng = np.random.RandomState(seed)
    perf = rng.rand(n).astype(np.float32) * 10
    part = rng.rand(n) > 0.5
    a = int(sched.choose_agent(jnp.asarray(perf), jnp.asarray(part)))
    # reference: mean shortest path to participating nodes, argmin
    w = np.asarray(sched.performance_graph(jnp.asarray(perf)))
    d = floyd_warshall(w.astype(np.float64))
    if part.any():
        scores = d[:, part].mean(axis=1)
    else:
        scores = perf
    assert a == int(np.argmin(scores))


def test_first_placement_prefers_least_loaded():
    perf = jnp.asarray([5.0, 1.0, 9.0])
    a = int(sched.choose_agent(perf, jnp.zeros(3, bool)))
    assert a == 1


def test_same_run_clusters():
    """LPs of one run land near each other (paper: 'group the logical processes
    belonging to the same simulation run into a minimum cluster')."""
    perf = jnp.asarray([1.0, 1.05, 20.0, 20.0])
    placement = np.asarray(sched.plan_placement(perf, jnp.zeros(6, jnp.int32), 4))
    # all six LPs of the single run avoid the two heavily loaded agents
    assert set(placement.tolist()) <= {0, 1}


def test_rebalance_triggers_on_hot_agent():
    from repro.core import monitoring as mon
    a = 4
    counters = np.zeros((a, mon.N_COUNTERS), np.int32)
    counters[:, mon.C_WINDOWS] = 10
    counters[0, mon.C_EVENTS] = 10_000          # agent 0 is hot
    counters[1:, mon.C_EVENTS] = 10
    lp_agent = jnp.zeros(8, jnp.int32)          # everything on agent 0
    lp_ctx = jnp.zeros(8, jnp.int32)
    new = np.asarray(sched.rebalance(jnp.asarray(counters), lp_agent, lp_ctx,
                                     jnp.zeros(a)))
    assert not np.all(new == 0)                 # moved off the hot agent

    # balanced fleet: placement untouched
    counters[:, mon.C_EVENTS] = 100
    same = np.asarray(sched.rebalance(jnp.asarray(counters), lp_agent, lp_ctx,
                                      jnp.zeros(a)))
    np.testing.assert_array_equal(same, np.zeros(8))


def test_straggler_monitor_detects_and_replans():
    from repro.ft.straggler import StragglerMonitor
    m = StragglerMonitor(n_hosts=4)
    for step in range(5):
        for h in range(4):
            m.record(h, step, 1.0 if h != 2 else 3.0)
    assert m.stragglers() == [2]
    plan = np.asarray(m.replacement_plan(np.zeros(6, np.int32),
                                         np.zeros(6, np.int32)))
    assert 2 not in set(plan.tolist())
    rec = m.eviction_recommendation()
    assert rec["evict_hosts"] == [2]
