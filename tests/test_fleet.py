"""Elastic fleet orchestration tests: preempt, shrink, resume, byte-exact.

The contract (docs/architecture.md, "Elastic fleet orchestration"): the
:class:`~repro.fleet.Orchestrator` wraps the four engine drivers behind one
``run(built, devices, policy)`` entry point and survives shard loss — an
injected preemption probe or a real SIGKILL — by restoring the latest
GVT-aligned checkpoint on the surviving device set. The orchestrator changes
*where* the run executes, never *what* it computes: the resumed run's
traces, counters, world, and pool must be byte-identical to the
uninterrupted run and the sequential heapq oracle. Fleet counters
(``C_PREEMPT``/``C_RESUME``/``C_RESHARD``) are booked host-side only — the
in-graph rows stay zero, which is exactly what keeps the equality exact.

Fast tests drive the in-process drivers with the injected probe; slow tests
add the subprocess lanes (``tests/distributed_harness.py``): a 4-device
injected shard loss shrinking to 2 survivors, and a real SIGKILL discovered
at restart through the ``fleet.json`` sidecar.
"""

import json
import os
import signal

import jax
import numpy as np
import pytest

from conftest import t0t1_builder
from distributed_harness import run_distributed_child, run_killed_child
from repro.checkpoint import SimCheckpointer
from repro.core import Engine, MetricsStream, TraceStream
from repro.core import monitoring as mon
from repro.core.policy import ExecPolicy
from repro.fleet import FleetError, FleetPolicy, Orchestrator, PreemptionError


def build(n_agents, *, pool_cap=256, exec_cap=16, exec_policy=None):
    b, kw = t0t1_builder()
    kw["pool_cap"] = pool_cap
    if exec_policy is not None:
        kw["exec_policy"] = exec_policy
    else:
        kw["exec_cap"] = exec_cap
    return b.build(n_agents=n_agents, **kw)


def tree_eq(a, b):
    return bool(
        jax.tree.all(
            jax.tree.map(
                lambda x, y: bool((np.asarray(x) == np.asarray(y)).all()), a, b
            )
        )
    )


def preempt_once(at_window, survivors):
    """A probe that kills the FIRST attempt once it reaches ``at_window``."""

    def probe(window, attempt):
        return survivors if attempt == 0 and window >= at_window else None

    return probe


def fleet_rows_zero(state):
    """The in-graph counter vector must never carry fleet bookkeeping."""
    c = np.asarray(state.counters)
    return int(c[..., list(mon.FLEET_COUNTERS)].sum()) == 0


@pytest.fixture(scope="module")
def oracle(t0t1_oracle):
    _w, _c, trace = t0t1_oracle
    return trace


# ----------------------------------------------------------------- policy
def test_policy_validation():
    with pytest.raises(FleetError, match="unknown driver"):
        FleetPolicy(driver="bogus")
    with pytest.raises(FleetError, match="min_devices"):
        FleetPolicy(min_devices=0)
    with pytest.raises(FleetError, match="max_retries"):
        FleetPolicy(max_retries=-1)
    with pytest.raises(FleetError, match="checkpoint_every"):
        FleetPolicy(checkpoint_every=-1)


def test_preemption_error_carries_survivors():
    e = PreemptionError(3, at_window=17)
    assert e.survivors == 3 and e.at_window == 17
    assert "window 17" in str(e)


# ------------------------------------------------- one entry point, no loss
def test_orchestrator_matches_engine_drivers():
    """Uninterrupted orchestrated runs are the plain driver runs: same
    bytes, one attempt, zero fleet books, auto driver resolution."""
    built = build(3)
    ref = Engine(*built).run_local()
    res = Orchestrator().run(built, devices=jax.devices()[:1])
    assert res.driver == "local" and res.attempts == 1
    assert res.counts == {"PREEMPT": 0, "RESUME": 0, "RESHARD": 0}
    assert tree_eq(res.state, ref)

    ladder = ExecPolicy(ladder=(4, 16))
    built_a = build(3, exec_policy=ladder)
    ref_a = Engine(*built_a).run_adaptive()
    res_a = Orchestrator().run(built_a, devices=jax.devices()[:1])
    assert res_a.driver == "adaptive"
    assert tree_eq(res_a.state, ref_a)


def test_orchestrator_streams_oracle_exact(oracle):
    ts = TraceStream()
    built = build(4)
    res = Orchestrator(trace_stream=ts, trace_cap=32, drain_every=4).run(
        built, devices=jax.devices()[:1]
    )
    assert ts.merged() == oracle
    assert int(np.asarray(res.state.counters)[:, mon.C_TRACE_DROP].sum()) == 0


# --------------------------------------------------- injected shard loss
def test_injected_preemption_resume_byte_identical(oracle, tmp_path):
    """The headline in-process elastic case: attempt 0 is preempted past a
    committed checkpoint; attempt 1 auto-resumes and finishes. Final state
    bytes == the uninterrupted run, streamed trace == oracle, fleet books
    land host-side only."""
    built = build(4)
    ref_ms = MetricsStream(interval=4)
    ref = Engine(
        *built,
        trace_cap=32,
        trace_stream=TraceStream(),
        metrics_stream=ref_ms,
        drain_every=4,
    ).run_local()

    ts, ms = TraceStream(), MetricsStream(interval=4)
    pol = FleetPolicy(checkpoint_dir=str(tmp_path), checkpoint_every=4)
    orch = Orchestrator(
        pol,
        trace_stream=ts,
        metrics_stream=ms,
        preempt=preempt_once(12, 1),
        trace_cap=32,
        drain_every=4,
    )
    res = orch.run(built, devices=jax.devices()[:1])
    assert res.attempts == 2
    assert res.counts == {"PREEMPT": 1, "RESUME": 1, "RESHARD": 0}
    assert tree_eq(res.state, ref)
    assert ts.merged() == oracle
    assert fleet_rows_zero(res.state)
    # metrics records concatenate to the uninterrupted run's, with the fleet
    # books as the ONLY difference (class "fleet" is host-side by design)
    assert len(ms.lines) == len(ref_ms.lines)
    fleet_names = {name for name, _ in mon.BUILTIN_COUNTERS[-3:]}
    assert fleet_names == {"PREEMPT", "RESUME", "RESHARD"}
    for got, want in zip(ms.lines, ref_ms.lines):
        got = dict(got, counters={k: v for k, v in got["counters"].items()
                                  if k not in fleet_names})
        want = dict(want, counters={k: v for k, v in want["counters"].items()
                                    if k not in fleet_names})
        assert got == want
    # the booked values surface in the final record
    assert ms.latest["counters"]["PREEMPT"] == 1
    assert ms.latest["counters"]["RESUME"] == 1


def test_preemption_before_first_checkpoint_restarts_fresh(tmp_path):
    """Dying before any committed checkpoint means a clean restart (no
    RESUME book) — and the rerun still matches the uninterrupted bytes."""
    built = build(3)
    ref = Engine(*built).run_local()
    pol = FleetPolicy(checkpoint_dir=str(tmp_path), checkpoint_every=50)
    orch = Orchestrator(pol, preempt=preempt_once(2, 1))
    res = orch.run(built, devices=jax.devices()[:1])
    assert res.attempts == 2
    assert res.counts == {"PREEMPT": 1, "RESUME": 0, "RESHARD": 0}
    assert tree_eq(res.state, ref)


def test_degraded_floor_hard_fails(tmp_path):
    pol = FleetPolicy(
        checkpoint_dir=str(tmp_path), checkpoint_every=4, min_devices=1
    )
    orch = Orchestrator(pol, preempt=preempt_once(4, 0))
    with pytest.raises(FleetError, match="device floor"):
        orch.run(build(2), devices=jax.devices()[:1])
    assert orch.counts["PREEMPT"] == 1


def test_retry_cap_exhausted(tmp_path):
    pol = FleetPolicy(
        checkpoint_dir=str(tmp_path), checkpoint_every=4, max_retries=2
    )
    orch = Orchestrator(
        pol, preempt=lambda window, attempt: 1 if window >= 4 else None
    )
    with pytest.raises(FleetError, match="retry cap"):
        orch.run(build(2), devices=jax.devices()[:1])
    assert orch.counts["PREEMPT"] == 3  # initial + 2 retries, all preempted


def test_backoff_schedule(tmp_path):
    """Exponential, capped, only between attempts."""
    slept = []
    pol = FleetPolicy(
        checkpoint_dir=str(tmp_path),
        checkpoint_every=4,
        max_retries=3,
        backoff=2.0,
        backoff_cap=3.0,
    )
    orch = Orchestrator(
        pol,
        preempt=lambda w, attempt: 1 if attempt < 2 and w >= 4 else None,
        sleep=slept.append,
    )
    res = orch.run(build(2), devices=jax.devices()[:1])
    assert res.attempts == 3
    assert slept == [2.0, 3.0]  # 2, then min(4, cap=3)


# ------------------------------------------------ sidecar (SIGKILL lane)
def test_sidecar_restart_discovery(tmp_path):
    """A prior orchestrated process that died mid-run leaves committed
    checkpoints plus an unclean ``fleet.json``; the next start books the
    death as a preemption, restores the books, resumes, and reshard-counts
    the device change — all without the dead process telling anyone."""
    built = build(3)
    ref = Engine(*built).run_local()

    # simulate the dead process: checkpoints exist, sidecar is unclean
    class _Die(RuntimeError):
        pass

    def die(window, _state):
        if window >= 8:
            raise _Die

    eng = Engine(
        *built,
        checkpointer=SimCheckpointer(str(tmp_path), every=4),
        window_hook=die,
    )
    with pytest.raises(_Die):
        eng.run_local()
    with open(os.path.join(str(tmp_path), "fleet.json"), "w") as f:
        json.dump(
            {
                "n_devices": 2,
                "clean": False,
                "counts": {"PREEMPT": 1, "RESUME": 1, "RESHARD": 0},
            },
            f,
        )

    pol = FleetPolicy(checkpoint_dir=str(tmp_path), checkpoint_every=4)
    orch = Orchestrator(pol)
    res = orch.run(built, devices=jax.devices()[:1])
    assert res.attempts == 1
    # prior books restored (1,1,0) + the discovered death + this resume,
    # which also resharded 2 -> 1
    assert res.counts == {"PREEMPT": 2, "RESUME": 2, "RESHARD": 1}
    assert tree_eq(res.state, ref)
    # a completed run flips the sidecar clean: a rerun is NOT a preemption
    with open(os.path.join(str(tmp_path), "fleet.json")) as f:
        assert json.load(f)["clean"] is True


# ------------------------------------------------------------- ensemble
def test_ensemble_driver_through_orchestrator():
    built = build(2, pool_cap=128)
    seeds = np.arange(1, 4, dtype=np.int32)
    ref = Engine(*built).run_ensemble(seeds)
    res = Orchestrator(FleetPolicy(driver="ensemble")).run(built, seeds=seeds)
    assert res.driver == "ensemble" and res.attempts == 1
    assert tree_eq(res.state, ref)
    with pytest.raises(FleetError, match="seed vector"):
        Orchestrator(FleetPolicy(driver="ensemble")).run(built)


# ------------------------------------------- subprocess elastic lanes
_SHARD_LOSS_BODY = r"""
import tempfile
from repro.fleet import FleetPolicy, Orchestrator
built = t0t1_build(5, pool_cap=128, exec_cap=8, n_flows=16, second_gen=True)
world, own, init_ev, spec = built
otrace = oracle_trace(pool_cap=128, exec_cap=8, n_flows=16, second_gen=True)
ts = mon.TraceStream()
with tempfile.TemporaryDirectory() as tmp:
    pol = FleetPolicy(checkpoint_dir=tmp, checkpoint_every=4)
    orch = Orchestrator(
        pol, trace_stream=ts, trace_cap=32, drain_every=4,
        preempt=lambda w, attempt: 2 if attempt == 0 and w >= 12 else None)
    res = orch.run(built, devices=jax.devices())
# the uninterrupted reference: a from-scratch streamed run on the SAME
# 2-device survivor mesh
ref_ts = mon.TraceStream()
ref_eng = Engine(world, own, init_ev, spec, trace_cap=32, drain_every=4,
                 trace_stream=ref_ts)
ref = ref_eng.run_distributed(Mesh(np.array(jax.devices()[:2]), ("agents",)))
fleet_idx = [mon.C_PREEMPT, mon.C_RESUME, mon.C_RESHARD]
print(json.dumps({
    "driver": res.driver,
    "devices": res.devices,
    "attempts": res.attempts,
    "counts": res.counts,
    "state_eq_ref": tree_eq(res.state, ref),
    "stream_eq_oracle": ts.merged() == otrace,
    "ref_eq_oracle": ref_ts.merged() == otrace,
    "trace_drop": int(np.asarray(res.state.counters)[:, mon.C_TRACE_DROP].sum()),
    "fleet_rows_zero":
        int(np.asarray(res.state.counters)[:, fleet_idx].sum()) == 0,
}))
"""


@pytest.mark.slow
def test_injected_shard_loss_shrinks_and_matches(tmp_path):
    """4 devices, an injected shard loss at window >= 12 leaves 2 survivors:
    the orchestrator shrinks the mesh, resumes from the latest checkpoint,
    and finishes byte-identical to an uninterrupted 2-device run and the
    oracle — PREEMPT/RESUME/RESHARD each booked once, host-side only."""
    res = run_distributed_child(_SHARD_LOSS_BODY, n_devices=4)
    assert res["driver"] == "distributed" and res["devices"] == 2, res
    assert res["attempts"] == 2, res
    assert res["counts"] == {"PREEMPT": 1, "RESUME": 1, "RESHARD": 1}, res
    assert res["state_eq_ref"] is True, res
    assert res["stream_eq_oracle"] is True, res
    assert res["ref_eq_oracle"] is True, res
    assert res["trace_drop"] == 0, res
    assert res["fleet_rows_zero"] is True, res


_KILL_BODY = r"""
tmp = {tmp!r}
from repro.fleet import FleetPolicy, Orchestrator
built = t0t1_build(5, pool_cap=128, exec_cap=8, n_flows=16, second_gen=True)
pol = FleetPolicy(checkpoint_dir=tmp, checkpoint_every=4, kill_after=12)
orch = Orchestrator(pol, trace_stream=mon.TraceStream(), trace_cap=32,
                    drain_every=4)
orch.run(built, devices=jax.devices())
print(json.dumps({{"survived": True}}))
"""

_RESTART_BODY = r"""
tmp = {tmp!r}
from repro.fleet import FleetPolicy, Orchestrator
built = t0t1_build(5, pool_cap=128, exec_cap=8, n_flows=16, second_gen=True)
world, own, init_ev, spec = built
otrace = oracle_trace(pool_cap=128, exec_cap=8, n_flows=16, second_gen=True)
ts = mon.TraceStream()
pol = FleetPolicy(checkpoint_dir=tmp, checkpoint_every=4)
orch = Orchestrator(pol, trace_stream=ts, trace_cap=32, drain_every=4)
res = orch.run(built, devices=jax.devices())  # 2 devices now
ref_ts = mon.TraceStream()
ref_eng = Engine(world, own, init_ev, spec, trace_cap=32, drain_every=4,
                 trace_stream=ref_ts)
ref = ref_eng.run_distributed(Mesh(np.array(jax.devices()), ("agents",)))
print(json.dumps({{
    "attempts": res.attempts,
    "counts": res.counts,
    "state_eq_ref": tree_eq(res.state, ref),
    "stream_eq_oracle": ts.merged() == otrace,
    "ref_eq_oracle": ref_ts.merged() == otrace,
}}))
"""


@pytest.mark.slow
def test_sigkill_restart_discovers_preemption(tmp_path):
    """The SIGKILL lane end-to-end: an orchestrated 4-device run is killed
    by a real, unhandled SIGKILL right after a committed checkpoint; the
    unclean ``fleet.json`` sidecar makes the next start (a fresh 2-device
    process rerunning the same command) book the death as a preemption and
    auto-resume — no --resume flag, no operator. Result bytes == the
    uninterrupted 2-device run == the oracle."""
    tmp = str(tmp_path)
    dead = run_killed_child(_KILL_BODY.format(tmp=tmp), n_devices=4)
    assert dead.returncode == -signal.SIGKILL, (
        dead.returncode,
        dead.stderr[-2000:],
    )
    assert "survived" not in dead.stdout
    with open(os.path.join(tmp, "fleet.json")) as f:
        side = json.load(f)
    assert side["clean"] is False and side["n_devices"] == 4
    assert SimCheckpointer(tmp).latest_step() >= 12
    res = run_distributed_child(_RESTART_BODY.format(tmp=tmp), n_devices=2)
    assert res["attempts"] == 1, res
    assert res["counts"] == {"PREEMPT": 1, "RESUME": 1, "RESHARD": 1}, res
    assert res["state_eq_ref"] is True, res
    assert res["stream_eq_oracle"] is True, res
    assert res["ref_eq_oracle"] is True, res
