"""Substrate tests: checkpoint/resume, data determinism, compression, elastic."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import TrainConfig
from repro.configs.registry import smoke_config
from repro.data import pipeline as dp
from repro.ft import elastic
from repro.models.model import build_model
from repro.train import compression as comp
from repro.train.loop import make_train_step
from repro.train.optimizer import adamw_update, init_opt_state


def test_checkpoint_roundtrip_and_resume(tmp_path):
    """Save -> restore -> continue must be bit-identical to an unbroken run."""
    cfg = dataclasses.replace(smoke_config("deepseek-7b"), dtype="float32")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    tc = TrainConfig(learning_rate=1e-3)
    step = jax.jit(make_train_step(model, tc))
    dcfg = dp.DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)

    # unbroken: 6 steps
    p_u, o_u = params, opt
    for i in range(6):
        p_u, o_u, _ = step(p_u, o_u, dp.batch_for_shard(dcfg, i, 0, 1))

    # broken: 3 steps -> checkpoint -> restore -> 3 steps
    ck = Checkpointer(str(tmp_path))
    p_b, o_b = params, opt
    for i in range(3):
        p_b, o_b, _ = step(p_b, o_b, dp.batch_for_shard(dcfg, i, 0, 1))
    ck.save(3, (p_b, o_b), blocking=True)
    step_no, (p_r, o_r) = ck.restore((p_b, o_b))
    assert step_no == 3
    for i in range(3, 6):
        p_r, o_r, _ = step(p_r, o_r, dp.batch_for_shard(dcfg, i, 0, 1))

    for a, b in zip(jax.tree.leaves(p_u), jax.tree.leaves(p_r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_async(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"w": jnp.arange(8.0)}
    for s in (1, 2, 3, 4):
        ck.save(s, tree)            # async path
    ck.wait()
    assert ck.all_steps() == [3, 4]


def test_checkpoint_structure_mismatch_detected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"w": jnp.zeros(3)}, blocking=True)
    try:
        ck.restore({"other": jnp.zeros(3)})
        raise AssertionError("should have raised")
    except ValueError as e:
        assert "mismatch" in str(e)


def test_data_pipeline_determinism_and_resharding():
    dcfg = dp.DataConfig(vocab=101, seq_len=16, global_batch=8)
    a = dp.batch_for_shard(dcfg, 7, 0, 1)
    b = dp.batch_for_shard(dcfg, 7, 0, 1)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    # 2-shard split reassembles the 1-shard global batch (elastic invariant)
    s0 = dp.batch_for_shard(dcfg, 7, 0, 2)
    s1 = dp.batch_for_shard(dcfg, 7, 1, 2)
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(s0["tokens"]), np.asarray(s1["tokens"])]),
        np.asarray(a["tokens"]))
    # targets are next-token shifted
    block = dp.global_batch_at(dcfg, 7)
    np.testing.assert_array_equal(np.asarray(a["targets"]),
                                  np.asarray(block[:, 1:]))


def test_compression_error_feedback_converges():
    """int8+EF gradient descent on a quadratic reaches the optimum."""
    x = jnp.asarray([5.0, -3.0, 2.0])
    err = jnp.zeros(3)
    for _ in range(300):
        g = 2 * x                                  # grad of ||x||^2
        qt, err = comp.compress_tree(g, err)
        g_hat = comp.decompress_tree(qt)
        x = x - 0.05 * g_hat
    assert float(jnp.max(jnp.abs(x))) < 1e-2


def test_quantize_int8_bounds():
    x = jnp.asarray([-1000.0, 0.0, 0.5, 999.0])
    q, scale = comp.quantize_int8(x)
    back = comp.dequantize_int8(q, scale)
    assert float(jnp.max(jnp.abs(back - x))) <= float(scale) * 0.5 + 1e-6


def test_elastic_remesh_plans():
    p = elastic.plan_remesh(512, multi_pod=True)
    assert p.shape == (2, 16, 16) and p.axes == ("pod", "data", "model")
    p = elastic.plan_remesh(300)                   # lost a third of the fleet
    assert p.n_devices <= 300 and p.shape[-1] == 16
    p = elastic.plan_remesh(8)                     # catastrophic loss
    assert p.n_devices <= 8
    plan = elastic.reshard_plan(elastic.MeshPlan(("data", "model"), (16, 16)),
                                elastic.plan_remesh(128))
    assert plan["model"] == "keep"
    assert "gather" in plan["data"]


def test_adamw_descends_quadratic():
    tc = TrainConfig(learning_rate=0.05, warmup_steps=1, weight_decay=0.0)
    params = {"w": jnp.asarray([4.0, -2.0])}
    opt = init_opt_state(params)
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(params, g, opt, tc, total_steps=10**6)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05
