"""Batched same-kind handler dispatch (engine step 4).

The grouped vectorized dispatcher must be byte-identical to the PR 1
sequential fold — same traces, same counters (modulo the two batch-path
diagnostics), same world and pool state — on mixed-kind windows, under
duplicate-dst conflict fallback, and when safe events spill past exec_cap.
These tests pin that contract against the sequential oracle and against the
sequential engine path, plus unit coverage for the new conflict mask, the
segmented emit compaction, and the init-state drop accounting.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import t0t1_builder
from repro.core import (
    Engine,
    ScenarioBuilder,
    events as ev,
    merged_engine_trace,
    run_sequential,
    sync,
)
from repro.core import monitoring as mon

NON_DIAG = [i for i in range(mon.N_COUNTERS) if i not in mon.BATCH_DIAG_COUNTERS]


def run_pair(world, own, init_ev, spec, max_windows=20000):
    """Run one scenario under batched and under sequential dispatch."""
    eng_b = Engine(world, own, init_ev, spec, trace_cap=4096)
    st_b = eng_b.run_local(max_windows=max_windows)
    spec_s = dataclasses.replace(spec, batched_dispatch=False)
    eng_s = Engine(world, own, init_ev, spec_s, trace_cap=4096)
    st_s = eng_s.run_local(max_windows=max_windows)
    return st_b, st_s


def engine_trace(st):
    return merged_engine_trace(np.asarray(st.trace), np.asarray(st.trace_n))


def assert_states_identical(st_b, st_s):
    """Batched and sequential dispatch agree byte-for-byte."""
    cb = np.asarray(st_b.counters)
    cs = np.asarray(st_s.counters)
    np.testing.assert_array_equal(cb[:, NON_DIAG], cs[:, NON_DIAG])
    assert engine_trace(st_b) == engine_trace(st_s)
    np.testing.assert_array_equal(np.asarray(st_b.windows), np.asarray(st_s.windows))
    for name, a, b in zip(st_b.world._fields, st_b.world, st_s.world):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)
    for name, a, b in zip(st_b.pool._fields, st_b.pool, st_s.pool):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)


@pytest.mark.parametrize("n_agents", [1, 2])
def test_mixed_kind_windows_match_oracle(n_agents, t0t1_oracle):
    """The T0/T1 study mixes flow, job, write, and tick kinds per window."""
    ow, _oc, otrace = t0t1_oracle
    b, kw = t0t1_builder()
    world, own, init_ev, spec = b.build(n_agents=n_agents, **kw)
    st_b, st_s = run_pair(world, own, init_ev, spec)
    assert engine_trace(st_b) == otrace
    c = np.asarray(st_b.counters).sum(axis=0)
    assert c[mon.C_BATCH_EXEC] + c[mon.C_BATCH_FALLBACK] == c[mon.C_EVENTS]
    w = jax.tree.map(lambda x: np.asarray(x[0]), st_b.world)
    np.testing.assert_allclose(np.asarray(ow.sto_used), w.sto_used, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(ow.lp_lvt), w.lp_lvt)
    assert_states_identical(st_b, st_s)


def test_clean_mixed_kind_window_runs_fully_batched():
    """Distinct-dst events of four kinds in one window: no fallback at all."""
    b = ScenarioBuilder(max_cpu=2, queue_cap=8, max_link=2, max_flow=8)
    farm0 = b.add_farm([4.0])
    farm1 = b.add_farm([2.0])
    sto0 = b.add_storage(500.0, 5000.0, 5.0)
    sto1 = b.add_storage(400.0, 4000.0, 5.0)
    sinks = [b.add_idle_lp() for _ in range(4)]
    job = [8.0, 1.0, -1, -1, 0]
    b.add_event(time=1, kind=ev.K_JOB_SUBMIT, src=farm0, dst=farm0, payload=job)
    b.add_event(time=1, kind=ev.K_JOB_SUBMIT, src=farm1, dst=farm1, payload=job)
    b.add_event(time=1, kind=ev.K_DATA_WRITE, src=sto0, dst=sto0, payload=[15.0])
    b.add_event(time=1, kind=ev.K_DATA_WRITE, src=sto1, dst=sto1, payload=[10.0])
    for lp in sinks:
        b.add_event(time=1, kind=ev.K_NOOP, src=lp, dst=lp)
    built = b.build(n_agents=1, lookahead=4, t_end=200, pool_cap=128)
    world, own, init_ev, spec = built
    _ow, _oc, otrace = run_sequential(world, own, init_ev, spec)
    st_b, st_s = run_pair(world, own, init_ev, spec)
    c = np.asarray(st_b.counters)[0]
    assert c[mon.C_BATCH_FALLBACK] == 0
    assert c[mon.C_BATCH_EXEC] == c[mon.C_EVENTS] > 0
    assert engine_trace(st_b) == otrace
    assert_states_identical(st_b, st_s)


def test_shared_row_conflicts_fall_back_and_match_oracle():
    """Same-window events declaring one component row take the fallback.

    Repeated DATA_WRITEs to one storage LP all address the same storage row
    (a genuine read-modify-write collision), so the rows-keyed conflict mask
    must serialize them; the interleaved NOOPs stay batched.
    """
    b = ScenarioBuilder(max_cpu=2)
    sto0 = b.add_storage(500.0, 5000.0, 5.0)
    sto1 = b.add_storage(400.0, 4000.0, 5.0)
    sinks = [b.add_idle_lp() for _ in range(3)]
    for _ in range(6):
        b.add_event(time=1, kind=ev.K_DATA_WRITE, src=sto0, dst=sto0, payload=[1.0])
        b.add_event(time=1, kind=ev.K_DATA_WRITE, src=sto1, dst=sto1, payload=[1.0])
    for lp in sinks:
        b.add_event(time=1, kind=ev.K_NOOP, src=lp, dst=lp)
    built = b.build(n_agents=1, lookahead=1, t_end=10, pool_cap=64, exec_cap=32)
    world, own, init_ev, spec = built
    _ow, _oc, otrace = run_sequential(world, own, init_ev, spec)
    st_b, st_s = run_pair(world, own, init_ev, spec)
    c = np.asarray(st_b.counters)[0]
    assert c[mon.C_BATCH_FALLBACK] == 12
    assert c[mon.C_BATCH_EXEC] == 3
    assert c[mon.C_EVENTS] == 15
    assert engine_trace(st_b) == otrace
    assert_states_identical(st_b, st_s)


@pytest.mark.parametrize("exec_cap", [1, 2])
def test_spill_interaction_matches_oracle(exec_cap, t0t1_oracle):
    """exec_cap < n_safe: batched windows spill exactly like sequential ones."""
    _ow, _oc, otrace = t0t1_oracle
    b, kw = t0t1_builder()
    world, own, init_ev, spec = b.build(n_agents=1, exec_cap=exec_cap, **kw)
    st_b, st_s = run_pair(world, own, init_ev, spec)
    c = np.asarray(st_b.counters).sum(axis=0)
    assert c[mon.C_EXEC_SPILL] > 0
    assert engine_trace(st_b) == otrace
    assert_states_identical(st_b, st_s)


def test_conflict_mask_flags_shared_component_row():
    """Distinct LPs writing one component row still conflict; table 0 never."""
    safe = jnp.ones((4,), bool)
    table = jnp.asarray([1, 1, 2, 0], jnp.int32)
    res = jnp.asarray([5, 5, 5, 5], jnp.int32)
    got = sync.conflict_mask(safe, table, res, n_res=16)
    assert np.asarray(got).tolist() == [True, True, False, False]


def test_conflict_mask_ignores_rows_without_component_writes():
    """table 0 rows (no declared component row) never conflict — even many of
    them: their only shared state are the engine-owned per-LP columns, whose
    segment scatters commute (max / idempotent set)."""
    safe = jnp.asarray([True, True, True, False])
    table = jnp.zeros((4,), jnp.int32)
    res = jnp.zeros((4,), jnp.int32)
    got = sync.conflict_mask(safe, table, res, n_res=16)
    assert np.asarray(got).tolist() == [False, False, False, False]


def test_conflict_mask_respects_safe_mask():
    """An unsafe row sharing a component row with a safe one is no conflict."""
    safe = jnp.asarray([True, False])
    table = jnp.asarray([3, 3], jnp.int32)
    res = jnp.asarray([1, 1], jnp.int32)
    got = sync.conflict_mask(safe, table, res, n_res=16)
    assert np.asarray(got).tolist() == [False, False]


def test_compact_batch_keeps_order_and_counts_drops():
    base = ev.empty_batch(6)
    batch = base._replace(
        time=jnp.asarray([9, 1, 9, 2, 3, 4], jnp.int32),
        seq=jnp.asarray([10, 11, 12, 13, 14, 15], jnp.int32),
        valid=jnp.asarray([False, True, False, True, True, True]),
    )
    out, n_valid, dropped = ev.compact_batch(batch, 3)
    assert int(n_valid) == 4
    assert int(dropped) == 1
    assert np.asarray(out.time).tolist() == [1, 2, 3]
    assert np.asarray(out.seq).tolist() == [11, 13, 14]
    assert np.asarray(out.valid).all()
    wide, n_valid, dropped = ev.compact_batch(batch, 8)
    assert int(n_valid) == 4
    assert int(dropped) == 0
    assert np.asarray(wide.valid).tolist() == [True] * 4 + [False] * 4
    assert np.asarray(wide.time).tolist()[:4] == [1, 2, 3, 4]
    assert np.asarray(wide.time).tolist()[4:] == [int(ev.T_INF)] * 4


def test_init_state_counts_seed_pool_overflow():
    """ROADMAP bugfix: oversubscribed seeds land in C_DROP_POOL, not silence."""
    b = ScenarioBuilder(max_cpu=2)
    farm = b.add_farm([5.0])
    for i in range(10):
        b.add_event(time=1 + i, kind=ev.K_NOOP, src=farm, dst=farm)
    world, own, init_ev, spec = b.build(
        n_agents=1,
        lookahead=1,
        t_end=50,
        pool_cap=4,
    )
    st = Engine(world, own, init_ev, spec).init_state()
    assert np.asarray(st.counters)[0, mon.C_DROP_POOL] == 6


def check_batched_equals_sequential(p):
    """Shared property body: one scenario, both dispatch paths, identical."""
    b = ScenarioBuilder(max_cpu=4, queue_cap=8, max_link=4, max_flow=16)
    t0 = b.add_regional_center(
        n_cpu=2,
        cpu_power=p["p0"],
        disk=400.0,
        tape=4000.0,
        tape_rate=5.0,
    )
    t1 = b.add_regional_center(
        n_cpu=2,
        cpu_power=p["p1"],
        disk=250.0,
        tape=2500.0,
        tape_rate=5.0,
    )
    wan = b.add_net_region(link_bws=[p["bw0"], p["bw1"]], link_lats=[5, 5])
    payload = [
        p["size"],
        0,
        -1,
        -1,
        t1["farm"],
        ev.K_JOB_SUBMIT,
        t1["storage"],
        ev.K_DATA_WRITE,
    ]
    b.add_generator(
        target_lp=wan,
        kind=ev.K_FLOW_START,
        payload=payload,
        interval=p["interval"],
        count=p["count"],
    )
    del t0
    world, own, init_ev, spec = b.build(
        n_agents=2,
        lookahead=p["lookahead"],
        t_end=3000,
        pool_cap=256,
        exec_cap=p["exec_cap"],
        work_per_mb=2.0,
    )
    st_b, st_s = run_pair(world, own, init_ev, spec)
    assert_states_identical(st_b, st_s)


def test_batched_equals_sequential_fixed_examples():
    """Seeded spot-checks of the property (runs without hypothesis)."""
    rng = np.random.RandomState(0)
    for _ in range(2):
        p = dict(
            p0=float(rng.uniform(1.0, 20.0)),
            p1=float(rng.uniform(1.0, 20.0)),
            bw0=float(rng.uniform(0.1, 8.0)),
            bw1=float(rng.uniform(0.1, 8.0)),
            size=float(rng.uniform(5.0, 120.0)),
            interval=int(rng.randint(5, 60)),
            count=int(rng.randint(2, 10)),
            lookahead=int(rng.randint(1, 4)),
            exec_cap=int(rng.choice([1, 3, 17, 256])),
        )
        check_batched_equals_sequential(p)


try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    scenario_params = st.fixed_dictionaries(
        dict(
            p0=st.floats(1.0, 20.0),
            p1=st.floats(1.0, 20.0),
            bw0=st.floats(0.1, 8.0),
            bw1=st.floats(0.1, 8.0),
            size=st.floats(5.0, 120.0),
            interval=st.integers(5, 60),
            count=st.integers(2, 10),
            lookahead=st.integers(1, 4),
            exec_cap=st.sampled_from([1, 3, 17, 256]),
        )
    )

    @settings(max_examples=6, deadline=None)
    @given(scenario_params)
    def test_batched_equals_sequential_property(p):
        """Batched and sequential dispatch produce identical traces and
        counters (and world/pool state) on randomized scenarios."""
        check_batched_equals_sequential(p)
