"""Render the roofline table + dry-run summary from results/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os

HBM_PER_CHIP = 16e9  # v5e


def load(results_dir="results/dryrun", tag_filter=""):
    rows = []
    for p in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        name = os.path.basename(p)[:-5]
        parts = name.split("__")
        r["tag"] = parts[3] if len(parts) > 3 else ""
        if r["tag"] != tag_filter:
            continue
        rows.append(r)
    return rows


def fits(r) -> str:
    m = r.get("memory_analysis", {})
    if not m:
        return "?"
    total = (m.get("argument_size_in_bytes", 0) + m.get("temp_size_in_bytes", 0)
             + m.get("output_size_in_bytes", 0)
             - m.get("alias_size_in_bytes", 0))
    return f"{total / 1e9:.1f}" + ("" if total <= HBM_PER_CHIP else "!")


def table(rows, mesh=None):
    out = ["| arch | shape | mesh | t_comp (s) | t_mem (s) | t_coll (s) | "
           "bottleneck | useful | roofline frac | mem GB/chip |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"{r.get('status')} | | | | | | |")
            continue
        if mesh and r["mesh"] != mesh:
            continue
        t = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {t['t_compute_s']:.4f} | {t['t_memory_s']:.4f} "
            f"| {t['t_collective_s']:.4f} | {t['bottleneck']} "
            f"| {t['useful_ratio']:.2f} | {t['roofline_fraction']:.3f} "
            f"| {fits(r)} |")
    return "\n".join(out)


def interesting_cells(rows):
    """worst roofline fraction / most collective-bound / paper-representative."""
    ok = [r for r in rows if r.get("status") == "ok"
          and r["mesh"] == "single" and r["shape"] != "long_500k"]
    worst = min(ok, key=lambda r: r["roofline"]["roofline_fraction"])
    coll = max(ok, key=lambda r: (r["roofline"]["t_collective_s"]
                                  / max(max(r["roofline"]["t_compute_s"],
                                            r["roofline"]["t_memory_s"]),
                                        1e-12)))
    return worst, coll


if __name__ == "__main__":
    rows = load()
    print(table(rows))
    w, c = interesting_cells(rows)
    print(f"\nworst-fraction cell: {w['arch']} x {w['shape']} "
          f"(frac {w['roofline']['roofline_fraction']:.3f})")
    print(f"most collective-bound: {c['arch']} x {c['shape']} "
          f"(t_coll/t_major "
          f"{c['roofline']['t_collective_s'] / max(max(c['roofline']['t_compute_s'], c['roofline']['t_memory_s']), 1e-12):.2f})")
