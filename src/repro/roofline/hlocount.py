"""Loop-aware FLOP / byte / collective accounting from HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE regardless of
trip count (verified in tests), which silently undercounts scan-over-layers
programs by ~n_layers x. This module re-counts structurally:

* ``stablehlo_costs(lowered.as_text())`` — walks the pre-partitioning StableHLO,
  multiplies every region's cost by the enclosing ``stablehlo.while`` trip counts
  (parsed from the loop condition's ``compare LT`` against a constant), and sums
  dot_general FLOPs (2 * result_elems * contracted_elems) and dot operand/result
  bytes. Shapes there are GLOBAL (per-fleet), so divide by chip count.

* ``collective_costs(compiled.as_text())`` — walks the post-SPMD HLO module,
  resolves ``while(..., body=%B, condition=%C)`` computation references,
  multiplies nested trip counts, and sums result-shape bytes per collective kind.
  Post-SPMD shapes are PER-DEVICE, so these are per-chip bytes directly.

Both parsers are pure text walks — deterministic, backend-independent, and
O(module size).
"""
from __future__ import annotations

import math
import re

# --------------------------------------------------------------------------
# StableHLO side (FLOPs / dot bytes, global shapes)
# --------------------------------------------------------------------------

_TENSOR_RE = re.compile(r"tensor<([^>]*)>")
_CONST_RE = re.compile(r"%([\w#.]+)\s*=\s*stablehlo\.constant dense<(\d+)>")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
                "i64": 8, "ui64": 8, "i32": 4, "ui32": 4, "i16": 2, "ui16": 2,
                "i8": 1, "ui8": 1, "i1": 1}


def _tensor_dims(t: str) -> tuple[list[int], int]:
    """'2x64x16xf32' -> ([2,64,16], 4); 'f32' -> ([], 4)."""
    parts = t.split("x")
    dims = []
    for p in parts[:-1]:
        if p.isdigit():
            dims.append(int(p))
    dt = parts[-1]
    return dims, _DTYPE_BYTES.get(dt, 4)


class _Node:
    __slots__ = ("header", "lines", "children")

    def __init__(self, header=""):
        self.header = header
        self.lines: list[str] = []
        self.children: list["_Node"] = []


def _parse_tree(text: str) -> _Node:
    """Brace-structured parse. Handles MLIR's '} do {' pop-then-push lines and
    attribute braces like 'dense<...> {...}' that open and close on one line."""
    root = _Node("<module>")
    stack = [root]
    for raw in text.splitlines():
        line = raw.strip()
        events = "".join(c for c in line if c in "{}")
        # cancel balanced '{}' attribute pairs within the line
        while "{}" in events:
            events = events.replace("{}", "")
        if not events:
            stack[-1].lines.append(line)
            continue
        stack[-1].lines.append(line)
        for c in events:
            if c == "{":
                node = _Node(line)
                stack[-1].children.append(node)
                stack.append(node)
            else:
                if len(stack) > 1:
                    stack.pop()
    return root


def _dot_cost(line: str) -> tuple[float, float]:
    """(flops, bytes) of one stablehlo.dot_general line."""
    m = re.search(r"contracting_dims\s*=\s*\[([0-9,\s]*)\]\s*x\s*\[", line)
    tensors = _TENSOR_RE.findall(line)
    if not m or len(tensors) < 3:
        return 0.0, 0.0
    lhs_dims, lhs_b = _tensor_dims(tensors[0])
    rhs_dims, rhs_b = _tensor_dims(tensors[1])
    out_dims, out_b = _tensor_dims(tensors[-1])
    cdims = [int(x) for x in m.group(1).split(",") if x.strip()]
    contracted = math.prod(lhs_dims[c] for c in cdims if c < len(lhs_dims))
    out_elems = math.prod(out_dims) if out_dims else 1
    flops = 2.0 * out_elems * max(contracted, 1)
    byts = (math.prod(lhs_dims or [1]) * lhs_b
            + math.prod(rhs_dims or [1]) * rhs_b
            + out_elems * out_b)
    return flops, byts


def _cond_trip(cond: _Node, constants: dict[str, int]) -> int:
    """Trip count from a while condition region (compare LT against constant)."""
    local = {name: int(val)
             for name, val in _CONST_RE.findall("\n".join(cond.lines))}
    blob = "\n".join(cond.lines)
    m = re.search(r"stablehlo\.compare\s+LT,\s*%[\w#.]+,\s*%([\w#.]+)", blob)
    if m:
        name = m.group(1)
        if name in local:
            return max(local[name], 1)
        if name in constants:
            return max(constants[name], 1)
    if local:
        return max(max(local.values()), 1)
    return 1


def _node_cost(node: _Node, constants, funcs, memo) -> tuple[float, float]:
    flops = byts = 0.0
    for ln in node.lines:
        if "stablehlo.dot_general" in ln:
            f, b = _dot_cost(ln)
            flops += f
            byts += b
        else:
            cm = re.search(r"func\.call\s+@([\w#$.\-]+)", ln)
            if cm and cm.group(1) in funcs:
                f, b = _func_cost(cm.group(1), constants, funcs, memo)
                flops += f
                byts += b
    i = 0
    children = node.children
    while i < len(children):
        ch = children[i]
        hdr = ch.header
        if hdr.endswith("cond {") or re.search(r"\bcond\s*{\s*$", hdr):
            trip = _cond_trip(ch, constants)
            if i + 1 < len(children) and "do" in children[i + 1].header:
                f, b = _node_cost(children[i + 1], constants, funcs, memo)
                flops += trip * f
                byts += trip * b
                i += 2
                continue
            i += 1
            continue
        f, b = _node_cost(ch, constants, funcs, memo)
        flops += f
        byts += b
        i += 1
    return flops, byts


def _func_cost(name, constants, funcs, memo):
    if name in memo:
        return memo[name]
    memo[name] = (0.0, 0.0)  # break recursion
    memo[name] = _node_cost(funcs[name], constants, funcs, memo)
    return memo[name]


def stablehlo_costs(text: str) -> dict:
    """Global (fleet-level) flops + dot-traffic bytes with loop multipliers."""
    constants = {name: int(val) for name, val in _CONST_RE.findall(text)}
    root = _parse_tree(text)
    # function table: nodes whose header declares func.func @name
    funcs: dict[str, _Node] = {}
    stack = [root]
    while stack:
        n = stack.pop()
        m = re.search(r"func\.func.*@([\w#$.\-]+)\s*\(", n.header)
        if m:
            funcs[m.group(1)] = n
        stack.extend(n.children)
    memo: dict[str, tuple[float, float]] = {}
    if "main" in funcs:
        flops, byts = _func_cost("main", constants, funcs, memo)
    else:
        flops, byts = _node_cost(root, constants, funcs, memo)
    return {"flops": flops, "dot_bytes": byts}


# --------------------------------------------------------------------------
# Post-SPMD HLO side (collectives, per-device shapes)
# --------------------------------------------------------------------------

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_HLO_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_HLO_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}


def _hlo_shape_bytes(s: str) -> int:
    m = _HLO_SHAPE_RE.match(s)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _HLO_DTYPE_BYTES.get(dt, 4)


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in text.splitlines():
        m = re.match(r"\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->.*{", line)
        if m and ("{" in line):
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
                continue
            comps[cur].append(line)
    return comps


def _comp_trip(lines: list[str]) -> int:
    """Trip count heuristic for a while condition computation."""
    consts = {}
    for ln in lines:
        m = re.match(r"\s*%?([\w.\-]+)\s*=\s*s32\[\]\s*constant\((\d+)\)", ln)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for ln in lines:
        if "compare(" in ln and "direction=LT" in ln:
            ops = re.findall(r"%([\w.\-]+)", ln)
            for o in ops[1:]:
                if o in consts:
                    return max(consts[o], 1)
    if consts:
        return max(consts.values())
    return 1


def collective_costs(compiled_text: str) -> dict[str, float]:
    """Per-device collective bytes by kind, with while-loop trip multipliers."""
    comps = _split_computations(compiled_text)

    entry = None
    for name in comps:
        if "ENTRY" in compiled_text.split(name)[0].splitlines()[-1:][0:1] or []:
            pass
    # ENTRY computation: the one declared with "ENTRY" keyword
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", compiled_text)
    entry = m.group(1) if m else next(iter(comps), None)

    def comp_cost(name: str, seen: tuple) -> dict[str, float]:
        out = {k: 0.0 for k in _COLLECTIVES}
        if name not in comps or name in seen:
            return out
        for ln in comps[name]:
            ls = ln.strip()
            mm = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", ls)
            if mm:
                shapes_str, op = mm.groups()
                for k in _COLLECTIVES:
                    if op == k or op.startswith(k + "-start") or op.startswith(
                            k + "."):
                        tot = sum(_hlo_shape_bytes(s) for s in re.findall(
                            r"[a-z0-9]+\[[0-9,]*\]", shapes_str))
                        out[k] += tot
                        break
            wm = re.search(r"while\(.*\).*condition=%?([\w.\-]+).*body=%?"
                           r"([\w.\-]+)", ls)
            if not wm:
                wm2 = re.search(r"body=%?([\w.\-]+).*condition=%?([\w.\-]+)", ls)
                if wm2:
                    body, cond = wm2.group(1), wm2.group(2)
                else:
                    continue
            else:
                cond, body = wm.group(1), wm.group(2)
            trip = _comp_trip(comps.get(cond, []))
            sub = comp_cost(body, seen + (name,))
            for k in _COLLECTIVES:
                out[k] += trip * sub[k]
        # non-while callees (fusions don't contain collectives; calls may)
        for ln in comps[name]:
            cm = re.search(r"(?:call|to_apply)=%?([\w.\-]+)", ln)
            if cm and "while" not in ln:
                sub = comp_cost(cm.group(1), seen + (name,))
                for k in _COLLECTIVES:
                    out[k] += sub[k]
        return out

    return comp_cost(entry, ()) if entry else {k: 0.0 for k in _COLLECTIVES}
