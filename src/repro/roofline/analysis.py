"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, all in seconds:
  compute    = HLO_FLOPs_per_chip / peak_FLOP/s
  memory     = HLO_bytes_per_chip / HBM_bw
  collective = collective_bytes_per_chip / link_bw

``compiled.cost_analysis()`` reports the *partitioned per-device* module, so the
per-chip convention is native (verified in tests against analytic 6ND counts).
Collective bytes are not in cost_analysis: we parse the post-SPMD HLO text and sum
result-shape bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute. Conventions (documented, deterministic): all-reduce counts
2x its payload (ring reduce-scatter + all-gather); others count their result
bytes once. MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) gives the
useful-compute ratio that flags remat/redundancy waste.
"""
from __future__ import annotations

import dataclasses
import re

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[16,4096,6144]' -> bytes. Tuple shapes handled by the caller."""
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result bytes per collective kind from post-SPMD HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        # result "<shape> op-name(" — find which collective this line defines
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", ls)
        if not m:
            continue
        shapes_str, op = m.groups()
        base = op.rstrip("-start").rstrip(".")
        kind = None
        for k in _COLLECTIVES:
            if op == k or op == k + "-start" or op.startswith(k + "."):
                kind = k
                break
        if kind is None:
            continue
        # result may be a tuple: (bf16[...], bf16[...])
        total = sum(_shape_bytes(s) for s in re.findall(
            r"[a-z0-9]+\[[0-9,]*\]", shapes_str))
        out[kind] += total
    return out


def total_collective_bytes(per_kind: dict[str, int]) -> int:
    tot = 0
    for k, v in per_kind.items():
        tot += 2 * v if k == "all-reduce" else v
    return tot


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_by_kind: dict
    model_flops_total: float

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW_PER_LINK

    @property
    def bottleneck(self) -> str:
        t = {"compute": self.t_compute, "memory": self.t_memory,
             "collective": self.t_collective}
        return max(t, key=t.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs (remat & redundancy waste detector)."""
        hlo_total = self.flops_per_chip * self.chips
        return self.model_flops_total / hlo_total if hlo_total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / achievable step time (max of the three terms)."""
        t_useful = (self.model_flops_total / self.chips) / PEAK_FLOPS_BF16
        t_step = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / t_step if t_step else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops_total,
            "hlo_flops_per_chip": self.flops_per_chip,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "coll_by_kind": self.coll_by_kind,
        }


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6*N*D (dense) or 6*N_active*D (MoE); D = tokens processed per step.

    Train counts fwd+bwd (6); prefill counts fwd only (2); decode counts fwd for
    global_batch single tokens. Enc-dec splits N across the two stacks since
    they see different token counts (encoder: seq_len frames; decoder: the
    448-token transcript).
    """
    n = cfg.active_param_count if cfg.n_experts else cfg.param_count
    mult = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[shape.mode]
    if cfg.encoder_layers:
        frac_enc = cfg.encoder_layers / (cfg.encoder_layers + cfg.n_layers)
        n_enc, n_dec = n * frac_enc, n * (1 - frac_enc)
        if shape.mode == "decode":
            return 2.0 * n_dec * shape.global_batch
        d_enc = shape.global_batch * shape.seq_len
        d_dec = shape.global_batch * cfg.decoder_len
        return mult * (n_enc * d_enc + n_dec * d_dec)
    if shape.mode == "decode":
        return 2.0 * n * shape.global_batch
    return mult * n * shape.global_batch * shape.seq_len


def terms_from_artifacts(arch: str, shape_cfg: ShapeConfig, mesh_name: str,
                         chips: int, cfg: ModelConfig, stablehlo_text: str,
                         compiled_text: str) -> RooflineTerms:
    """Loop-aware roofline terms (see hlocount.py for counting conventions).

    compute/memory come from the pre-partition StableHLO (global shapes / chips);
    collectives from the post-SPMD module (per-device shapes), both with while
    trip-count multiplication. Memory adds one read of the resident parameters
    per step (dot-operand traffic alone misses weight streaming when a dimension
    folds into a fused op).
    """
    from repro.roofline import hlocount
    sc = hlocount.stablehlo_costs(stablehlo_text)
    per_kind = hlocount.collective_costs(compiled_text)
    param_bytes = cfg.param_count * 2.0  # bf16 residents
    return RooflineTerms(
        arch=arch, shape=shape_cfg.name, mesh=mesh_name, chips=chips,
        flops_per_chip=sc["flops"] / chips,
        bytes_per_chip=sc["dot_bytes"] / chips + param_bytes / chips,
        coll_bytes_per_chip=float(total_collective_bytes(per_kind)),
        coll_by_kind={k: float(v) for k, v in per_kind.items()},
        model_flops_total=model_flops(cfg, shape_cfg),
    )
