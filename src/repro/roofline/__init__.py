"""repro.roofline subpackage."""
