"""Batched serving engine: continuous prefill + decode over a request queue.

Simple production shape: fixed decode batch of B slots; arriving requests are
prefilled (one jit'd prefill per request batch) and their KV/rnn state packed
into free slots; every engine tick decodes one token for all live slots. Slots
free on EOS/max-tokens. Greedy or temperature sampling.

The per-slot state packing relies on every family exposing the same decode-state
pytree (models/model.py), so MoE / SSM / enc-dec serve through one engine.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass
class Request:
    rid: int
    tokens: list[int]
    max_new: int = 32
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, params, *, batch_slots: int = 4,
                 prompt_len: int = 64, temperature: float = 0.0, seed: int = 0):
        self.model = model
        self.params = params
        self.b = batch_slots
        self.prompt_len = prompt_len
        self.temperature = temperature
        self.rng = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(model.prefill_fn)
        self._decode = jax.jit(model.decode_fn)
        self.state = None
        self.slot_req: list[Request | None] = [None] * batch_slots
        self.last_tok = np.zeros((batch_slots, 1), np.int32)
        self.length = 0

    # ------------------------------------------------------------- admission
    def admit(self, reqs: list[Request]):
        """Prefill a full batch of requests into the decode slots."""
        assert len(reqs) <= self.b
        pad = self.prompt_len
        toks = np.zeros((self.b, pad), np.int32)
        for i, r in enumerate(reqs):
            t = r.tokens[-pad:]
            toks[i, pad - len(t):] = t       # left-pad (uniform lengths)
        logits, state = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        self.state = state
        self.length = pad
        nxt = self._sample(logits)
        for i, r in enumerate(reqs):
            self.slot_req[i] = r
            r.out.append(int(nxt[i]))
        self.last_tok = np.asarray(nxt)[:, None].astype(np.int32)

    def _sample(self, logits):
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        self.rng, k = jax.random.split(self.rng)
        return jax.random.categorical(k, logits / self.temperature, axis=-1)

    # ------------------------------------------------------------------ tick
    def tick(self):
        """Decode one token for every live slot."""
        logits, self.state = self._decode(
            self.params, self.state, jnp.asarray(self.last_tok),
            jnp.int32(self.length))
        self.length += 1
        nxt = np.asarray(self._sample(logits))
        for i, r in enumerate(self.slot_req):
            if r is None or r.done:
                continue
            r.out.append(int(nxt[i]))
            if len(r.out) >= r.max_new:
                r.done = True
        self.last_tok = nxt[:, None].astype(np.int32)

    def run(self, reqs: list[Request], max_ticks: int = 64):
        self.admit(reqs[: self.b])
        for _ in range(max_ticks):
            if all(r is None or r.done for r in self.slot_req):
                break
            self.tick()
        return reqs
