"""repro.serve subpackage."""
