"""AdamW with warmup + cosine decay and global-norm clipping (no external deps).

Optimizer state is a pytree congruent with params (m, v in f32), so it shards with
the same logical rules as the weights (ZeRO-style: the "fsdp" axes of the params
shard the moments identically).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class OptState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def init_opt_state(params, opt_dtype: str = "float32") -> OptState:
    dt = jnp.dtype(opt_dtype)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree.map(jnp.copy, zeros))


def lr_schedule(step, tc: TrainConfig, total_steps: int = 10_000):
    warm = jnp.minimum(step / jnp.maximum(tc.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - tc.warmup_steps)
                    / jnp.maximum(total_steps - tc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return tc.learning_rate * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(params, grads, opt: OptState, tc: TrainConfig,
                 total_steps: int = 10_000):
    """Returns (new_params, new_opt, metrics). Grads may be bf16; math is f32."""
    step = opt.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, tc.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(step, tc, total_steps)
    b1c = 1.0 - tc.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - tc.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = tc.b1 * m.astype(jnp.float32) + (1.0 - tc.b1) * g
        v2 = tc.b2 * v.astype(jnp.float32) + (1.0 - tc.b2) * jnp.square(g)
        mh = m2 / b1c
        vh = v2 / b2c
        delta = mh / (jnp.sqrt(vh) + tc.eps) + tc.weight_decay * p.astype(
            jnp.float32)
        p2 = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p2, m2.astype(m.dtype), v2.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt.m)
    flat_v = jax.tree.leaves(opt.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(step=step, m=new_m, v=new_v), metrics
