"""Training loop: microbatched grad accumulation, optional gradient compression,
checkpoint/restart, straggler monitoring hooks.

``make_train_step`` builds the jit-able step used both by launch/train.py (real
runs) and launch/dryrun.py (lower+compile only). Buffers are donated; grads
accumulate over ``microbatches`` via lax.scan (compute/comm overlap: each
microbatch's psum overlaps the next microbatch's fwd under XLA latency-hiding
scheduling, and grads crossing the pod axis can be int8-compressed).
"""
from __future__ import annotations

import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ModelConfig, TrainConfig
from repro.data import pipeline as dp
from repro.ft.straggler import StragglerMonitor
from repro.models.model import Model
from repro.train import compression as comp
from repro.train import optimizer as opt


def make_train_step(model: Model, tc: TrainConfig, total_steps: int = 10_000
                    ) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    With tc.microbatches > 1 the batch's leading dim is split and gradients are
    accumulated in f32 across a lax.scan (remat inside each microbatch's fwd).
    """

    def loss_for(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_for, has_aux=True)

    def step(params, opt_state, batch):
        n = tc.microbatches
        if n > 1:
            def split(x):
                b = x.shape[0] if x.ndim >= 1 else 1
                # leading-batch arrays are split; (3, b, s) positions handled too
                if x.ndim >= 2 and x.shape[0] == 3:  # positions3
                    return x.reshape(3, n, x.shape[1] // n, *x.shape[2:]
                                     ).swapaxes(0, 1)
                return x.reshape(n, b // n, *x.shape[1:])

            micro = jax.tree.map(split, batch)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)

            def acc_fn(carry, mb):
                g_acc, l_acc = carry
                (loss, _), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss), None

            (g_sum, loss_sum), _ = jax.lax.scan(acc_fn, (zeros, 0.0), micro)
            grads = jax.tree.map(lambda g: g / n, g_sum)
            loss = loss_sum / n
            metrics: dict[str, Any] = {}
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        if tc.compress_grads:
            # int8 round-trip with error feedback folded into opt state is set up
            # by the caller (error_fb tree rides in opt_state.m's structure); the
            # in-graph quantize/dequantize makes XLA emit an int8 all-reduce on
            # the slowest (pod) axis when sharded accordingly.
            q, _ = comp.compress_tree(grads, jax.tree.map(
                lambda g: jnp.zeros(g.shape, jnp.float32), grads))
            grads = comp.decompress_tree(q)

        params2, opt2, om = opt.adamw_update(params, grads, opt_state, tc,
                                             total_steps)
        om["loss"] = loss
        return params2, opt2, {**metrics, **om}

    return step


def train(model: Model, tc: TrainConfig, *, steps: int, data_cfg: dp.DataConfig,
          ckpt_dir: str | None = None, ckpt_every: int = 100,
          log_every: int = 10, extra_batch: dict | None = None):
    """Single-host training driver with checkpoint/restart + straggler monitor."""
    rng = jax.random.PRNGKey(tc.seed)
    params, _ = model.init(rng)
    opt_state = opt.init_opt_state(params, tc.opt_dtype)
    start = 0

    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    if ckpt and ckpt.latest_step() is not None:
        start, (params, opt_state) = ckpt.restore((params, opt_state))
        print(f"[train] restored step {start}")

    step_fn = jax.jit(make_train_step(model, tc, total_steps=steps),
                      donate_argnums=(0, 1))
    monitor = StragglerMonitor(n_hosts=1)
    history = []
    for step, batch in dp.batch_iterator(data_cfg, start_step=start):
        if step >= steps:
            break
        if extra_batch:
            batch = {**batch, **extra_batch}
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        monitor.record(host=0, step=step, seconds=dt)
        history.append(loss)
        if step % log_every == 0:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
        if ckpt and step > start and step % ckpt_every == 0:
            ckpt.save(step, (params, opt_state))
    if ckpt:
        ckpt.wait()
    return params, opt_state, history
