"""repro.train subpackage."""
