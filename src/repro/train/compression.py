"""Gradient compression for cross-pod (DCN) reductions: int8 + error feedback.

At 2x16x16 the "pod" axis all-reduce crosses data-center network; int8 quantization
cuts those bytes 2x vs bf16 (4x vs f32) with the quantization error carried
forward per-parameter (error feedback preserves Adam convergence — Karimireddy et
al.; verified on a quadratic in tests/test_substrate.py).

Scope note (honest): under automatic SPMD the gradient reduction is inserted by
the partitioner inside the backward pass, so the in-graph quantize/dequantize here
compresses gradient *values* after reduction. Binding the int8 payload to the
pod-axis collective itself requires the manual-collective training step
(shard_map DP with explicit psum on the quantized tree) — the pipeline below is
the drop-in building block for that step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array):
    """Per-tensor symmetric int8. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, error_fb):
    """Quantize grads + carried error; returns (quantized tree, new error tree).

    error_fb is pytree-congruent f32 residuals (zeros at step 0).
    """
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, scale = quantize_int8(target)
        deq = dequantize_int8(q, scale)
        return (q, scale), target - deq

    flat_g, td = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_fb)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    qtree = jax.tree.unflatten(td, [o[0] for o in outs])
    etree = jax.tree.unflatten(td, [o[1] for o in outs])
    return qtree, etree


def decompress_tree(qtree):
    return jax.tree.map(lambda pair: dequantize_int8(*pair), qtree,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and len(x) == 2 and not isinstance(x[0], tuple))


def init_error_fb(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(x: jax.Array, axis: str) -> jax.Array:
    """int8 all-reduce over ``axis``: the DCN-crossing collective itself.

    Protocol: (1) all-reduce-max of the per-shard absmax (8 bytes) fixes a
    common scale; (2) shards quantize to int8 and psum in int32 (numerically
    exact for <= 2^23 shards); (3) dequantize. Payload: 1 byte/element + eps.
    Works under shard_map on a real pod axis and under vmap(axis_name) in
    tests (tests/test_substrate.py::test_compressed_psum_matches_psum).
    """
    xf = x.astype(jnp.float32)
    amax = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    return total.astype(jnp.float32) * scale


def compressed_psum_tree(tree, axis: str):
    return jax.tree.map(lambda x: compressed_psum(x, axis), tree)
