"""Shared neural layers: norms, RoPE (incl. M-RoPE), attention (chunked-online-
softmax XLA path + KV caches + sliding window), MLPs, embeddings.

All functions are pure; parameters are plain dicts built by ``init_*`` helpers that
return ``Annotated`` leaves (array + logical axis names) so the model builder can
derive sharding specs without a second source of truth.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.sharding import Annotated


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ----------------------------------------------------------------- init utils
def _norm_init(key, shape, scale=1.0):
    return jnp.ones(shape, jnp.float32) * scale


def dense_init(key, shape, names, dtype, scale=None):
    fan_in = shape[0] if len(shape) >= 1 else 1
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    w = jax.random.normal(key, shape, jnp.float32) * std
    return Annotated(w.astype(dtype), names)


# ------------------------------------------------------------------ RMSNorm
def rmsnorm(x, gamma, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma.astype(x.dtype)


def init_rmsnorm(d):
    return Annotated(jnp.ones((d,), jnp.float32), ("embed",))


def layernorm(x, gamma, beta, eps):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y.astype(x.dtype) * gamma.astype(x.dtype) + beta.astype(x.dtype))


# --------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                                   # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs          # (..., s, hd/2)
    cos = jnp.cos(ang)[..., None, :]                                # (..., s, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_m_rope(x: jax.Array, positions3: jax.Array, theta: float) -> jax.Array:
    """Qwen2-VL multimodal RoPE: positions3 (3, ..., seq) = (temporal, h, w).

    The head_dim is split 2:1:1 between the three position streams (the published
    mrope_section for Qwen2-VL is [16, 24, 24] of 64 pair-slots; we use the same
    proportions parametrically).
    """
    hd = x.shape[-1]
    half = hd // 2
    s_t = half // 2
    s_h = (half - s_t) // 2
    s_w = half - s_t - s_h
    freqs = rope_freqs(hd, theta)                                   # (half,)
    sections = [s_t, s_h, s_w]
    pos_parts = []
    off = 0
    for i, sec in enumerate(sections):
        p = positions3[i][..., None].astype(jnp.float32) * freqs[off:off + sec]
        pos_parts.append(p)
        off += sec
    ang = jnp.concatenate(pos_parts, axis=-1)                       # (..., s, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- attention
class KVCache(NamedTuple):
    k: jax.Array    # (batch, cache_len, n_kv, head_dim) — cfg.kv_dtype storage
    v: jax.Array
    length: jax.Array  # i32 scalar — valid prefix


def cache_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.kv_dtype) if cfg.kv_dtype else dtype_of(cfg)


NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, hd), ("fsdp", "heads", "head"), dt),
        "wk": dense_init(ks[1], (d, kv, hd), ("fsdp", "kv_heads", "head"), dt),
        "wv": dense_init(ks[2], (d, kv, hd), ("fsdp", "kv_heads", "head"), dt),
        "wo": dense_init(ks[3], (h, hd, d), ("heads", "head", "fsdp"), dt,
                         scale=1.0 / math.sqrt(h * hd)),
    }
    if cfg.use_bias:
        p["bq"] = Annotated(jnp.zeros((h, hd), jnp.float32), ("heads", "head"))
        p["bk"] = Annotated(jnp.zeros((kv, hd), jnp.float32), ("kv_heads", "head"))
        p["bv"] = Annotated(jnp.zeros((kv, hd), jnp.float32), ("kv_heads", "head"))
    return p


def _qkv(p, x, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.use_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    return q, k, v


def _chunked_attention(q, k, v, *, causal: bool, window: int, q_offset,
                       kv_len_valid, chunk_q: int, chunk_kv: int,
                       scheme: str = "rect"):
    """Online-softmax attention, O(chunk) memory — the XLA flash-equivalent.

    q: (b, sq, h, hd); k/v: (b, skv, n_kv, hd). GQA via head grouping. ``q_offset``
    is the absolute position of q[0] (decode / prefill continuation).
    ``kv_len_valid`` masks cache tails. ``scheme='tri'`` skips fully-masked KV
    chunks for causal prefill (§Perf knob) by unrolling the outer loop.
    """
    b, sq, h, hd = q.shape
    skv, n_kv = k.shape[1], k.shape[2]
    group = h // n_kv
    scale = 1.0 / math.sqrt(hd)

    def divisor_chunk(n, c):
        c = min(c, n)
        while n % c:
            c -= 1
        return c

    cq = divisor_chunk(sq, chunk_q)
    ck = divisor_chunk(skv, chunk_kv)
    n_q, n_k = sq // cq, skv // ck
    qr = q.reshape(b, n_q, cq, n_kv, group, hd)
    kr = k.reshape(b, n_k, ck, n_kv, hd)
    vr = v.reshape(b, n_k, ck, n_kv, hd)

    kv_pos = jnp.arange(skv, dtype=jnp.int32).reshape(n_k, ck)

    def q_block(qi, qblk):
        # qblk: (b, cq, n_kv, group, hd)
        q_pos = q_offset + qi * cq + jnp.arange(cq, dtype=jnp.int32)  # (cq,)

        def kv_step2(carry, inputs):
            m, l, acc = carry
            kblk, vblk, kpos = inputs
            # scores: (b, n_kv, group, cq, ck)
            s = jnp.einsum("bqngd,bknd->bngqk",
                           qblk.astype(jnp.float32), kblk.astype(jnp.float32))
            s = s * scale
            mask = kpos[None, :] <= q_pos[:, None] if causal else jnp.ones(
                (cq, ck), bool)
            if causal and window > 0:
                mask = mask & (kpos[None, :] > q_pos[:, None] - window)
            mask = mask & (kpos[None, :] < kv_len_valid)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m2 = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m2[..., None])
            corr = jnp.exp(m - m2)
            l2 = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bngqk,bknd->bngqd", p, vblk.astype(jnp.float32))
            acc2 = acc * corr[..., None] + pv
            return (m2, l2, acc2), None

        m0 = jnp.full((b, n_kv, group, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, n_kv, group, cq), jnp.float32)
        a0 = jnp.zeros((b, n_kv, group, cq, hd), jnp.float32)

        if scheme == "tri" and causal:
            # unrolled triangular/banded schedule: q chunk qi touches only kv
            # chunks intersecting [qi*cq - window + 1, (qi+1)*cq) — skips the
            # fully-masked blocks the rectangular scan pays for (2x for causal,
            # ~seq/window x for sliding-window attention).
            hi = int(qi) + 1
            lo = 0
            if window > 0:
                lo = max(0, (int(qi) * cq - window + 1) // ck)
            carry = (m0, l0, a0)
            for kj in range(lo, hi):
                carry, _ = kv_step2(carry, (kr[:, kj], vr[:, kj], kv_pos[kj]))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(
                kv_step2, (m0, l0, a0),
                (kr.swapaxes(0, 1), vr.swapaxes(0, 1), kv_pos))
        out = acc / jnp.maximum(l[..., None], 1e-30)                  # (b,n_kv,g,cq,hd)
        return out.transpose(0, 3, 1, 2, 4)                           # (b,cq,n_kv,g,hd)

    if scheme == "tri" and causal:
        outs = [q_block(qi, qr[:, qi]) for qi in range(n_q)]
        out = jnp.stack(outs, axis=1)                                 # (b,n_q,cq,...)
    else:
        out = jax.vmap(q_block, in_axes=(0, 1), out_axes=1)(
            jnp.arange(n_q), qr)
    return out.reshape(b, sq, h, hd)


def attention(p, x, cfg: ModelConfig, *, positions, causal=True, cache: KVCache |
              None = None, update_cache=False, cross_kv=None):
    """Full attention entry point used by all transformer families.

    Modes: (a) self-attention over x (train / prefill — optionally writing a cache),
    (b) decode against a cache (x is the new token(s)), (c) cross-attention when
    ``cross_kv=(k, v)`` is precomputed (whisper decoder).
    """
    b, s, d = x.shape
    q, k_new, v_new = _qkv(p, x, cfg)

    if cross_kv is not None:
        k, v = cross_kv
        kv_valid = jnp.int32(k.shape[1])
        out = _chunked_attention(q, k, v, causal=False, window=0, q_offset=0,
                                 kv_len_valid=kv_valid, chunk_q=cfg.attn_chunk_q,
                                 chunk_kv=cfg.attn_chunk_kv)
        new_cache = cache
    elif cache is not None:
        if cfg.m_rope:
            q = apply_m_rope(q, positions, cfg.rope_theta)
            k_new = apply_m_rope(k_new, positions, cfg.rope_theta)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k_new = apply_rope(k_new, positions, cfg.rope_theta)
        cache_len = cache.k.shape[1]
        cdt = cache.k.dtype    # storage dtype (optionally f8: cfg.kv_dtype)
        if s == 1:
            # decode: ring-buffer write (one in-place slice update — no shift
            # copies). When full, the oldest slot is overwritten: exactly the
            # sliding-window semantics; RoPE is relative and every valid slot
            # is attendable, so slot order never matters.
            k_q, v_q = k_new.astype(cdt), v_new.astype(cdt)
            widx = cache.length % cache_len          # length counts monotonically
            k = jax.lax.dynamic_update_slice(cache.k, k_q, (0, widx, 0, 0))
            v = jax.lax.dynamic_update_slice(cache.v, v_q, (0, widx, 0, 0))
            new_cache = KVCache(k=k, v=v, length=cache.length + 1)
            valid = jnp.minimum(cache.length + 1, cache_len)
            # storage dtype flows into the attention chunks; each kv block is
            # upcast to f32 inside the online-softmax step (never the full
            # cache — the f8 cache stays f8 in HBM).
            out = _chunked_attention(q, k, v,
                                     causal=False, window=0, q_offset=0,
                                     kv_len_valid=valid, chunk_q=1,
                                     chunk_kv=cfg.attn_chunk_kv)
        else:
            # prefill: attend over the fresh K/V; store the (window) tail
            out = _chunked_attention(
                q, k_new, v_new, causal=True, window=cfg.window, q_offset=0,
                kv_len_valid=jnp.int32(s), chunk_q=cfg.attn_chunk_q,
                chunk_kv=cfg.attn_chunk_kv, scheme=cfg.causal_scheme)
            keep = min(cache_len, s)
            k = jax.lax.dynamic_update_slice(
                cache.k, k_new[:, s - keep:].astype(cdt), (0, 0, 0, 0))
            v = jax.lax.dynamic_update_slice(
                cache.v, v_new[:, s - keep:].astype(cdt), (0, 0, 0, 0))
            new_cache = KVCache(k=k, v=v, length=jnp.int32(keep))
    else:
        if cfg.m_rope:
            q = apply_m_rope(q, positions, cfg.rope_theta)
            k_new = apply_m_rope(k_new, positions, cfg.rope_theta)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k_new = apply_rope(k_new, positions, cfg.rope_theta)
        out = _chunked_attention(
            q, k_new, v_new, causal=causal, window=cfg.window, q_offset=0,
            kv_len_valid=jnp.int32(s), chunk_q=cfg.attn_chunk_q,
            chunk_kv=cfg.attn_chunk_kv, scheme=cfg.causal_scheme)
        new_cache = None

    y = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"])
    return y, new_cache


# ------------------------------------------------------------------- MLPs
def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None, gated=True):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 3)
    p = {
        "wi": dense_init(ks[0], (d, f), ("fsdp", "mlp"), dt),
        "wo": dense_init(ks[1], (f, d), ("mlp", "fsdp"), dt),
    }
    if gated:
        p["wg"] = dense_init(ks[2], (d, f), ("fsdp", "mlp"), dt)
    return p


def mlp(p, x):
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    if "wg" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["wg"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


# -------------------------------------------------------------- embeddings
def init_embedding(key, cfg: ModelConfig):
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 2)
    p = {"tok": dense_init(ks[0], (cfg.vocab, cfg.d_model), ("vocab", "fsdp"),
                           dt, scale=1.0 / math.sqrt(cfg.d_model))}
    if not cfg.tie_embeddings:
        p["out"] = dense_init(ks[1], (cfg.d_model, cfg.vocab), ("fsdp", "vocab"),
                              dt, scale=1.0 / math.sqrt(cfg.d_model))
    return p


def embed(p, tokens):
    return jnp.take(p["tok"], tokens, axis=0)


def unembed(p, x):
    w = p.get("out")
    if w is None:
        w = p["tok"].T
    return jnp.einsum("bsd,dv->bsv", x, w)
