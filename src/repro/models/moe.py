"""Mixture-of-Experts FFN: top-k routing, capacity-bounded sort-based dispatch,
expert-parallel einsums, load-balancing auxiliary loss.

Dispatch is gather/scatter based (tokens sorted by expert, truncated at capacity)
— the memory-lean encoding that shards cleanly: with "experts" -> "model" the expert
einsum becomes expert-parallel (a2a-style redistribution inserted by SPMD); when the
expert count does not divide the axis (mixtral's 8 on a 16-way axis) the rules fall
back to tensor-parallel expert MLPs ("mlp" -> "model") automatically.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.sharding import Annotated, shard


def init_moe(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = L.dtype_of(cfg)
    ks = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d)
    return {
        "router": L.dense_init(ks[0], (d, e), ("fsdp", "experts"), jnp.float32),
        "wi": Annotated(jax.random.normal(ks[1], (e, d, f), jnp.float32)
                        .astype(dt) * std, ("experts", "fsdp", "mlp")),
        "wg": Annotated(jax.random.normal(ks[2], (e, d, f), jnp.float32)
                        .astype(dt) * std, ("experts", "fsdp", "mlp")),
        "wo": Annotated(jax.random.normal(ks[3], (e, f, d), jnp.float32)
                        .astype(dt) / math.sqrt(f), ("experts", "mlp", "fsdp")),
    }


def moe_ffn(p, x, cfg: ModelConfig):
    """x: (b, s, d) -> (y, aux_loss). Capacity per row = cf * s * top_k / E.

    Dispatch is PER BATCH ROW (gather/scatter indices stay < s), so the sharded
    batch axis survives the routing untouched — flattening (b, s) together would
    force SPMD to replicate the token table across the fleet (an "involuntary
    full rematerialization" in the partitioner, observed in the dry-run; see
    EXPERIMENTS.md §Perf for before/after).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                  # (b,s,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balancing aux loss (Switch/Mixtral form, global means)
    me = jnp.mean(probs, axis=(0, 1))                              # (e,)
    ce = jnp.mean(
        (jax.nn.one_hot(gate_idx, e).sum(axis=2) > 0).astype(jnp.float32),
        axis=(0, 1))
    aux = e * jnp.sum(me * ce)

    cap = max(int(cfg.capacity_factor * s * k / e), 1)
    cap = (cap + 127) // 128 * 128 if cap >= 128 else (cap + 7) // 8 * 8
    cap = min(cap, s * k)        # an expert can never see more than s*k slots
    cap = max(cap, 1)            # decode: s*k tiny -> minimal but nonzero

    flat_expert = gate_idx.reshape(b, s * k)
    tok_ids = jnp.arange(s * k, dtype=jnp.int32) // k              # (s*k,)
    flat_gate = gate_vals.reshape(b, s * k)

    def route_row(fe, fg, xrow):
        order = jnp.argsort(fe, stable=True)
        se, sg = fe[order], fg[order]
        stok = tok_ids[order]
        grp_start = jnp.searchsorted(se, se, side="left")
        pos = jnp.arange(s * k, dtype=jnp.int32) - grp_start
        keep = pos < cap
        slot = jnp.where(keep, se * cap + pos, e * cap)            # OOB -> drop
        # empty slots keep gate 0 and point at token 0 (masked by the gate at
        # combine time) — no sentinel row, so the gather operand keeps shape
        # (s, d) and partitions cleanly.
        tok_of_slot = jnp.zeros((e * cap,), jnp.int32).at[slot].set(
            stok, mode="drop")
        gate_of_slot = jnp.zeros((e * cap,), jnp.float32).at[slot].set(
            sg, mode="drop")
        xe = xrow[tok_of_slot] * (gate_of_slot > 0)[:, None].astype(xrow.dtype)
        return xe.reshape(e, cap, d), tok_of_slot, gate_of_slot

    flat_expert = shard(flat_expert, ("batch", "seq"))
    flat_gate = shard(flat_gate, ("batch", "seq"))
    xe, tok_of_slot, gate_of_slot = jax.vmap(route_row)(
        flat_expert, flat_gate, x)                                 # (b,e,cap,d)

    # pin the activation shardings so the partitioner gathers the (small, fsdp)
    # expert weights instead of re-sharding the (huge) token activations
    xe = shard(xe, ("batch", "experts", "expert_cap", "embed"))
    tok_of_slot = shard(tok_of_slot, ("batch", "seq"))
    gate_of_slot = shard(gate_of_slot, ("batch", "seq"))
    h = jnp.einsum("becd,edf->becf", xe, p["wi"])
    g = jnp.einsum("becd,edf->becf", xe, p["wg"])
    h = shard(h, ("batch", "experts", "expert_cap", "mlp"))
    g = shard(g, ("batch", "experts", "expert_cap", "mlp"))
    y = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * h, p["wo"])  # (b,e,cap,d)
    y = shard(y, ("batch", "experts", "expert_cap", "embed"))

    def combine_row(yrow, tok, gate):
        y_flat = yrow.reshape(e * cap, d).astype(jnp.float32) * gate[:, None]
        return jnp.zeros((s, d), jnp.float32).at[tok].add(y_flat)

    out = jax.vmap(combine_row)(y, tok_of_slot, gate_of_slot)
    return out.astype(x.dtype), aux
