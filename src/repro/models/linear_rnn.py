"""Gated linear recurrences: RWKV6 (Finch) and Mamba2-style SSD (hymba's SSM heads).

Both are instances of one primitive — a decayed outer-product state recurrence

    S_t = diag(decay_t) * S_{t-1} + k_t (x) v_t        out_t = q_t . S_t

with two variants: RWKV applies the decay on the K channels *after* reading the
state (plus a per-channel "bonus" u for the current token); Mamba/SSD applies a
per-V-channel (here: per-head scalar) decay *before* reading. The TPU-native form
is the chunked algorithm: within a chunk of C tokens everything is dense matmuls
(MXU), and state crosses chunk boundaries through a lax.scan — sequential-scan
FLOPs become O(S/C) matmuls instead of S scalar steps. ``*_ref`` are the sequential
oracles; the Pallas kernels in repro.kernels mirror the chunked math.

Numerics: cumulative decays are computed in f32 and clamped (decay >= exp(-8)); the
chunk length bounds the dynamic range of the cumprod ratios. Validated against the
sequential refs in tests/test_kernels.py.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.sharding import Annotated

DECAY_MIN = math.exp(-8.0)


# ----------------------------------------------------------- sequential refs
def gla_ref(q, k, v, decay, bonus=None, mode="k", s0=None):
    """Sequential oracle. q,k: (b,s,h,dk); v: (b,s,h,dv);
    decay: (b,s,h,dk) for mode='k', (b,s,h,dv) for mode='v'; bonus: (h,dk)."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    w = decay.astype(jnp.float32)
    state0 = jnp.zeros((b, h, dk, dv), jnp.float32) if s0 is None else s0

    def step(S, inp):
        qt, kt, vt, wt = inp  # (b,h,dk) (b,h,dk) (b,h,dv) (b,h,dk|dv)
        kv = kt[..., :, None] * vt[..., None, :]          # (b,h,dk,dv)
        if mode == "k":
            Su = S + bonus[None, :, :, None] * kv if bonus is not None else S
            out = jnp.einsum("bhk,bhkv->bhv", qt, Su)
            S2 = S * wt[..., :, None] + kv
        else:
            S2 = S * wt[..., None, :] + kv
            out = jnp.einsum("bhk,bhkv->bhv", qt, S2)
        return S2, out

    xs = (qf.swapaxes(0, 1), kf.swapaxes(0, 1), vf.swapaxes(0, 1),
          w.swapaxes(0, 1))
    state, outs = jax.lax.scan(step, state0, xs)
    return outs.swapaxes(0, 1), state                      # (b,s,h,dv), (b,h,dk,dv)


# ------------------------------------------------------------- chunked form
def gla_chunked(q, k, v, decay, bonus=None, mode="k", chunk=64, s0=None):
    """Chunked (MXU-friendly) evaluation, == gla_ref up to f32 roundoff."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, s)
    while s % c:          # fall back to the largest divisor (odd prefills)
        c -= 1
    n = s // c
    qf = q.astype(jnp.float32).reshape(b, n, c, h, dk)
    kf = k.astype(jnp.float32).reshape(b, n, c, h, dk)
    vf = v.astype(jnp.float32).reshape(b, n, c, h, dv)
    wd = decay.astype(jnp.float32).reshape(b, n, c, h, decay.shape[-1])
    state0 = jnp.zeros((b, h, dk, dv), jnp.float32) if s0 is None else s0
    tri_lo = jnp.tril(jnp.ones((c, c), jnp.float32), k=-1)   # strictly lower
    tri_inc = jnp.tril(jnp.ones((c, c), jnp.float32))        # incl diag

    def chunk_step(S, inp):
        qc, kc, vc, wc = inp   # (b,c,h,dk) (b,c,h,dk) (b,c,h,dv) (b,c,h,dk|dv)
        if mode == "k":
            # Q_i = prod_{j<i} w_j (exclusive), Qs_j = prod_{j'<=j} w_j' (inclusive)
            logw = jnp.log(wc)
            Qs = jnp.exp(jnp.cumsum(logw, axis=1))           # inclusive
            Q = Qs / wc                                      # exclusive
            r_t = qc * Q                                     # (b,c,h,dk)
            k_t = kc / Qs
            A = jnp.einsum("bihk,bjhk->bhij", r_t, k_t) * tri_lo[None, None]
            if bonus is not None:
                diag = jnp.einsum("bihk,hk,bihk->bhi", qc, bonus, kc)
                A = A + diag[..., None] * jnp.eye(c)[None, None]
            out = (jnp.einsum("bihk,bhkv->bihv", r_t, S)
                   + jnp.einsum("bhij,bjhv->bihv", A, vc))
            Qc_tot = Qs[:, -1]                               # (b,h,dk)
            S2 = (S * Qc_tot[..., None]
                  + jnp.einsum("bjhk,bjhv->bhkv", Qc_tot[:, None] * k_t, vc))
        else:
            logw = jnp.log(wc)                               # (b,c,h,dv)
            Qs = jnp.exp(jnp.cumsum(logw, axis=1))           # inclusive
            B = jnp.einsum("bihk,bjhk->bhij", qc, kc) * tri_inc[None, None]
            v_t = vc / Qs
            out = Qs * (jnp.einsum("bihk,bhkv->bihv", qc, S)
                        + jnp.einsum("bhij,bjhv->bihv", B, v_t))
            Qc_tot = Qs[:, -1]                               # (b,h,dv)
            S2 = Qc_tot[:, :, None, :] * (
                S + jnp.einsum("bjhk,bjhv->bhkv", kc, v_t))
        return S2, out

    xs = tuple(x.swapaxes(0, 1) for x in (qf, kf, vf, wd))
    state, outs = jax.lax.scan(chunk_step, state0, xs)
    outs = outs.swapaxes(0, 1).reshape(b, s, h, dv)
    return outs, state


def gla_decode_step(q, k, v, decay, state, bonus=None, mode="k"):
    """Single-token recurrent step (serving). q,k: (b,h,dk); v: (b,h,dv);
    decay per mode; state: (b,h,dk,dv)."""
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    w = decay.astype(jnp.float32)
    kv = kf[..., :, None] * vf[..., None, :]
    if mode == "k":
        Su = state + (bonus[None, :, :, None] * kv if bonus is not None else 0.0)
        out = jnp.einsum("bhk,bhkv->bhv", qf, Su)
        state2 = state * w[..., :, None] + kv
    else:
        state2 = state * w[..., None, :] + kv
        out = jnp.einsum("bhk,bhkv->bhv", qf, state2)
    return out, state2


# ------------------------------------------------------------------ RWKV6
def init_rwkv_time_mix(key, cfg: ModelConfig):
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    dt = L.dtype_of(cfg)
    ks = jax.random.split(key, 8)
    lora = 64
    return {
        "mu": Annotated(jnp.full((5, d), 0.5, jnp.float32), ("conv", "embed")),
        "wr": L.dense_init(ks[0], (d, h, hd), ("fsdp", "heads", "head"), dt),
        "wk": L.dense_init(ks[1], (d, h, hd), ("fsdp", "heads", "head"), dt),
        "wv": L.dense_init(ks[2], (d, h, hd), ("fsdp", "heads", "head"), dt),
        "wg": L.dense_init(ks[3], (d, h, hd), ("fsdp", "heads", "head"), dt),
        "wo": L.dense_init(ks[4], (h, hd, d), ("heads", "head", "fsdp"), dt,
                           scale=1.0 / math.sqrt(d)),
        # Finch data-dependent decay: w = exp(-exp(w0 + (tanh(x A) B)))
        "w0": Annotated(jnp.full((h, hd), -2.0, jnp.float32), ("heads", "head")),
        "wA": L.dense_init(ks[5], (d, lora), ("fsdp", "mlp"), jnp.float32,
                           scale=0.01),
        "wB": L.dense_init(ks[6], (lora, h, hd), ("mlp", "heads", "head"),
                           jnp.float32, scale=0.01),
        "u": Annotated(jnp.zeros((h, hd), jnp.float32), ("heads", "head")),
        "ln_x": Annotated(jnp.ones((h, hd), jnp.float32), ("heads", "head")),
    }


def _token_shift(x, prev=None):
    """RWKV token shift: x_{t-1} (zeros / supplied state at t=0)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def rwkv_time_mix(p, x, cfg: ModelConfig, *, state=None, shift_prev=None,
                  chunked=True):
    """state: (b,h,dk,dv) recurrent state or None; returns (y, new_state, x_last)."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    xx = _token_shift(x, shift_prev)
    mu = p["mu"].astype(x.dtype)
    xr = x + (xx - x) * mu[0]
    xk = x + (xx - x) * mu[1]
    xv = x + (xx - x) * mu[2]
    xw = x + (xx - x) * mu[3]
    xg = x + (xx - x) * mu[4]
    r = jnp.einsum("bsd,dhk->bshk", xr, p["wr"])
    k = jnp.einsum("bsd,dhk->bshk", xk, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xv, p["wv"])
    g = jnp.einsum("bsd,dhk->bshk", xg, p["wg"])
    # data-dependent decay (the Finch contribution)
    dd = jnp.einsum("bsl,lhk->bshk", jnp.tanh(
        jnp.einsum("bsd,dl->bsl", xw.astype(jnp.float32), p["wA"])), p["wB"])
    w = jnp.exp(-jnp.exp(jnp.clip(p["w0"][None, None] + dd, -8.0, 2.0)))
    w = jnp.maximum(w, DECAY_MIN)

    fn = gla_chunked if chunked else gla_ref
    out, new_state = fn(r, k, v, w, bonus=p["u"], mode="k",
                        **({"chunk": cfg.chunk_gla} if chunked else {}), s0=state)
    # per-head group norm, then output gate
    mean = jnp.mean(out, axis=-1, keepdims=True)
    var = jnp.var(out, axis=-1, keepdims=True)
    out = (out - mean) * jax.lax.rsqrt(var + 64e-5) * p["ln_x"][None, None]
    out = out.astype(x.dtype) * jax.nn.silu(g)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_state, x[:, -1:]


def init_rwkv_channel_mix(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    dt = L.dtype_of(cfg)
    ks = jax.random.split(key, 2)
    return {
        "mu": Annotated(jnp.full((2, d), 0.5, jnp.float32), ("conv", "embed")),
        "wk": L.dense_init(ks[0], (d, f), ("fsdp", "mlp"), dt),
        "wv": L.dense_init(ks[1], (f, d), ("mlp", "fsdp"), dt),
    }


def rwkv_channel_mix(p, x, shift_prev=None):
    xx = _token_shift(x, shift_prev)
    mu = p["mu"].astype(x.dtype)
    xk = x + (xx - x) * mu[0]
    k = jnp.einsum("bsd,df->bsf", xk, p["wk"])
    k = jnp.square(jax.nn.relu(k))
    return jnp.einsum("bsf,fd->bsd", k, p["wv"]), x[:, -1:]


# ------------------------------------------------- Mamba2-style SSD (hymba)
def init_ssd(key, cfg: ModelConfig):
    """Scalar-per-head decay SSD: q=C, k=B, v=x*dt — hymba's SSM half."""
    d, h = cfg.d_model, cfg.n_heads
    n = cfg.ssm_state
    hd = cfg.head_dim
    dt = L.dtype_of(cfg)
    ks = jax.random.split(key, 5)
    return {
        "wx": L.dense_init(ks[0], (d, h, hd), ("fsdp", "heads", "head"), dt),
        "wB": L.dense_init(ks[1], (d, h, n), ("fsdp", "heads", "ssm_state"), dt),
        "wC": L.dense_init(ks[2], (d, h, n), ("fsdp", "heads", "ssm_state"), dt),
        "wdt": L.dense_init(ks[3], (d, h), ("fsdp", "heads"), jnp.float32,
                            scale=0.01),
        "a_log": Annotated(jnp.zeros((h,), jnp.float32), ("heads",)),
        "wo": L.dense_init(ks[4], (h, hd, d), ("heads", "head", "fsdp"), dt,
                           scale=1.0 / math.sqrt(d)),
        "dt_bias": Annotated(jnp.full((h,), -1.0, jnp.float32), ("heads",)),
    }


def ssd_mix(p, x, cfg: ModelConfig, *, state=None, chunked=True):
    """Returns (y, new_state). state: (b, h, n, hd)."""
    b, s, d = x.shape
    h, n, hd = cfg.n_heads, cfg.ssm_state, cfg.head_dim
    xs = jnp.einsum("bsd,dhk->bshk", x, p["wx"])                  # v (b,s,h,hd)
    Bm = jnp.einsum("bsd,dhn->bshn", x, p["wB"])                  # k
    Cm = jnp.einsum("bsd,dhn->bshn", x, p["wC"])                  # q
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["wdt"])
        + p["dt_bias"][None, None])                               # (b,s,h)
    a = jnp.exp(-dt * jnp.exp(p["a_log"])[None, None])            # (b,s,h) in (0,1)
    a = jnp.maximum(a, DECAY_MIN)
    v = xs.astype(jnp.float32) * dt[..., None]
    decay = jnp.broadcast_to(a[..., None], (b, s, h, hd))         # per-v-channel

    fn = gla_chunked if chunked else gla_ref
    out, new_state = fn(Cm, Bm, v.astype(Cm.dtype), decay, mode="v",
                        **({"chunk": cfg.chunk_gla} if chunked else {}), s0=state)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"])
    return y, new_state
