"""Logical-axis sharding rules (MaxText-style, with divisibility-aware fallbacks).

Every tensor in the model zoo carries a tuple of logical axis names. A ``Rules``
mapping takes each logical name to an ordered list of mesh-axis candidates; the
first candidate whose mesh-axis product divides the dimension (and whose mesh axes
are not already consumed by an earlier dim of the same tensor) wins. This makes one
rule set serve every architecture (25-head models simply fall back to unsharded
heads while their MLPs stay tensor-parallel) and makes hillclimbing a rules edit.
"""
from __future__ import annotations

import math
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical name -> ordered candidates; each candidate is a tuple of mesh axes
Rules = Mapping[str, Sequence[tuple[str, ...]]]

DEFAULT_RULES: dict[str, tuple[tuple[str, ...], ...]] = {
    # activations
    "batch": (("pod", "data"), ("data",), ("pod",)),
    "seq": (),                      # unsharded by default (full activations)
    "act_seq": (("model",),),       # sequence-sharded saved activations / norms
    "embed": (),
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "head": (),
    "mlp": (("model",),),
    "experts": (("model",),),
    "expert_cap": (),
    "vocab": (("model",),),
    "seq_kv": (("model",),),        # decode KV-cache fallback axis
    # weights
    "fsdp": (("data",),),           # ZeRO-3 weight axis
    "layers": (),                   # scan axis
    "ssm_state": (),
    "conv": (),
}


def spec_for(shape: Sequence[int], names: Sequence[str], rules: Rules,
             mesh_shape: Mapping[str, int]) -> P:
    """Resolve logical names to a PartitionSpec for a concrete shape + mesh."""
    assert len(shape) == len(names), (shape, names)
    used: set[str] = set()
    parts = []
    for dim, name in zip(shape, names):
        pick = None
        for cand in rules.get(name, ()):
            if any(a in used or a not in mesh_shape for a in cand):
                continue
            prod = math.prod(mesh_shape[a] for a in cand)
            if dim > 0 and dim % prod == 0 and prod > 1:
                pick = cand
                break
        if pick is None:
            parts.append(None)
        else:
            used.update(pick)
            parts.append(pick[0] if len(pick) == 1 else pick)
    return P(*parts)


def constrain(x: jax.Array, names: Sequence[str], rules: Rules | None,
              mesh: Mesh | None) -> jax.Array:
    """with_sharding_constraint by logical names (no-op outside a mesh)."""
    if mesh is None or rules is None or mesh.empty:
        return x
    spec = spec_for(x.shape, names, rules, dict(zip(mesh.axis_names, mesh.devices.shape)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Trace-time sharding context: launcher sets it around jit tracing; model code
# calls ``shard(x, names)``. Outside the context it is the identity, so tests
# and single-device paths never touch mesh state.
# ---------------------------------------------------------------------------
import contextlib
import threading

_CTX = threading.local()


@contextlib.contextmanager
def sharding_ctx(mesh: Mesh, rules: Rules | None = None):
    prev = getattr(_CTX, "val", None)
    _CTX.val = (mesh, rules or DEFAULT_RULES)
    try:
        yield
    finally:
        _CTX.val = prev


def current_rules() -> Rules | None:
    ctx = getattr(_CTX, "val", None)
    return ctx[1] if ctx else None


def shard(x: jax.Array, names: Sequence[str]) -> jax.Array:
    ctx = getattr(_CTX, "val", None)
    if ctx is None:
        return x
    return constrain(x, names, ctx[1], ctx[0])


def tree_specs(specs_names, shapes, rules: Rules, mesh: Mesh):
    """Map a pytree of logical-name tuples + matching shapes -> NamedShardings."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(names, shaped):
        return NamedSharding(mesh, spec_for(shaped.shape, names, rules, mesh_shape))

    return jax.tree.map(one, specs_names, shapes,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(s, str) for s in x))


class Annotated:
    """Carrier for (array-like, logical names). Used in init to emit spec trees."""

    __slots__ = ("value", "names")

    def __init__(self, value, names: tuple[str, ...]):
        self.value = value
        self.names = names


def split_annotated(tree):
    """Annotated pytree -> (values pytree, names pytree)."""
    leaves_is = lambda x: isinstance(x, Annotated)
    values = jax.tree.map(lambda a: a.value, tree, is_leaf=leaves_is)
    names = jax.tree.map(lambda a: a.names, tree, is_leaf=leaves_is)
    return values, names
