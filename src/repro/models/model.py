"""Model assembly for all six architecture families.

One ``Model`` object per config exposes:
  init(rng) -> (params, names)      names = logical-axis tuples for sharding
  loss_fn(params, batch)            training loss (+ metrics)
  prefill_fn(params, batch)         -> (last-token logits, decode state)
  decode_fn(params, state, tokens, length) -> (logits, state)
  input_specs(shape) / decode_state_specs(shape)   ShapeDtypeStruct stand-ins

Layers run under lax.scan (compile time / HLO size O(1) in depth) with optional
full-block remat; saved activations are sequence-sharded via the "act_seq" rule.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import layers as L
from repro.models import linear_rnn as R
from repro.models import moe as M
from repro.models.sharding import Annotated, shard, split_annotated

AUX_WEIGHT = 0.01
VLM_PATCHES = 1024          # stub frontend: patch-embedding slots at seq start
LOSS_CHUNKS = 8             # seq chunks for the big-vocab chunked loss


# ======================================================================== init
def _init_block(key, cfg: ModelConfig, kind: str):
    """One transformer block's params. kind: dense|moe|hybrid|rwkv|enc|dec."""
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {}
    if kind == "rwkv":
        p["ln1"] = L.init_rmsnorm(cfg.d_model)
        p["tmix"] = R.init_rwkv_time_mix(ks[0], cfg)
        p["ln2"] = L.init_rmsnorm(cfg.d_model)
        p["cmix"] = R.init_rwkv_channel_mix(ks[1], cfg)
        return p
    p["ln1"] = L.init_rmsnorm(cfg.d_model)
    p["attn"] = L.init_attention(ks[0], cfg)
    p["ln2"] = L.init_rmsnorm(cfg.d_model)
    if kind == "hybrid":
        p["ssd"] = R.init_ssd(ks[1], cfg)
        p["ln_attn_out"] = L.init_rmsnorm(cfg.d_model)
        p["ln_ssd_out"] = L.init_rmsnorm(cfg.d_model)
        p["mlp"] = L.init_mlp(ks[2], cfg)
    elif kind == "moe":
        p["moe"] = M.init_moe(ks[1], cfg)
    elif kind == "dense_ffn_moe_arch":
        p["mlp"] = L.init_mlp(ks[1], cfg, d_ff=4 * cfg.d_model)
    elif kind == "enc":
        p["lnb1"] = Annotated(jnp.zeros((cfg.d_model,), jnp.float32), ("embed",))
        p["lnb2"] = Annotated(jnp.zeros((cfg.d_model,), jnp.float32), ("embed",))
        p["mlp"] = L.init_mlp(ks[1], cfg, gated=False)
    elif kind == "dec":
        p["xattn"] = L.init_attention(ks[1], cfg, cross=True)
        p["ln3"] = L.init_rmsnorm(cfg.d_model)
        p["lnb1"] = Annotated(jnp.zeros((cfg.d_model,), jnp.float32), ("embed",))
        p["lnb2"] = Annotated(jnp.zeros((cfg.d_model,), jnp.float32), ("embed",))
        p["lnb3"] = Annotated(jnp.zeros((cfg.d_model,), jnp.float32), ("embed",))
        p["mlp"] = L.init_mlp(ks[2], cfg, gated=False)
    else:  # dense / vlm
        p["mlp"] = L.init_mlp(ks[1], cfg)
    return p


def _stack_init(key, cfg: ModelConfig, kind: str, n: int):
    """Init per layer, stack with a leading 'layers' axis (the scan axis)."""
    keys = jax.random.split(key, n)
    blocks = [_init_block(k, cfg, kind) for k in keys]
    def stack(*leaves):
        if isinstance(leaves[0], Annotated):
            return Annotated(jnp.stack([l.value for l in leaves]),
                             ("layers",) + leaves[0].names)
        return jnp.stack(leaves)
    return jax.tree.map(stack, *blocks,
                        is_leaf=lambda x: isinstance(x, Annotated))


def _block_kind(cfg: ModelConfig) -> str:
    return {"dense": "dense", "vlm": "dense", "moe": "moe",
            "hybrid": "hybrid", "ssm": "rwkv", "encdec": "dec"}[cfg.family]


def init_params(key, cfg: ModelConfig):
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {"embed": L.init_embedding(ks[0], cfg)}
    kind = _block_kind(cfg)
    n_scan = cfg.n_layers - cfg.moe_first_dense
    if cfg.moe_first_dense:
        p["first_layers"] = _stack_init(ks[1], cfg, "dense_ffn_moe_arch",
                                        cfg.moe_first_dense)
    p["layers"] = _stack_init(ks[2], cfg, kind, n_scan)
    p["final_norm"] = L.init_rmsnorm(cfg.d_model)
    if cfg.family == "encdec":
        p["encoder"] = _stack_init(ks[3], cfg, "enc", cfg.encoder_layers)
        p["enc_norm"] = L.init_rmsnorm(cfg.d_model)
        p["enc_normb"] = Annotated(jnp.zeros((cfg.d_model,), jnp.float32),
                                   ("embed",))
    if cfg.family == "vlm":
        p["patch_proj"] = L.dense_init(ks[4], (cfg.d_model, cfg.d_model),
                                       ("fsdp", "embed"), L.dtype_of(cfg))
    return split_annotated(p)


# ================================================================= block apply
def _apply_block(p, x, cfg: ModelConfig, kind: str, *, positions, cache=None,
                 cross_kv=None, rnn_state=None, decode=False):
    """Returns (x, aux, new_cache, new_rnn_state)."""
    aux = jnp.float32(0.0)
    new_cache, new_rnn = None, None

    if kind == "rwkv":
        tm_state = rnn_state["S"] if rnn_state else None
        tm_prev = rnn_state["tm_prev"] if rnn_state else None
        cm_prev = rnn_state["cm_prev"] if rnn_state else None
        h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
        y, S2, tm_last = R.rwkv_time_mix(p["tmix"], h, cfg, state=tm_state,
                                         shift_prev=tm_prev, chunked=not decode)
        x = x + y
        h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
        y, cm_last = R.rwkv_channel_mix(p["cmix"], h, shift_prev=cm_prev)
        x = x + y
        if rnn_state is not None:
            new_rnn = {"S": S2, "tm_prev": tm_last, "cm_prev": cm_last}
        return x, aux, new_cache, new_rnn

    if kind in ("enc", "dec"):
        h = L.layernorm(x, p["ln1"], p["lnb1"], cfg.norm_eps)
        y, new_cache = L.attention(p["attn"], h, cfg, positions=positions,
                                   causal=(kind == "dec"), cache=cache)
        x = x + y
        if kind == "dec":
            h = L.layernorm(x, p["ln3"], p["lnb3"], cfg.norm_eps)
            y, _ = L.attention(p["xattn"], h, cfg, positions=positions,
                               cross_kv=cross_kv)
            x = x + y
        h = L.layernorm(x, p["ln2"], p["lnb2"], cfg.norm_eps)
        x = x + L.mlp(p["mlp"], h)
        return x, aux, new_cache, new_rnn

    # pre-norm self-attention families
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    if kind == "hybrid":
        attn_y, new_cache = L.attention(p["attn"], h, cfg, positions=positions,
                                        cache=cache)
        ssd_state = rnn_state["ssd"] if rnn_state else None
        ssd_y, S2 = R.ssd_mix(p["ssd"], h, cfg, state=ssd_state,
                              chunked=not decode)
        # hymba: normalize both heads' outputs, then average
        y = 0.5 * (L.rmsnorm(attn_y, p["ln_attn_out"], cfg.norm_eps)
                   + L.rmsnorm(ssd_y, p["ln_ssd_out"], cfg.norm_eps))
        x = x + y
        if rnn_state is not None:
            new_rnn = {"ssd": S2}
    else:
        y, new_cache = L.attention(p["attn"], h, cfg, positions=positions,
                                   cache=cache)
        x = x + y

    h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if kind == "moe":
        # routing gathers index the seq dim: keep it unsharded here (batch-only
        # sharding) or the partitioner replicates the token table fleet-wide
        h = shard(h, ("batch", "seq", "embed"))
        y, aux = M.moe_ffn(p["moe"], h, cfg)
        y = shard(y, ("batch", "act_seq", "embed"))
    else:
        y = L.mlp(p["mlp"], h)
    x = x + y
    return x, aux, new_cache, new_rnn


# ================================================================== backbones
def _scan_blocks(params_layers, x, cfg: ModelConfig, kind: str, *, positions,
                 caches=None, cross_kv=None, rnn_states=None, decode=False,
                 remat: bool):
    """lax.scan over the stacked layer params (+ per-layer cache/state)."""

    def body(carry, inp):
        x, aux_sum = carry
        p, cache, rnn = inp
        x = shard(x, ("batch", "act_seq", "embed"))
        x, aux, new_cache, new_rnn = _apply_block(
            p, x, cfg, kind, positions=positions, cache=cache,
            cross_kv=cross_kv, rnn_state=rnn, decode=decode)
        return (x, aux_sum + aux), (new_cache, new_rnn)

    fn = jax.checkpoint(body) if remat else body
    (x, aux), (new_caches, new_rnns) = jax.lax.scan(
        fn, (x, jnp.float32(0.0)), (params_layers, caches, rnn_states))
    return x, aux, new_caches, new_rnns


# ==================================================================== Model
@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ----------------------------------------------------------------- init
    def init(self, rng):
        return init_params(rng, self.cfg)

    # ------------------------------------------------------------- embedding
    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        x = L.embed(params["embed"], batch["tokens"])
        if cfg.family == "vlm" and "patch_embeds" in batch:
            patches = jnp.einsum("bpd,de->bpe",
                                 batch["patch_embeds"].astype(x.dtype),
                                 params["patch_proj"])
            x = jax.lax.dynamic_update_slice(x, patches, (0, 0, 0))
        return x

    def _positions(self, batch, seq, offset=0):
        cfg = self.cfg
        b = batch["tokens"].shape[0]
        if cfg.m_rope:
            if "positions3" in batch:
                return batch["positions3"]
            pos = jnp.arange(seq, dtype=jnp.int32)[None].repeat(b, 0) + offset
            return jnp.stack([pos, pos, pos])
        return jnp.arange(seq, dtype=jnp.int32)[None].repeat(b, 0) + offset

    # ------------------------------------------------------------- encoders
    def _encode(self, params, batch):
        cfg = self.cfg
        frames = batch["frames"].astype(L.dtype_of(cfg))       # (b, s, d) stub
        b, s, d = frames.shape
        pos = jnp.arange(s, dtype=jnp.int32)[None].repeat(b, 0)
        # sinusoidal positions (whisper style)
        half = d // 2
        freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                        / max(half - 1, 1))
        ang = pos[..., None].astype(jnp.float32) * freqs
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
        x = frames + pe.astype(frames.dtype)
        x, _, _, _ = _scan_blocks(params["encoder"], x, cfg, "enc",
                                  positions=pos, caches=None, rnn_states=None,
                                  remat=(cfg.remat == "full"))
        return L.layernorm(x, params["enc_norm"], params["enc_normb"],
                           cfg.norm_eps)

    def _cross_kv(self, params, enc_out):
        """Precompute per-layer cross K/V from encoder output (stacked)."""
        cfg = self.cfg

        def per_layer(pl):
            k = jnp.einsum("bsd,dhk->bshk", enc_out, pl["xattn"]["wk"])
            v = jnp.einsum("bsd,dhk->bshk", enc_out, pl["xattn"]["wv"])
            return k, v

        return jax.vmap(per_layer)(params["layers"])            # (L, b, s, kv, hd)

    # ----------------------------------------------------------------- train
    def loss_fn(self, params, batch):
        cfg = self.cfg
        kind = _block_kind(cfg)
        remat = cfg.remat == "full"

        if cfg.family == "encdec":
            enc_out = self._encode(params, batch)
            xk, xv = self._cross_kv(params, enc_out)
            tokens = batch["tokens"]                            # decoder tokens
            x = L.embed(params["embed"], tokens)
            pos = self._positions(batch, tokens.shape[1])
            x, aux, _, _ = self._dec_scan(params, x, pos, (xk, xv), remat)
        else:
            x = self._embed_inputs(params, batch)
            pos = self._positions(batch, x.shape[1])
            first_aux = jnp.float32(0.0)
            if cfg.moe_first_dense:
                x, first_aux, _, _ = _scan_blocks(
                    params["first_layers"], x, cfg, "dense_ffn_moe_arch",
                    positions=pos, remat=remat)
            x, aux, _, _ = _scan_blocks(params["layers"], x, cfg, kind,
                                        positions=pos, remat=remat)
            aux = aux + first_aux

        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        x = shard(x, ("batch", "act_seq", "embed"))
        loss, ntok = _chunked_xent(params["embed"], x, batch["targets"], cfg)
        total = loss + AUX_WEIGHT * aux
        return total, {"loss": loss, "aux": aux, "tokens": ntok}

    def _dec_scan(self, params, x, pos, cross_kv, remat):
        """Decoder scan with per-layer cross-KV (stacked along the scan axis)."""
        cfg = self.cfg
        xk, xv = cross_kv

        def body(carry, inp):
            x, aux = carry
            p, k_l, v_l = inp
            x = shard(x, ("batch", "act_seq", "embed"))
            x, a, _, _ = _apply_block(p, x, cfg, "dec", positions=pos,
                                      cross_kv=(k_l, v_l))
            return (x, aux + a), None

        fn = jax.checkpoint(body) if remat else body
        (x, aux), _ = jax.lax.scan(fn, (x, jnp.float32(0.0)),
                                   (params["layers"], xk, xv))
        return x, aux, None, None

    # --------------------------------------------------------------- prefill
    def prefill_fn(self, params, batch):
        """Forward with cache writes; returns (last logits (b, v), decode state)."""
        cfg = self.cfg
        kind = _block_kind(cfg)
        b = batch["tokens"].shape[0]

        if cfg.family == "encdec":
            enc_out = self._encode(params, batch)
            xk, xv = self._cross_kv(params, enc_out)
            tokens = batch["tokens"]
            s = tokens.shape[1]
            x = L.embed(params["embed"], tokens)
            pos = self._positions(batch, s)
            caches = self._self_caches(b, cfg.decoder_len)

            def body(x, inp):
                p, cache, k_l, v_l = inp
                x = shard(x, ("batch", "act_seq", "embed"))
                h = L.layernorm(x, p["ln1"], p["lnb1"], cfg.norm_eps)
                y, new_cache = L.attention(p["attn"], h, cfg, positions=pos,
                                           causal=True, cache=cache)
                x = x + y
                h = L.layernorm(x, p["ln3"], p["lnb3"], cfg.norm_eps)
                y, _ = L.attention(p["xattn"], h, cfg, positions=pos,
                                   cross_kv=(k_l, v_l))
                x = x + y
                h = L.layernorm(x, p["ln2"], p["lnb2"], cfg.norm_eps)
                x = x + L.mlp(p["mlp"], h)
                return x, new_cache

            x, new_caches = jax.lax.scan(
                body, x, (params["layers"], caches, xk, xv))
            state = {"kv": new_caches, "cross": (xk, xv)}
        else:
            x = self._embed_inputs(params, batch)
            s = x.shape[1]
            pos = self._positions(batch, s)
            caches, rnn = self._inner_state(b, self._cache_len(s), s)
            state = {}
            if cfg.moe_first_dense:
                fcaches = self._self_caches(b, self._cache_len(s),
                                            n=cfg.moe_first_dense)
                x, _, fkv, _ = _scan_blocks(params["first_layers"], x, cfg,
                                            "dense_ffn_moe_arch", positions=pos,
                                            caches=fcaches, remat=False)
                state["kv_first"] = fkv
            x, _, new_caches, new_rnn = _scan_blocks(
                params["layers"], x, cfg, kind, positions=pos, caches=caches,
                rnn_states=rnn, remat=False)
            state.update(kv=new_caches, rnn=new_rnn)

        x = L.rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = L.unembed(params["embed"], x.astype(L.dtype_of(cfg)))
        return logits[:, 0].astype(jnp.float32), state

    # ---------------------------------------------------------------- decode
    def decode_fn(self, params, state, tokens, length):
        """One token for every sequence in the batch. tokens: (b, 1)."""
        cfg = self.cfg
        kind = _block_kind(cfg)
        b = tokens.shape[0]
        x = L.embed(params["embed"], tokens)
        if cfg.m_rope:
            pos1 = jnp.full((b, 1), length, jnp.int32)
            pos = jnp.stack([pos1, pos1, pos1])
        else:
            pos = jnp.full((b, 1), length, jnp.int32)

        if cfg.family == "encdec":
            xk, xv = state["cross"]

            def body(x, inp):
                p, cache, k_l, v_l = inp
                h = L.layernorm(x, p["ln1"], p["lnb1"], cfg.norm_eps)
                y, new_cache = L.attention(p["attn"], h, cfg, positions=pos,
                                           causal=True, cache=cache)
                x = x + y
                h = L.layernorm(x, p["ln3"], p["lnb3"], cfg.norm_eps)
                y, _ = L.attention(p["xattn"], h, cfg, positions=pos,
                                   cross_kv=(k_l, v_l))
                x = x + y
                h = L.layernorm(x, p["ln2"], p["lnb2"], cfg.norm_eps)
                x = x + L.mlp(p["mlp"], h)
                return x, new_cache

            x, new_caches = jax.lax.scan(body, x, (params["layers"],
                                                   state["kv"], xk, xv))
            new_state = {"kv": new_caches, "cross": state["cross"]}
        else:
            caches, rnn = state.get("kv"), state.get("rnn")
            new_state = {}
            if cfg.moe_first_dense:
                x, _, fkv, _ = _scan_blocks(params["first_layers"], x, cfg,
                                            "dense_ffn_moe_arch", positions=pos,
                                            caches=state["kv_first"],
                                            remat=False, decode=True)
                new_state["kv_first"] = fkv
            x, _, new_caches, new_rnn = _scan_blocks(
                params["layers"], x, cfg, kind, positions=pos, caches=caches,
                rnn_states=rnn, remat=False, decode=True)
            new_state.update(kv=new_caches, rnn=new_rnn)

        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = L.unembed(params["embed"], x.astype(L.dtype_of(cfg)))
        return logits[:, 0].astype(jnp.float32), new_state

    # ------------------------------------------------------- state factories
    def _cache_len(self, seq: int) -> int:
        cfg = self.cfg
        if cfg.family == "ssm":
            return 0
        base = seq + cfg.cache_headroom
        if cfg.window > 0:
            return min(cfg.window, base)
        return base

    def _self_caches(self, b, cache_len, n=None):
        cfg = self.cfg
        if n is None:
            n = cfg.n_layers - cfg.moe_first_dense
        dt = L.cache_dtype(cfg)
        z = jnp.zeros((n, b, cache_len, cfg.n_kv, cfg.head_dim), dt)
        return L.KVCache(k=z, v=z, length=jnp.zeros((n,), jnp.int32))

    def _inner_state(self, b, cache_len, seq):
        cfg = self.cfg
        kind = _block_kind(cfg)
        n = cfg.n_layers - cfg.moe_first_dense
        caches = None
        rnn = None
        if kind in ("dense", "moe"):
            caches = self._self_caches(b, cache_len)
        elif kind == "hybrid":
            caches = self._self_caches(b, cache_len)
            rnn = {"ssd": jnp.zeros((n, b, cfg.n_heads, cfg.ssm_state,
                                     cfg.head_dim), jnp.float32)}
        elif kind == "rwkv":
            d = cfg.d_model
            dt = L.dtype_of(cfg)
            rnn = {
                "S": jnp.zeros((n, b, cfg.n_heads, cfg.head_dim, cfg.head_dim),
                               jnp.float32),
                "tm_prev": jnp.zeros((n, b, 1, d), dt),
                "cm_prev": jnp.zeros((n, b, 1, d), dt),
            }
        return caches, rnn

    # ------------------------------------------------------------ input specs
    def input_specs(self, shape: ShapeConfig) -> dict:
        """ShapeDtypeStruct stand-ins for every model input (dry-run, no alloc)."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32, f = jnp.int32, jnp.dtype(cfg.dtype)
        sds = jax.ShapeDtypeStruct
        if shape.mode == "train":
            out = {"tokens": sds((b, s), i32), "targets": sds((b, s), i32)}
            if cfg.family == "encdec":
                out = {"frames": sds((b, s, cfg.d_model), f),
                       "tokens": sds((b, cfg.decoder_len), i32),
                       "targets": sds((b, cfg.decoder_len), i32)}
            if cfg.family == "vlm":
                out["patch_embeds"] = sds((b, VLM_PATCHES, cfg.d_model), f)
                out["positions3"] = sds((3, b, s), i32)
            return out
        if shape.mode == "prefill":
            out = {"tokens": sds((b, s), i32)}
            if cfg.family == "encdec":
                out = {"frames": sds((b, s, cfg.d_model), f),
                       "tokens": sds((b, cfg.decoder_len), i32)}
            if cfg.family == "vlm":
                out["patch_embeds"] = sds((b, VLM_PATCHES, cfg.d_model), f)
                out["positions3"] = sds((3, b, s), i32)
            return out
        return {"tokens": sds((b, 1), i32)}

    def decode_state_specs(self, shape: ShapeConfig):
        """Decode-state stand-ins matching prefill_fn's output structure.

        Built with eval_shape — no allocation, safe for 500k-token cache specs.
        """
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len

        def make():
            if cfg.family == "encdec":
                n = cfg.n_layers
                dt = L.dtype_of(cfg)
                xk = jnp.zeros((n, b, s, cfg.n_kv, cfg.head_dim), dt)
                return {"kv": self._self_caches(b, cfg.decoder_len),
                        "cross": (xk, xk)}
            state = {}
            if cfg.moe_first_dense:
                state["kv_first"] = self._self_caches(
                    b, self._cache_len(s), n=cfg.moe_first_dense)
            caches, rnn = self._inner_state(b, self._cache_len(s), s)
            state.update(kv=caches, rnn=rnn)
            return state

        return jax.eval_shape(make)


# ------------------------------------------------------------- chunked loss
def _chunked_xent(embed_params, x, targets, cfg: ModelConfig):
    """Cross-entropy without materializing full-seq logits (seq-chunked).

    Big-vocab models (moonshot: 163840) would otherwise hold (b, s, v) f32.
    Targets < 0 are masked (padding).
    """
    b, s, d = x.shape
    n_chunks = math.gcd(LOSS_CHUNKS, s)
    c = s // n_chunks
    xc = x.reshape(b, n_chunks, c, d).swapaxes(0, 1)
    tc = targets.reshape(b, n_chunks, c).swapaxes(0, 1)

    def one(chunk):
        xb, tb = chunk
        logits = L.unembed(embed_params, xb).astype(jnp.float32)   # (b,c,v)
        m = jnp.max(logits, axis=-1, keepdims=True)
        lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
        ll = jnp.take_along_axis(logits, jnp.maximum(tb, 0)[..., None],
                                 axis=-1)[..., 0]
        mask = (tb >= 0).astype(jnp.float32)
        return jnp.sum((lse - ll) * mask), jnp.sum(mask)

    losses, counts = jax.lax.map(one, (xc, tc))
    ntok = jnp.maximum(jnp.sum(counts), 1.0)
    return jnp.sum(losses) / ntok, jnp.sum(counts)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
