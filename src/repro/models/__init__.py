"""Model zoo: 10 architectures across 6 families (DESIGN.md section 4)."""
