"""Straggler detection + mitigation — the paper's scheduler as a fleet feature.

The paper's motivation (§4.1): "by assigning simulation jobs to be executed on slow
workstation all other simulation jobs are affected ... because of the need to
maintain causal consistency". A gang-scheduled SPMD training step has exactly the
same failure mode: the step time is the max over hosts.

Detection: per-host EWMA of step wall time; a host whose EWMA exceeds
``threshold``x the fleet median is flagged. Mitigation: feed the measured slowness
into the paper's performance values (core.scheduler) and re-place DES LPs away from
the slow host; for the training fleet, surface an eviction/re-mesh recommendation
consumed by ft/elastic.py (demote to a smaller healthy mesh).
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp

from repro.core import scheduler as sched


@dataclasses.dataclass
class StragglerMonitor:
    n_hosts: int
    alpha: float = 0.2
    threshold: float = 1.5

    def __post_init__(self):
        self.ewma = np.zeros(self.n_hosts)
        self.count = np.zeros(self.n_hosts, dtype=int)

    def record(self, host: int, step: int, seconds: float):
        if self.count[host] == 0:
            self.ewma[host] = seconds
        else:
            self.ewma[host] = (1 - self.alpha) * self.ewma[host] \
                + self.alpha * seconds
        self.count[host] += 1

    def stragglers(self) -> list[int]:
        seen = self.count > 0
        if seen.sum() < 2:
            return []
        med = float(np.median(self.ewma[seen]))
        return [h for h in range(self.n_hosts)
                if seen[h] and self.ewma[h] > self.threshold * max(med, 1e-9)]

    # ---- paper-scheduler mitigation (DES fleet) ----------------------------
    def replacement_plan(self, lp_agent, lp_ctx):
        """Re-place LPs with the paper's §4.1 algorithm, with measured slowness
        folded into the performance values (slow agents look expensive)."""
        perf = jnp.asarray(np.where(self.count > 0, self.ewma, self.ewma.mean()
                                    if self.count.any() else 1.0),
                           jnp.float32)
        perf = perf / jnp.maximum(jnp.min(perf), 1e-9)   # relative slowness
        return sched.plan_placement(perf * 10.0, jnp.asarray(lp_ctx),
                                    self.n_hosts)

    def eviction_recommendation(self) -> dict:
        s = self.stragglers()
        return {"evict_hosts": s, "healthy": [h for h in range(self.n_hosts)
                                              if h not in s]}
