"""repro.ft subpackage."""
