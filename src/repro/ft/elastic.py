"""Elastic scaling: rebuild the mesh from surviving hosts and re-shard state.

Flow on failure (or straggler eviction):
  1. `plan_remesh(n_alive)` picks the largest supported (data, model) grid that
     fits the survivors, preferring to shrink the *data* axis (batch re-division
     is free with the stateless pipeline) before touching *model* (weight layout).
  2. `reshard_plan(old, new)` describes, per logical axis, gather/slice factors —
     with the stateless data pipeline (data/pipeline.py) and logical-rules
     sharding, re-sharding params is a device_put with the new NamedSharding.
  3. The checkpointer restores the last committed step when the fleet must
     restart cold; warm re-meshing reuses in-HBM state on survivors.

The DES core is elastic by construction: the scheduler (C3) re-places LPs on the
surviving agents (Engine.apply_placement_local) and replicated component state
(C4) means no LP state is lost with a failed agent — the paper's replication
argument becoming a fault-tolerance property.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    axes: tuple[str, ...]
    shape: tuple[int, ...]

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def plan_remesh(n_alive: int, *, model_parallel: int = 16,
                multi_pod: bool = False) -> MeshPlan:
    """Largest power-of-two mesh <= n_alive keeping the model axis intact.

    Shrinking `model` would re-layout every weight shard; shrinking `data` only
    changes the batch divisor, so data gives way first. If fewer than one model
    group survives, model halves (weights re-gathered from checkpoint shards).
    """
    assert n_alive >= 1
    mp = model_parallel
    while mp > n_alive:
        mp //= 2
    dp = 1
    while dp * 2 * mp <= n_alive:
        dp *= 2
    if multi_pod and dp % 2 == 0:
        return MeshPlan(("pod", "data", "model"), (2, dp // 2, mp))
    return MeshPlan(("data", "model"), (dp, mp))


def reshard_plan(old: MeshPlan, new: MeshPlan) -> dict:
    """Logical description of the state movement between meshes."""
    o = dict(zip(old.axes, old.shape))
    n = dict(zip(new.axes, new.shape))
    plan = {}
    for ax in ("pod", "data", "model"):
        a, b = o.get(ax, 1), n.get(ax, 1)
        if a == b:
            plan[ax] = "keep"
        elif a > b:
            plan[ax] = f"gather x{a // b}"     # fewer shards: all-gather groups
        else:
            plan[ax] = f"split x{b // a}"      # more shards: slice locally
    plan["batch_divisor"] = n.get("pod", 1) * n.get("data", 1)
    return plan


def validate_plan(plan: MeshPlan, n_alive: int) -> bool:
    return 1 <= plan.n_devices <= n_alive
