"""jit'd public wrappers for the Pallas kernels.

Dispatch policy: on TPU backends the compiled kernels run natively; everywhere
else (this CPU container, unit tests) ``interpret=True`` executes the same kernel
bodies in Python for correctness validation against ref.py. The model zoo calls
these through cfg.use_flash / engine select_fn hooks, so the XLA fallbacks and
the kernels are interchangeable implementations of identical math.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import bandwidth_share as _bw
from repro.kernels import event_select as _es
from repro.kernels import flash_attention as _fa
from repro.kernels import rwkv6_scan as _gla
from repro.kernels import ssm_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128, block_k=128):
    """q: (BH, Sq, D); k, v: (BKV, Skv, D). GQA via BH % BKV grouping."""
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def rwkv6_scan(q, k, v, w, u, *, chunk=64):
    """RWKV6 chunked recurrence. (BH, S, d) operands, u: (BH, d)."""
    return _gla.gla_pallas(q, k, v, w, u, mode="k", chunk=chunk,
                           interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(q, k, v, w, *, chunk=64):
    """Mamba2-style SSD chunked recurrence (decay on V channels)."""
    return _ssd.ssd_pallas(q, k, v, w, chunk=chunk, interpret=_interpret())


@jax.jit
def sort_events(time_key, seq):
    """(CAP,) -> permutation ascending by (time, seq). Engine sort hook."""
    return _es.sort_events(time_key, seq, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("exec_cap",))
def select_events(time_key, seq, exec_cap):
    """(CAP,) -> (exec_cap,) compacted gather indices. Engine select_fn hook."""
    return _es.select_events(time_key, seq, exec_cap, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("n_kinds",))
def group_by_kind(kind, active, n_kinds):
    """(CAP,) kinds + active mask -> (order, rank, counts). Engine group_fn
    hook for batched same-kind dispatch (segment-rank Pallas kernel).

    ``n_kinds`` is the model's kind count — registry-dependent since PR 4, so
    it must come from the scenario: bind it with
    ``functools.partial(ops.group_by_kind, n_kinds=engine.registry.n_kinds)``
    when wiring the hook.
    """
    return _es.group_by_kind(kind, active, n_kinds, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("exec_cap", "n_kinds", "n_res",
                                             "n_tables"))
def fused_select(time_key, seq, safe, time, kind, src, dst, ctx, payload,
                 valid, table_id, res, free_tail, exec_cap, *, n_kinds,
                 n_res, n_tables=None):
    """The superstep megakernel: the whole window front-end in one call.

    Fuses select + gather + conflict mask + group_by_kind + release ranks
    (kernels.event_select.fused_select) with the free-ring cursor in SMEM on
    TPU. Engine fused_fn hook — ``spec.fused_select=True`` binds it as

        functools.partial(ops.fused_select, n_kinds=registry.n_kinds,
                          n_res=registry.max_rows(world),
                          n_tables=registry.n_tables)

    The stitched twins (engine.fused_select_xla, kernels.ref.fused_select_ref)
    are the byte-compatibility references the tests sweep against.
    """
    return _es.fused_select(time_key, seq, safe, time, kind, src, dst, ctx,
                            payload, valid, table_id, res, free_tail,
                            exec_cap, n_kinds=n_kinds, n_res=n_res,
                            n_tables=n_tables, interpret=_interpret())


@jax.jit
def ring_slots(free_ring, head, want):
    """(cap,) free ring + head + (n,) insert mask -> (n,) destination slots.

    The free-ring variant of the event-pool insert (Pallas prefix-sum +
    chunked one-hot ring gather). Hook it into the pool with
    ``events.insert(pool, batch, slot_fn=ops.ring_slots)``; the default XLA
    path inside ``events.insert`` is the reference (kernels.ref.ring_slots_ref
    — tests sweep kernel vs reference).
    """
    return _es.ring_slots(free_ring, head, want, interpret=_interpret())


@jax.jit
def trace_rank(mask):
    """(n,) processed mask -> (n,) exclusive prefix ranks.

    The trace-ring append's position math (streaming-trace drain, PR 5 ring
    idiom): masked window lane r writes trace slot ``(trace_n + rank[r]) %
    trace_cap``. Hook it into the engine with ``Engine(...,
    trace_fn=ops.trace_rank)``; the default XLA cumsum inside
    ``events.trace_append`` is the reference (kernels.ref.trace_rank_ref —
    tests sweep kernel vs reference).
    """
    return _es.trace_rank(mask, interpret=_interpret())


@jax.jit
def route_rank(dst_agent):
    """(n,) destination buckets -> (n,) stable within-bucket ranks.

    The emit-routing pack for the engine's all_to_all exchange (and the
    migration re-home): flat scatter slot = ``dst * route_cap + rank``. Hook
    it into the engine with ``Engine(..., route_fn=ops.route_rank)``; the
    default XLA path (engine.route_rank_xla == kernels.ref.route_rank_ref)
    is the reference the tests sweep against.
    """
    return _es.route_rank(dst_agent, interpret=_interpret())


@jax.jit
def maxmin_rates(inc, bw, active):
    """(F, L), (L,), (F,) -> (F,) max-min fair rates."""
    return _bw.maxmin_rates_pallas(inc, bw, active, interpret=_interpret())
