"""Flash attention (causal / sliding-window) as a Pallas TPU kernel.

Tiling: grid = (batch*q_heads, n_q_blocks, n_kv_blocks); the kv dimension is the
minor-most grid axis, so TPU executes it sequentially per (bh, q_block) and the
online-softmax running state (m, l, acc) lives in VMEM scratch across kv steps.
GQA is handled in the index_map (kv block index = head // group) — no repeated-KV
materialization. Block shapes default to 128 (MXU-aligned lanes).

The HBM win vs the XLA path: scores (s_q x s_kv) never leave VMEM. On a v5e with
bq = bk = 128 and head_dim 128 the working set is
  q(128x128x4) + k + v + acc + scores ~= 0.4 MB << 64 MB VMEM,
leaving room for double-buffered pipelining of the k/v streams.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               bq: int, bk: int, n_kv_blocks: int, causal: bool, window: int,
               scale: float):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                      # (bq, d)
    k = k_ref[0].astype(jnp.float32)                      # (bk, d)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
        if window > 0:
            mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                   # (bq,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    m_ref[...] = m_new
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                          preferred_element_type=jnp.float32))

    @pl.when(ik == n_kv_blocks - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...][:, None], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=0, block_q=128, block_k=128,
                    interpret=False):
    """q: (BH, Sq, D); k, v: (BKV, Skv, D) with BH % BKV == 0 (GQA grouping)."""
    bh, sq, d = q.shape
    bkv, skv, _ = k.shape
    assert bh % bkv == 0
    group = bh // bkv
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    n_q, n_k = sq // bq, skv // bk
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_fa_kernel, bq=bq, bk=bk, n_kv_blocks=n_k,
                               causal=causal, window=window, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, q_, k_: (b, q_, 0)),
            pl.BlockSpec((1, bk, d), lambda b, q_, k_, g=group: (b // g, k_, 0)),
            pl.BlockSpec((1, bk, d), lambda b, q_, k_, g=group: (b // g, k_, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, q_, k_: (b, q_, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # m: running max
            pltpu.VMEM((bq,), jnp.float32),      # l: running denominator
            pltpu.VMEM((bq, d), jnp.float32),    # acc: running numerator
        ],
        interpret=interpret,
    )(q, k, v)
