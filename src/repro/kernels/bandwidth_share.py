"""Max–min fair bandwidth sharing (progressive filling) as a Pallas kernel.

The paper's interrupt-based traffic model recomputes every flow's fair share on
each flow start/end — the per-event hot spot of the network component (§4.2, the
Fig-2 event storm). The fixed point is computed by at most L water-filling rounds;
each round is two (L,F)x(F,) matvecs + reductions, all VMEM-resident. Mirrors
core.network.maxmin_rates bit-for-bit in f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-6
_BIG = 3.0e38


def _waterfill_kernel(inc_ref, bw_ref, act_ref, rate_ref, *, n_flows: int,
                      n_links: int):
    inc = inc_ref[...]                      # (F, L)
    bw = bw_ref[0]                          # (L,)
    active = act_ref[0]                     # (F,) f32 0/1
    inc = inc * active[:, None]

    def round_(_, carry):
        rate, frozen = carry                # (F,), (F,) f32
        unfrozen = active * (1.0 - frozen)
        n_unf = jax.lax.dot_general(inc, unfrozen, (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        used = jax.lax.dot_general(inc, rate * frozen, (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)
        resid = jnp.maximum(bw - used, 0.0)
        fair = jnp.where(n_unf > 0, resid / jnp.maximum(n_unf, 1.0), _BIG)
        fair = jnp.where((bw <= 0) & (n_unf > 0), 0.0, fair)
        level = jnp.min(fair)
        bottleneck = (fair <= level + _EPS).astype(jnp.float32)
        hits = jax.lax.dot_general(inc, bottleneck, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32) > 0
        newly = unfrozen * hits.astype(jnp.float32)
        rate = jnp.where(newly > 0, level, rate)
        frozen = jnp.maximum(frozen, newly)
        return rate, frozen

    rate0 = jnp.zeros((n_flows,), jnp.float32)
    frozen0 = 1.0 - active
    rate, _ = jax.lax.fori_loop(0, n_links, round_, (rate0, frozen0))
    rate_ref[0] = jnp.where(active > 0, rate, 0.0)


def maxmin_rates_pallas(inc: jax.Array, bw: jax.Array, active: jax.Array, *,
                        interpret=None) -> jax.Array:
    """inc: (F, L) 0/1 f32; bw: (L,); active: (F,) bool -> (F,) f32 rates.

    ``interpret=None`` resolves the backend policy (compiled on TPU,
    interpreted elsewhere) — the same dispatch every other kernel gets via
    its ``ops.py`` wrapper, so a direct call is safe on any backend too.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    f, l = inc.shape
    kernel = functools.partial(_waterfill_kernel, n_flows=f, n_links=l)
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((f, l), lambda i: (0, 0)),
                  pl.BlockSpec((1, l), lambda i: (0, 0)),
                  pl.BlockSpec((1, f), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, f), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, f), jnp.float32),
        interpret=interpret,
    )(inc.astype(jnp.float32), bw[None], active.astype(jnp.float32)[None])[0]
