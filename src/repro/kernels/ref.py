"""Pure-jnp oracles for every Pallas kernel (the allclose targets in tests)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.network import maxmin_rates as maxmin_rates_ref  # noqa: F401
from repro.models.linear_rnn import gla_ref  # noqa: F401

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=0):
    """q: (BH, Sq, D); k, v: (BKV, Skv, D); GQA via head grouping."""
    bh, sq, d = q.shape
    bkv, skv, _ = k.shape
    group = bh // bkv
    kr = jnp.repeat(k, group, axis=0)
    vr = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / math.sqrt(d)
    if causal:
        qp = jnp.arange(sq)[:, None]
        kp = jnp.arange(skv)[None, :]
        mask = kp <= qp
        if window > 0:
            mask = mask & (kp > qp - window)
        s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, vr.astype(jnp.float32)).astype(q.dtype)


def sort_events_ref(time_key: jax.Array, seq: jax.Array) -> jax.Array:
    """Stable (time, seq) sort permutation — mirror of engine.lexsort_time_seq."""
    perm = jnp.argsort(seq, stable=True)
    perm2 = jnp.argsort(time_key[perm], stable=True)
    return perm[perm2]


def select_events_ref(time_key: jax.Array, seq: jax.Array,
                      exec_cap: int) -> jax.Array:
    """Compacted gather indices: first ``exec_cap`` of the stable (time, seq)
    sort — the XLA reference for kernels.event_select.select_events."""
    return sort_events_ref(time_key, seq)[: min(exec_cap, time_key.shape[0])]


def ring_slots_ref(free_ring: jax.Array, head: jax.Array,
                   want: jax.Array) -> jax.Array:
    """Free-ring insert slot assignment — XLA reference for
    kernels.event_select.ring_slots (the math inside events.insert)."""
    cap = free_ring.shape[0]
    w = want.astype(jnp.int32)
    rank = jnp.cumsum(w) - w                      # exclusive prefix
    return free_ring[(jnp.asarray(head, jnp.int32) + rank) % cap]


def trace_rank_ref(mask: jax.Array) -> jax.Array:
    """Exclusive prefix rank of the processed mask — XLA reference for
    kernels.event_select.trace_rank (the trace-ring append position math)."""
    w = mask.astype(jnp.int32)
    return jnp.cumsum(w) - w


def route_rank_ref(dst_agent: jax.Array) -> jax.Array:
    """Stable within-bucket routing ranks — XLA reference for
    kernels.event_select.route_rank (the emit-routing pack inside
    engine._route_and_insert): rank[i] = |{j < i : dst_agent[j] == dst_agent[i]}|."""
    sperm = jnp.argsort(dst_agent, stable=True)
    skey = dst_agent[sperm]
    group_start = jnp.searchsorted(skey, skey, side="left")
    rank_sorted = jnp.arange(skey.shape[0], dtype=jnp.int32) - group_start
    return jnp.zeros_like(rank_sorted).at[sperm].set(rank_sorted)


def group_by_kind_ref(kind: jax.Array, active: jax.Array, n_kinds: int):
    """Same-kind grouping (order, rank, counts) — XLA reference for
    kernels.event_select.group_by_kind; mirror of engine.group_by_kind_xla."""
    key = jnp.where(active, jnp.clip(kind, 0, n_kinds - 1), jnp.int32(n_kinds))
    order = jnp.argsort(key, stable=True).astype(jnp.int32)
    ks = key[order]
    start = jnp.searchsorted(ks, ks, side="left").astype(jnp.int32)
    rank = jnp.arange(ks.shape[0], dtype=jnp.int32) - start
    counts = jnp.zeros((n_kinds,), jnp.int32).at[key].add(1, mode="drop")
    return order, rank, counts


def fused_select_ref(time_key, seq, safe, time, kind, src, dst, ctx, payload,
                     valid, table_id, res, free_tail, exec_cap, *,
                     n_kinds: int, n_res: int, n_tables: int | None = None):
    """Stitched oracle for the fused window front-end
    (kernels.event_select.fused_select): select, gather, pairwise conflict
    count, group, release rank — composed from the ref primitives above,
    deliberately NOT sharing code with engine.fused_select_xla so the two
    stitched paths check each other."""
    from repro.kernels.event_select import FusedSelect
    del n_tables  # the pairwise count needs no sentinel key space
    cap = time_key.shape[0]
    m = max(min(exec_cap, cap), 1)
    exec_idx = select_events_ref(time_key, seq, m)
    es = safe[exec_idx]
    tb = table_id[exec_idx]
    rkey = tb * jnp.int32(n_res) + res[exec_idx]
    comp = es & (tb > 0)
    cnt = jnp.sum((rkey[:, None] == rkey[None, :])
                  & comp[None, :], axis=1)
    dirty = comp & (cnt >= 2)
    clean = es & ~dirty
    kind_w = kind[exec_idx]
    order, _rank, _counts = group_by_kind_ref(kind_w, clean, n_kinds)
    w = es.astype(jnp.int32)
    rel = (jnp.asarray(free_tail, jnp.int32) + jnp.cumsum(w) - w) % cap
    return FusedSelect(
        exec_idx=exec_idx, exec_safe=es, time=time[exec_idx],
        seq=seq[exec_idx], kind=kind_w, src=src[exec_idx],
        dst=dst[exec_idx], ctx=ctx[exec_idx], payload=payload[exec_idx],
        valid=valid[exec_idx], clean=clean, order=order, rel_pos=rel)
