"""Event-selection Pallas kernel: the DES engine's per-window (time, seq) sort.

The conservative window's hot loop starts by ordering the event pool by
(timestamp, tie-break seq) with unsafe slots pushed to the back (their key is
T_INF). This kernel runs a bitonic sorting network entirely in VMEM over the
(time, seq, index) triple — log^2(N) vectorized compare-exchange stages, no HBM
traffic beyond one read and one write of the pool keys. The XOR-partner exchange
of the classic network is expressed as a (N/2j, 2, j) reshape + pair swap, which
vectorizes on the VPU.

``sort_events`` outputs the full permutation (i32 indices), matching
engine.lexsort_time_seq exactly (stable for equal (time, seq) pairs because the
index participates as the final tie-break, and input indices are distinct).
``select_events`` is the compacted variant for the engine's windowed execution:
sort + safe-prefix in one pass — only the first ``exec_cap`` indices leave VMEM,
so the engine can gather exactly the slots it will execute.

Invariants the engine's batched dispatch relies on (docs/architecture.md):

* **Stable (time, seq) prefix** — the ``select_events`` output is byte-identical
  to ``lexsort_time_seq(...)[:exec_cap]``; the engine's trace is written in this
  window order, so any kernel deviation breaks oracle trace equality, not just
  performance.
* **Segment-rank ordering** — ``group_by_kind`` returns active rows first,
  grouped by ascending kind, *stable in original window position within each
  kind*; ``rank`` is each row's index inside its kind segment and ``counts`` the
  per-kind populations. The dispatcher scatters handler emits back through this
  permutation, so stability is what keeps the flattened emit matrix equal to the
  sequential fold's append order. Both kernels must stay interchangeable with
  their XLA references (engine.group_by_kind_xla / select_events_xla) — the
  tests sweep kernel vs reference over random inputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

I32_MAX = jnp.int32(2**31 - 1)


def _lex_less(t1, s1, i1, t2, s2, i2):
    return ((t1 < t2)
            | ((t1 == t2) & (s1 < s2))
            | ((t1 == t2) & (s1 == s2) & (i1 < i2)))


def _sort_kernel(time_ref, seq_ref, perm_ref, *, n: int):
    t = time_ref[0]                        # (n,)
    s = seq_ref[0]
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)[0]

    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            def pairs(x):
                return x.reshape(n // (2 * j), 2, j)

            tp, sp, ip = pairs(t), pairs(s), pairs(idx)
            lo_i = jax.lax.broadcasted_iota(jnp.int32, (n // (2 * j), 1, j), 0)
            lo_r = jax.lax.broadcasted_iota(jnp.int32, (n // (2 * j), 1, j), 2)
            lo_index = lo_i * (2 * j) + lo_r                  # global index of lo
            ascend = (lo_index & k) == 0                      # (g, 1, j)

            t_lo, t_hi = tp[:, :1], tp[:, 1:]
            s_lo, s_hi = sp[:, :1], sp[:, 1:]
            i_lo, i_hi = ip[:, :1], ip[:, 1:]
            le = _lex_less(t_lo, s_lo, i_lo, t_hi, s_hi, i_hi)
            swap = jnp.where(ascend, ~le, le)

            def mix(lo, hi):
                nlo = jnp.where(swap, hi, lo)
                nhi = jnp.where(swap, lo, hi)
                return jnp.concatenate([nlo, nhi], axis=1).reshape(n)

            t, s, idx = mix(t_lo, t_hi), mix(s_lo, s_hi), mix(i_lo, i_hi)
            j //= 2
        k *= 2

    # the out block may be a prefix of the sorted permutation (select_events)
    perm_ref[0] = idx[: perm_ref.shape[1]]


def _run_sort(time_key: jax.Array, seq: jax.Array, m: int, *, interpret):
    """Shared pallas_call: sort padded keys, emit the first ``m`` indices."""
    cap = time_key.shape[0]
    n = 1 << max((cap - 1).bit_length(), 1)
    mpad = 1 << max((m - 1).bit_length(), 1)
    tpad = jnp.full((n,), I32_MAX, jnp.int32).at[:cap].set(time_key)[None]
    spad = jnp.full((n,), I32_MAX, jnp.int32).at[:cap].set(seq)[None]
    kernel = functools.partial(_sort_kernel, n=n)
    perm = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (0, 0)),
                  pl.BlockSpec((1, n), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, mpad), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, mpad), jnp.int32),
        interpret=interpret,
    )(tpad, spad)
    return perm[0, :m]


def sort_events(time_key: jax.Array, seq: jax.Array, *, interpret=False):
    """(CAP,) i32 keys -> (CAP,) i32 permutation, ascending (time, seq)."""
    return _run_sort(time_key, seq, time_key.shape[0], interpret=interpret)


def select_events(time_key: jax.Array, seq: jax.Array, exec_cap: int, *,
                  interpret=False):
    """Compacted gather indices: first ``exec_cap`` of the (time, seq) sort.

    With unsafe slots keyed T_INF, the returned indices are the ``exec_cap``
    earliest safe pool slots (then, if fewer are safe, unsafe filler the engine
    masks out). One kernel pass; only the prefix is written back.
    """
    return _run_sort(time_key, seq, min(exec_cap, time_key.shape[0]),
                     interpret=interpret)


def _group_kernel(kind_ref, act_ref, order_ref, rank_ref, counts_ref, *,
                  n: int, n_kinds: int):
    """Segment-rank grouping: bitonic sort by (kind, index) + in-VMEM ranks.

    Active rows get key = kind, inactive rows key = n_kinds (grouping them
    after every real kind), zero-padding beyond the caller's cap sorts last
    (its index exceeds every real row's). After the sort the grouped index
    vector IS the permutation; segment ranks fall out of a static loop over
    the n_kinds+1 possible keys (position minus the segment's exclusive
    prefix count), so no dynamic gather is needed on the VPU.
    """
    kd = kind_ref[0]                       # (n,)
    act = act_ref[0] != 0
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)[0]
    key = jnp.where(act, jnp.clip(kd, 0, n_kinds - 1), jnp.int32(n_kinds))

    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            def pairs(x):
                return x.reshape(n // (2 * j), 2, j)

            kp, ip = pairs(key), pairs(idx)
            lo_i = jax.lax.broadcasted_iota(jnp.int32, (n // (2 * j), 1, j), 0)
            lo_r = jax.lax.broadcasted_iota(jnp.int32, (n // (2 * j), 1, j), 2)
            lo_index = lo_i * (2 * j) + lo_r
            ascend = (lo_index & k) == 0

            k_lo, k_hi = kp[:, :1], kp[:, 1:]
            i_lo, i_hi = ip[:, :1], ip[:, 1:]
            le = (k_lo < k_hi) | ((k_lo == k_hi) & (i_lo < i_hi))
            swap = jnp.where(ascend, ~le, le)

            def mix(lo, hi):
                nlo = jnp.where(swap, hi, lo)
                nhi = jnp.where(swap, lo, hi)
                return jnp.concatenate([nlo, nhi], axis=1).reshape(n)

            key, idx = mix(k_lo, k_hi), mix(i_lo, i_hi)
            j //= 2
        k *= 2

    pos = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)[0]
    rank = pos
    total = jnp.int32(0)
    counts = []
    for g in range(n_kinds + 1):
        in_g = key == g
        cnt = jnp.sum(in_g.astype(jnp.int32))
        rank = rank - jnp.where(in_g, total, 0)
        if g < n_kinds:
            counts.append(cnt)
        total = total + cnt

    order_ref[0] = idx
    rank_ref[0] = rank
    counts_ref[0] = jnp.stack(counts)


def _ring_slots_kernel(ring_ref, want_ref, head_ref, out_ref, *,
                       n: int, cap: int, chunk: int):
    """Free-ring slot assignment: prefix-sum the insert mask, gather the ring.

    The insert path of the free-ring event pool (``events.insert``): the r-th
    masked batch row takes the slot at ring position ``(head + r) % cap``.
    The insert rank is a log-step shift-add prefix sum over the batch lane;
    the ring gather is expressed as chunked one-hot selection (iota-compare +
    masked sum) so no dynamic VMEM gather is needed on the VPU — the same
    trick the segment-rank kernel uses for its rank counts.
    """
    want = want_ref[0]                     # (n,) int32 0/1
    head = head_ref[0][0]
    x = want
    s = 1
    while s < n:
        x = x + jnp.concatenate([jnp.zeros((s,), jnp.int32), x[:-s]])
        s *= 2
    rank = x - want                        # exclusive prefix = insert rank
    pos = (head + rank) % jnp.int32(cap)

    acc = jnp.zeros((n,), jnp.int32)
    ids0 = jax.lax.broadcasted_iota(jnp.int32, (n, chunk), 1)
    for c in range(0, cap, chunk):
        ids = ids0 + jnp.int32(c)
        seg = ring_ref[0, c:c + chunk]     # (chunk,) static slice
        eq = pos[:, None] == ids
        acc = acc + jnp.sum(jnp.where(eq, seg[None, :], 0), axis=1)
    out_ref[0] = acc


def ring_slots(free_ring: jax.Array, head: jax.Array, want: jax.Array, *,
               interpret=False):
    """(cap,) free ring + head cursor + (n,) insert mask -> (n,) slot ids.

    The free-ring variant of the event-pool insert: destination pool slots
    for a window's emit batch, matching ``kernels.ref.ring_slots_ref`` (and
    hence the XLA path inside ``events.insert``) exactly on masked rows —
    unmasked rows carry the garbage the engine drops. One VMEM pass of
    O(n log n + cap * n / lanes) vector work; no pool-wide rank scan.
    """
    cap = free_ring.shape[0]
    nb = want.shape[0]
    n = 1 << max((nb - 1).bit_length(), 1)
    chunk = min(cap, 512)
    capp = ((cap + chunk - 1) // chunk) * chunk
    ringp = jnp.zeros((capp,), jnp.int32).at[:cap].set(free_ring)[None]
    wantp = jnp.zeros((n,), jnp.int32).at[:nb].set(
        want.astype(jnp.int32))[None]
    headp = jnp.asarray(head, jnp.int32).reshape(1, 1)
    kernel = functools.partial(_ring_slots_kernel, n=n, cap=cap, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((1, capp), lambda i: (0, 0)),
                  pl.BlockSpec((1, n), lambda i: (0, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.int32),
        interpret=interpret,
    )(ringp, wantp, headp)
    return out[0, :nb]


def _trace_rank_kernel(want_ref, out_ref, *, n: int):
    """Exclusive prefix rank of the processed mask: the r-th masked window
    lane writes absolute trace position ``trace_n + r``. Same log-step
    shift-add prefix sum as the ring-slot kernel, without the ring gather —
    the write itself is a plain XLA scatter on the (cap, 4) trace buffer."""
    want = want_ref[0]                     # (n,) int32 0/1
    x = want
    s = 1
    while s < n:
        x = x + jnp.concatenate([jnp.zeros((s,), jnp.int32), x[:-s]])
        s *= 2
    out_ref[0] = x - want                  # exclusive prefix


def trace_rank(mask: jax.Array, *, interpret=False):
    """(n,) processed mask -> (n,) exclusive prefix ranks (int32).

    The trace-ring append's position math (``events.trace_append`` rank_fn
    hook): masked row r's trace slot is ``(trace_n + rank[r]) % trace_cap``.
    Matches ``kernels.ref.trace_rank_ref`` on every row (unmasked rows carry
    the running count like the XLA cumsum — the append masks them out).
    """
    nb = mask.shape[0]
    n = 1 << max((nb - 1).bit_length(), 1)
    wpad = jnp.zeros((n,), jnp.int32).at[:nb].set(
        mask.astype(jnp.int32))[None]
    kernel = functools.partial(_trace_rank_kernel, n=n)
    out = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.int32),
        interpret=interpret,
    )(wpad)
    return out[0, :nb]


def _route_rank_kernel(dst_ref, rank_ref, *, n: int, chunk: int):
    """Within-bucket routing ranks: chunked predecessor-count, all in VMEM.

    rank[i] counts earlier rows with the same destination bucket — exactly
    the stable bucket rank of the emit-routing pack. The count is a chunked
    (n, chunk) equality compare + masked sum over the row axis (the same
    one-hot trick as the ring-slot gather), so no sort and no dynamic
    gather is needed on the VPU.
    """
    dst = dst_ref[0]                       # (n,)
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)[0]
    acc = jnp.zeros((n,), jnp.int32)
    jd0 = jax.lax.broadcasted_iota(jnp.int32, (n, chunk), 1)
    for c in range(0, n, chunk):
        jdx = jd0 + jnp.int32(c)
        seg = dst_ref[0, c:c + chunk]      # (chunk,) static slice
        eq = (dst[:, None] == seg[None, :]) & (jdx < pos[:, None])
        acc = acc + jnp.sum(eq.astype(jnp.int32), axis=1)
    rank_ref[0] = acc


def route_rank(dst_agent: jax.Array, *, interpret=False):
    """(n,) destination buckets -> (n,) stable within-bucket ranks.

    The emit-routing pack of the engine's all_to_all exchange (step 5 and the
    migration re-home): row i's slot in the (n_agents, route_cap) scatter
    buffer is ``dst_agent[i] * route_cap + rank[i]``. Matches
    ``kernels.ref.route_rank_ref`` exactly on every row (invalid rows carry a
    sentinel bucket and rank like any other bucket — the engine masks them).
    """
    nb = dst_agent.shape[0]
    n = 1 << max((nb - 1).bit_length(), 1)
    chunk = min(n, 512)
    # pad rows with per-row distinct sentinels so they never contaminate a
    # real bucket's count (ranks beyond nb are discarded anyway)
    pad_ids = -jnp.arange(1, n - nb + 1, dtype=jnp.int32)
    dpad = jnp.concatenate(
        [dst_agent.astype(jnp.int32), pad_ids])[None] if n > nb else (
        dst_agent.astype(jnp.int32)[None])
    kernel = functools.partial(_route_rank_kernel, n=n, chunk=chunk)
    rank = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.int32),
        interpret=interpret,
    )(dpad)
    return rank[0, :nb]


def group_by_kind(kind: jax.Array, active: jax.Array, n_kinds: int, *,
                  interpret=False):
    """Same-kind grouping for the engine's batched dispatch (step 4).

    Returns ``(order, rank, counts)`` matching ref.group_by_kind_ref: active
    rows first, grouped by ascending kind and stable in original position;
    ``rank`` gives each grouped row's index within its kind segment; ``counts``
    is the (n_kinds,) active population per kind.
    """
    cap = kind.shape[0]
    n = 1 << max((cap - 1).bit_length(), 1)
    kpad = jnp.zeros((n,), jnp.int32).at[:cap].set(kind)[None]
    apad = jnp.zeros((n,), jnp.int32).at[:cap].set(
        active.astype(jnp.int32))[None]
    kernel = functools.partial(_group_kernel, n=n, n_kinds=n_kinds)
    order, rank, counts = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (0, 0)),
                  pl.BlockSpec((1, n), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((1, n), lambda i: (0, 0)),
                   pl.BlockSpec((1, n), lambda i: (0, 0)),
                   pl.BlockSpec((1, n_kinds), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, n), jnp.int32),
                   jax.ShapeDtypeStruct((1, n), jnp.int32),
                   jax.ShapeDtypeStruct((1, n_kinds), jnp.int32)],
        interpret=interpret,
    )(kpad, apad)
    return order[0, :cap], rank[0, :cap], counts[0]
