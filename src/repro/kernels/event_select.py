"""Event-selection Pallas kernel: the DES engine's per-window (time, seq) sort.

The conservative window's hot loop starts by ordering the event pool by
(timestamp, tie-break seq) with unsafe slots pushed to the back (their key is
T_INF). This kernel runs a bitonic sorting network entirely in VMEM over the
(time, seq, index) triple — log^2(N) vectorized compare-exchange stages, no HBM
traffic beyond one read and one write of the pool keys. The XOR-partner exchange
of the classic network is expressed as a (N/2j, 2, j) reshape + pair swap, which
vectorizes on the VPU.

``sort_events`` outputs the full permutation (i32 indices), matching
engine.lexsort_time_seq exactly (stable for equal (time, seq) pairs because the
index participates as the final tie-break, and input indices are distinct).
``select_events`` is the compacted variant for the engine's windowed execution:
sort + safe-prefix in one pass — only the first ``exec_cap`` indices leave VMEM,
so the engine can gather exactly the slots it will execute.

Invariants the engine's batched dispatch relies on (docs/architecture.md):

* **Stable (time, seq) prefix** — the ``select_events`` output is byte-identical
  to ``lexsort_time_seq(...)[:exec_cap]``; the engine's trace is written in this
  window order, so any kernel deviation breaks oracle trace equality, not just
  performance.
* **Segment-rank ordering** — ``group_by_kind`` returns active rows first,
  grouped by ascending kind, *stable in original window position within each
  kind*; ``rank`` is each row's index inside its kind segment and ``counts`` the
  per-kind populations. The dispatcher scatters handler emits back through this
  permutation, so stability is what keeps the flattened emit matrix equal to the
  sequential fold's append order. Both kernels must stay interchangeable with
  their XLA references (engine.group_by_kind_xla / select_events_xla) — the
  tests sweep kernel vs reference over random inputs.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

I32_MAX = jnp.int32(2**31 - 1)


def _lex_less(t1, s1, i1, t2, s2, i2):
    return ((t1 < t2)
            | ((t1 == t2) & (s1 < s2))
            | ((t1 == t2) & (s1 == s2) & (i1 < i2)))


def _sort_kernel(time_ref, seq_ref, perm_ref, *, n: int):
    t = time_ref[0]                        # (n,)
    s = seq_ref[0]
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)[0]

    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            def pairs(x):
                return x.reshape(n // (2 * j), 2, j)

            tp, sp, ip = pairs(t), pairs(s), pairs(idx)
            lo_i = jax.lax.broadcasted_iota(jnp.int32, (n // (2 * j), 1, j), 0)
            lo_r = jax.lax.broadcasted_iota(jnp.int32, (n // (2 * j), 1, j), 2)
            lo_index = lo_i * (2 * j) + lo_r                  # global index of lo
            ascend = (lo_index & k) == 0                      # (g, 1, j)

            t_lo, t_hi = tp[:, :1], tp[:, 1:]
            s_lo, s_hi = sp[:, :1], sp[:, 1:]
            i_lo, i_hi = ip[:, :1], ip[:, 1:]
            le = _lex_less(t_lo, s_lo, i_lo, t_hi, s_hi, i_hi)
            swap = jnp.where(ascend, ~le, le)

            def mix(lo, hi):
                nlo = jnp.where(swap, hi, lo)
                nhi = jnp.where(swap, lo, hi)
                return jnp.concatenate([nlo, nhi], axis=1).reshape(n)

            t, s, idx = mix(t_lo, t_hi), mix(s_lo, s_hi), mix(i_lo, i_hi)
            j //= 2
        k *= 2

    # the out block may be a prefix of the sorted permutation (select_events)
    perm_ref[0] = idx[: perm_ref.shape[1]]


def _run_sort(time_key: jax.Array, seq: jax.Array, m: int, *, interpret):
    """Shared pallas_call: sort padded keys, emit the first ``m`` indices."""
    cap = time_key.shape[0]
    n = 1 << max((cap - 1).bit_length(), 1)
    mpad = 1 << max((m - 1).bit_length(), 1)
    tpad = jnp.full((n,), I32_MAX, jnp.int32).at[:cap].set(time_key)[None]
    spad = jnp.full((n,), I32_MAX, jnp.int32).at[:cap].set(seq)[None]
    kernel = functools.partial(_sort_kernel, n=n)
    perm = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (0, 0)),
                  pl.BlockSpec((1, n), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, mpad), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, mpad), jnp.int32),
        interpret=interpret,
    )(tpad, spad)
    return perm[0, :m]


def sort_events(time_key: jax.Array, seq: jax.Array, *, interpret=False):
    """(CAP,) i32 keys -> (CAP,) i32 permutation, ascending (time, seq)."""
    return _run_sort(time_key, seq, time_key.shape[0], interpret=interpret)


def select_events(time_key: jax.Array, seq: jax.Array, exec_cap: int, *,
                  interpret=False):
    """Compacted gather indices: first ``exec_cap`` of the (time, seq) sort.

    With unsafe slots keyed T_INF, the returned indices are the ``exec_cap``
    earliest safe pool slots (then, if fewer are safe, unsafe filler the engine
    masks out). One kernel pass; only the prefix is written back.
    """
    return _run_sort(time_key, seq, min(exec_cap, time_key.shape[0]),
                     interpret=interpret)


def _group_kernel(kind_ref, act_ref, order_ref, rank_ref, counts_ref, *,
                  n: int, n_kinds: int):
    """Segment-rank grouping: bitonic sort by (kind, index) + in-VMEM ranks.

    Active rows get key = kind, inactive rows key = n_kinds (grouping them
    after every real kind), zero-padding beyond the caller's cap sorts last
    (its index exceeds every real row's). After the sort the grouped index
    vector IS the permutation; segment ranks fall out of a static loop over
    the n_kinds+1 possible keys (position minus the segment's exclusive
    prefix count), so no dynamic gather is needed on the VPU.
    """
    kd = kind_ref[0]                       # (n,)
    act = act_ref[0] != 0
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)[0]
    key = jnp.where(act, jnp.clip(kd, 0, n_kinds - 1), jnp.int32(n_kinds))

    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            def pairs(x):
                return x.reshape(n // (2 * j), 2, j)

            kp, ip = pairs(key), pairs(idx)
            lo_i = jax.lax.broadcasted_iota(jnp.int32, (n // (2 * j), 1, j), 0)
            lo_r = jax.lax.broadcasted_iota(jnp.int32, (n // (2 * j), 1, j), 2)
            lo_index = lo_i * (2 * j) + lo_r
            ascend = (lo_index & k) == 0

            k_lo, k_hi = kp[:, :1], kp[:, 1:]
            i_lo, i_hi = ip[:, :1], ip[:, 1:]
            le = (k_lo < k_hi) | ((k_lo == k_hi) & (i_lo < i_hi))
            swap = jnp.where(ascend, ~le, le)

            def mix(lo, hi):
                nlo = jnp.where(swap, hi, lo)
                nhi = jnp.where(swap, lo, hi)
                return jnp.concatenate([nlo, nhi], axis=1).reshape(n)

            key, idx = mix(k_lo, k_hi), mix(i_lo, i_hi)
            j //= 2
        k *= 2

    pos = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)[0]
    rank = pos
    total = jnp.int32(0)
    counts = []
    for g in range(n_kinds + 1):
        in_g = key == g
        cnt = jnp.sum(in_g.astype(jnp.int32))
        rank = rank - jnp.where(in_g, total, 0)
        if g < n_kinds:
            counts.append(cnt)
        total = total + cnt

    order_ref[0] = idx
    rank_ref[0] = rank
    counts_ref[0] = jnp.stack(counts)


def _ring_slots_kernel(ring_ref, want_ref, head_ref, out_ref, *,
                       n: int, cap: int, chunk: int):
    """Free-ring slot assignment: prefix-sum the insert mask, gather the ring.

    The insert path of the free-ring event pool (``events.insert``): the r-th
    masked batch row takes the slot at ring position ``(head + r) % cap``.
    The insert rank is a log-step shift-add prefix sum over the batch lane;
    the ring gather is expressed as chunked one-hot selection (iota-compare +
    masked sum) so no dynamic VMEM gather is needed on the VPU — the same
    trick the segment-rank kernel uses for its rank counts.
    """
    want = want_ref[0]                     # (n,) int32 0/1
    head = head_ref[0][0]
    x = want
    s = 1
    while s < n:
        x = x + jnp.concatenate([jnp.zeros((s,), jnp.int32), x[:-s]])
        s *= 2
    rank = x - want                        # exclusive prefix = insert rank
    pos = (head + rank) % jnp.int32(cap)

    acc = jnp.zeros((n,), jnp.int32)
    ids0 = jax.lax.broadcasted_iota(jnp.int32, (n, chunk), 1)
    for c in range(0, cap, chunk):
        ids = ids0 + jnp.int32(c)
        seg = ring_ref[0, c:c + chunk]     # (chunk,) static slice
        eq = pos[:, None] == ids
        acc = acc + jnp.sum(jnp.where(eq, seg[None, :], 0), axis=1)
    out_ref[0] = acc


def ring_slots(free_ring: jax.Array, head: jax.Array, want: jax.Array, *,
               interpret=False):
    """(cap,) free ring + head cursor + (n,) insert mask -> (n,) slot ids.

    The free-ring variant of the event-pool insert: destination pool slots
    for a window's emit batch, matching ``kernels.ref.ring_slots_ref`` (and
    hence the XLA path inside ``events.insert``) exactly on masked rows —
    unmasked rows carry the garbage the engine drops. One VMEM pass of
    O(n log n + cap * n / lanes) vector work; no pool-wide rank scan.
    """
    cap = free_ring.shape[0]
    nb = want.shape[0]
    n = 1 << max((nb - 1).bit_length(), 1)
    chunk = min(cap, 512)
    capp = ((cap + chunk - 1) // chunk) * chunk
    ringp = jnp.zeros((capp,), jnp.int32).at[:cap].set(free_ring)[None]
    wantp = jnp.zeros((n,), jnp.int32).at[:nb].set(
        want.astype(jnp.int32))[None]
    headp = jnp.asarray(head, jnp.int32).reshape(1, 1)
    kernel = functools.partial(_ring_slots_kernel, n=n, cap=cap, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((1, capp), lambda i: (0, 0)),
                  pl.BlockSpec((1, n), lambda i: (0, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.int32),
        interpret=interpret,
    )(ringp, wantp, headp)
    return out[0, :nb]


def _trace_rank_kernel(want_ref, out_ref, *, n: int):
    """Exclusive prefix rank of the processed mask: the r-th masked window
    lane writes absolute trace position ``trace_n + r``. Same log-step
    shift-add prefix sum as the ring-slot kernel, without the ring gather —
    the write itself is a plain XLA scatter on the (cap, 4) trace buffer."""
    want = want_ref[0]                     # (n,) int32 0/1
    x = want
    s = 1
    while s < n:
        x = x + jnp.concatenate([jnp.zeros((s,), jnp.int32), x[:-s]])
        s *= 2
    out_ref[0] = x - want                  # exclusive prefix


def trace_rank(mask: jax.Array, *, interpret=False):
    """(n,) processed mask -> (n,) exclusive prefix ranks (int32).

    The trace-ring append's position math (``events.trace_append`` rank_fn
    hook): masked row r's trace slot is ``(trace_n + rank[r]) % trace_cap``.
    Matches ``kernels.ref.trace_rank_ref`` on every row (unmasked rows carry
    the running count like the XLA cumsum — the append masks them out).
    """
    nb = mask.shape[0]
    n = 1 << max((nb - 1).bit_length(), 1)
    wpad = jnp.zeros((n,), jnp.int32).at[:nb].set(
        mask.astype(jnp.int32))[None]
    kernel = functools.partial(_trace_rank_kernel, n=n)
    out = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.int32),
        interpret=interpret,
    )(wpad)
    return out[0, :nb]


def _route_rank_kernel(dst_ref, rank_ref, *, n: int, chunk: int):
    """Within-bucket routing ranks: chunked predecessor-count, all in VMEM.

    rank[i] counts earlier rows with the same destination bucket — exactly
    the stable bucket rank of the emit-routing pack. The count is a chunked
    (n, chunk) equality compare + masked sum over the row axis (the same
    one-hot trick as the ring-slot gather), so no sort and no dynamic
    gather is needed on the VPU.
    """
    dst = dst_ref[0]                       # (n,)
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)[0]
    acc = jnp.zeros((n,), jnp.int32)
    jd0 = jax.lax.broadcasted_iota(jnp.int32, (n, chunk), 1)
    for c in range(0, n, chunk):
        jdx = jd0 + jnp.int32(c)
        seg = dst_ref[0, c:c + chunk]      # (chunk,) static slice
        eq = (dst[:, None] == seg[None, :]) & (jdx < pos[:, None])
        acc = acc + jnp.sum(eq.astype(jnp.int32), axis=1)
    rank_ref[0] = acc


def route_rank(dst_agent: jax.Array, *, interpret=False):
    """(n,) destination buckets -> (n,) stable within-bucket ranks.

    The emit-routing pack of the engine's all_to_all exchange (step 5 and the
    migration re-home): row i's slot in the (n_agents, route_cap) scatter
    buffer is ``dst_agent[i] * route_cap + rank[i]``. Matches
    ``kernels.ref.route_rank_ref`` exactly on every row (invalid rows carry a
    sentinel bucket and rank like any other bucket — the engine masks them).
    """
    nb = dst_agent.shape[0]
    n = 1 << max((nb - 1).bit_length(), 1)
    chunk = min(n, 512)
    # pad rows with per-row distinct sentinels so they never contaminate a
    # real bucket's count (ranks beyond nb are discarded anyway)
    pad_ids = -jnp.arange(1, n - nb + 1, dtype=jnp.int32)
    dpad = jnp.concatenate(
        [dst_agent.astype(jnp.int32), pad_ids])[None] if n > nb else (
        dst_agent.astype(jnp.int32)[None])
    kernel = functools.partial(_route_rank_kernel, n=n, chunk=chunk)
    rank = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.int32),
        interpret=interpret,
    )(dpad)
    return rank[0, :nb]


def group_by_kind(kind: jax.Array, active: jax.Array, n_kinds: int, *,
                  interpret=False):
    """Same-kind grouping for the engine's batched dispatch (step 4).

    Returns ``(order, rank, counts)`` matching ref.group_by_kind_ref: active
    rows first, grouped by ascending kind and stable in original position;
    ``rank`` gives each grouped row's index within its kind segment; ``counts``
    is the (n_kinds,) active population per kind.
    """
    cap = kind.shape[0]
    n = 1 << max((cap - 1).bit_length(), 1)
    kpad = jnp.zeros((n,), jnp.int32).at[:cap].set(kind)[None]
    apad = jnp.zeros((n,), jnp.int32).at[:cap].set(
        active.astype(jnp.int32))[None]
    kernel = functools.partial(_group_kernel, n=n, n_kinds=n_kinds)
    order, rank, counts = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (0, 0)),
                  pl.BlockSpec((1, n), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((1, n), lambda i: (0, 0)),
                   pl.BlockSpec((1, n), lambda i: (0, 0)),
                   pl.BlockSpec((1, n_kinds), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, n), jnp.int32),
                   jax.ShapeDtypeStruct((1, n), jnp.int32),
                   jax.ShapeDtypeStruct((1, n_kinds), jnp.int32)],
        interpret=interpret,
    )(kpad, apad)
    return order[0, :cap], rank[0, :cap], counts[0]


class FusedSelect(NamedTuple):
    """Everything the engine's window front-end needs, from ONE kernel pass.

    All fields are window-aligned: length ``m = min(exec_cap, pool_cap)``
    (``payload`` is ``(m, PAYLOAD)``). ``exec_idx``/``exec_safe`` replace the
    select_fn + ``exec_selection_ring`` pair; the event fields replace the
    ``ev.gather`` slot gather; ``clean``/``order`` replace the conflict mask +
    group_by_kind pair inside the batched dispatch; ``rel_pos`` is the
    free-ring release position each executed slot reclaims into
    (``events.release(..., pos=rel_pos)``)."""

    exec_idx: jax.Array   # (m,) i32 pool slots in (time, seq) window order
    exec_safe: jax.Array  # (m,) bool — selected slot is safe this window
    time: jax.Array       # (m,) i32 gathered event fields ...
    seq: jax.Array
    kind: jax.Array
    src: jax.Array
    dst: jax.Array
    ctx: jax.Array
    payload: jax.Array    # (m, PAYLOAD) f32
    valid: jax.Array      # (m,) bool
    clean: jax.Array      # (m,) bool — safe and conflict-free
    order: jax.Array      # (m,) i32 same-kind grouping permutation
    rel_pos: jax.Array    # (m,) i32 free-ring release position (safe rows)


def _fused_select_kernel(tkey_ref, seq_ref, safe_ref, time_ref, kind_ref,
                         src_ref, dst_ref, ctx_ref, valid_ref, tbl_ref,
                         res_ref, pay_ref, tail_ref,
                         idx_out, safe_out, time_out, seq_out, kind_out,
                         src_out, dst_out, ctx_out, valid_out, pay_out,
                         clean_out, order_out, rel_out, *,
                         n: int, m: int, mpad: int, cap: int, n_kinds: int,
                         n_res: int, n_pay: int, chunk: int):
    """The superstep megakernel: select + gather + conflict + group + release.

    One VMEM-resident pass fuses the four front-end stages XLA otherwise
    stitches through HBM:

    1. **Sort-select**: the (time_key, seq, index) bitonic network of
       ``_sort_kernel`` — but every event field (time, kind, src, dst, ctx,
       valid, the conflict key columns, and all PAYLOAD payload lanes) rides
       through the compare-exchange as sort payload, so the window's slot
       *gather* falls out of the sort for free: after the network, lane i of
       every carried array IS pool slot ``exec_idx[i]``'s field. No dynamic
       VMEM gather, no HBM round-trip for the index array.
    2. **Conflict mask**: duplicate detection on the declared component rows
       (``rkey = table_id * n_res + res``) via a chunked pairwise count —
       ``cnt[j] = sum_i comp[i] & (rkey[i] == rkey[j])`` — matching
       ``sync.conflict_mask`` semantics exactly (rows with table_id == 0
       never conflict).
    3. **Group-by-kind**: the segment bitonic of ``_group_kernel`` over the
       window lanes, keyed (clean ? kind : n_kinds, position).
    4. **Release ranks**: the log-step shift-add exclusive prefix sum of the
       safe mask; with the ``free_tail`` ring cursor resident in SMEM (a
       scalar block on TPU), each executed slot's reclaim position
       ``(free_tail + rank) % cap`` leaves the kernel ready for the O(1)
       ``events.release`` scatter.
    """
    t = tkey_ref[0]
    s = seq_ref[0]
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)[0]
    # every event field rides the sorting network as payload (step 1)
    carry = [safe_ref[0], time_ref[0], kind_ref[0], src_ref[0], dst_ref[0],
             ctx_ref[0], valid_ref[0], tbl_ref[0], res_ref[0]]
    carry += [pay_ref[p] for p in range(n_pay)]

    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            def pairs(x):
                return x.reshape(n // (2 * j), 2, j)

            tp, sp, ip = pairs(t), pairs(s), pairs(idx)
            lo_i = jax.lax.broadcasted_iota(jnp.int32, (n // (2 * j), 1, j), 0)
            lo_r = jax.lax.broadcasted_iota(jnp.int32, (n // (2 * j), 1, j), 2)
            lo_index = lo_i * (2 * j) + lo_r
            ascend = (lo_index & k) == 0

            le = _lex_less(tp[:, :1], sp[:, :1], ip[:, :1],
                           tp[:, 1:], sp[:, 1:], ip[:, 1:])
            swap = jnp.where(ascend, ~le, le)

            def mix(x):
                xp = pairs(x)
                lo, hi = xp[:, :1], xp[:, 1:]
                return jnp.concatenate([jnp.where(swap, hi, lo),
                                        jnp.where(swap, lo, hi)],
                                       axis=1).reshape(n)

            t, s, idx = mix(t), mix(s), mix(idx)
            carry = [mix(x) for x in carry]
            j //= 2
        k *= 2

    # window prefix: only the first m lanes are the window (mpad is the
    # pow2-padded out width; lanes in [m, mpad) are masked everywhere below)
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, mpad), 1)[0]
    sel = pos < m
    safe_w = carry[0][:mpad]
    time_w = carry[1][:mpad]
    kind_w = carry[2][:mpad]
    es = (safe_w != 0) & sel

    # step 2: conflict mask on the declared (component table, resource row)
    tb = carry[7][:mpad]
    rs = carry[8][:mpad]
    rkey = tb * jnp.int32(n_res) + rs
    comp = es & (tb > 0)
    cnt = jnp.zeros((mpad,), jnp.int32)
    for c in range(0, mpad, chunk):
        eq = (rkey[:, None] == rkey[c:c + chunk][None, :]) \
            & comp[c:c + chunk][None, :]
        cnt = cnt + jnp.sum(eq.astype(jnp.int32), axis=1)
    dirty = comp & (cnt >= 2)
    clean = es & ~dirty

    # step 3: same-kind grouping of the clean lanes (stable in window order)
    gkey = jnp.where(clean, jnp.clip(kind_w, 0, n_kinds - 1),
                     jnp.int32(n_kinds))
    gidx = pos
    kk = 2
    while kk <= mpad:
        jj = kk // 2
        while jj >= 1:
            def gpairs(x):
                return x.reshape(mpad // (2 * jj), 2, jj)

            kp, ip = gpairs(gkey), gpairs(gidx)
            glo_i = jax.lax.broadcasted_iota(
                jnp.int32, (mpad // (2 * jj), 1, jj), 0)
            glo_r = jax.lax.broadcasted_iota(
                jnp.int32, (mpad // (2 * jj), 1, jj), 2)
            gascend = ((glo_i * (2 * jj) + glo_r) & kk) == 0

            k_lo, k_hi = kp[:, :1], kp[:, 1:]
            i_lo, i_hi = ip[:, :1], ip[:, 1:]
            gle = (k_lo < k_hi) | ((k_lo == k_hi) & (i_lo < i_hi))
            gswap = jnp.where(gascend, ~gle, gle)

            def gmix(lo, hi):
                return jnp.concatenate([jnp.where(gswap, hi, lo),
                                        jnp.where(gswap, lo, hi)],
                                       axis=1).reshape(mpad)

            gkey, gidx = gmix(k_lo, k_hi), gmix(i_lo, i_hi)
            jj //= 2
        kk *= 2

    # step 4: release ranks off the SMEM-resident free_tail cursor
    w = es.astype(jnp.int32)
    x = w
    sh = 1
    while sh < mpad:
        x = x + jnp.concatenate([jnp.zeros((sh,), jnp.int32), x[:-sh]])
        sh *= 2
    rel = (tail_ref[0, 0] + (x - w)) % jnp.int32(cap)

    idx_out[0] = idx[:mpad]
    safe_out[0] = es.astype(jnp.int32)
    time_out[0] = time_w
    seq_out[0] = s[:mpad]
    kind_out[0] = kind_w
    src_out[0] = carry[3][:mpad]
    dst_out[0] = carry[4][:mpad]
    ctx_out[0] = carry[5][:mpad]
    valid_out[0] = carry[6][:mpad]
    for p in range(n_pay):
        pay_out[p] = carry[9 + p][:mpad]
    clean_out[0] = clean.astype(jnp.int32)
    order_out[0] = gidx
    rel_out[0] = rel


def fused_select(time_key: jax.Array, seq: jax.Array, safe: jax.Array,
                 time: jax.Array, kind: jax.Array, src: jax.Array,
                 dst: jax.Array, ctx: jax.Array, payload: jax.Array,
                 valid: jax.Array, table_id: jax.Array, res: jax.Array,
                 free_tail: jax.Array, exec_cap: int, *, n_kinds: int,
                 n_res: int, n_tables: int | None = None,
                 interpret=False) -> FusedSelect:
    """The fused window front-end over a (pool_cap,) event pool.

    Byte-compatible with the stitched composition
    (``engine.fused_select_xla`` / ``ref.fused_select_ref``): select the
    ``exec_cap`` earliest safe slots, gather their fields, mask write
    conflicts, group by kind, and rank the free-ring release — one
    ``pallas_call``, intermediates never leaving VMEM. ``table_id``/``res``
    are the pool-wide conflict key columns (the engine precomputes the two
    registry gathers, the kernel has no table access); ``free_tail`` is the
    pool's ring cursor, kept in SMEM on TPU. Lanes where ``exec_safe`` is
    False carry the sorted slot's raw fields, exactly like the XLA gather —
    the engine masks them everywhere.
    """
    del n_tables  # bounds the stitched twins' key space; the pairwise count
    #               needs no sentinel span
    cap = time_key.shape[0]
    m = max(min(exec_cap, cap), 1)
    n = 1 << max((cap - 1).bit_length(), 1)
    mpad = 1 << max((m - 1).bit_length(), 1)
    n_pay = payload.shape[1]
    chunk = min(mpad, 256)

    def pad(xv, fill):
        return jnp.full((n,), fill, jnp.int32).at[:cap].set(
            xv.astype(jnp.int32))[None]

    args = [pad(time_key, I32_MAX), pad(seq, I32_MAX), pad(safe, 0),
            pad(time, 0), pad(kind, 0), pad(src, 0), pad(dst, 0),
            pad(ctx, 0), pad(valid, 0), pad(table_id, 0), pad(res, 0)]
    payp = jnp.zeros((n_pay, n), payload.dtype).at[:, :cap].set(payload.T)
    tailp = jnp.asarray(free_tail, jnp.int32).reshape(1, 1)

    def vec(w):
        return pl.BlockSpec((1, w), lambda i: (0, 0))

    if interpret:
        tail_spec = vec(1)
    else:
        # compiled lane: the ring cursor is a scalar block in SMEM (lazy
        # import — pltpu only resolves on a TPU-capable install)
        from jax.experimental.pallas import tpu as pltpu
        tail_spec = pl.BlockSpec(memory_space=pltpu.SMEM)

    kernel = functools.partial(_fused_select_kernel, n=n, m=m, mpad=mpad,
                               cap=cap, n_kinds=n_kinds, n_res=n_res,
                               n_pay=n_pay, chunk=chunk)
    outs = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[vec(n)] * 11
        + [pl.BlockSpec((n_pay, n), lambda i: (0, 0)), tail_spec],
        out_specs=[vec(mpad)] * 9
        + [pl.BlockSpec((n_pay, mpad), lambda i: (0, 0))] + [vec(mpad)] * 3,
        out_shape=[jax.ShapeDtypeStruct((1, mpad), jnp.int32)] * 9
        + [jax.ShapeDtypeStruct((n_pay, mpad), payload.dtype)]
        + [jax.ShapeDtypeStruct((1, mpad), jnp.int32)] * 3,
        interpret=interpret,
    )(*args, payp, tailp)
    (idxo, safeo, timeo, seqo, kindo, srco, dsto, ctxo, valido, payo,
     cleano, ordero, relo) = outs
    return FusedSelect(
        exec_idx=idxo[0, :m],
        exec_safe=safeo[0, :m] != 0,
        time=timeo[0, :m],
        seq=seqo[0, :m],
        kind=kindo[0, :m],
        src=srco[0, :m],
        dst=dsto[0, :m],
        ctx=ctxo[0, :m],
        payload=payo[:, :m].T,
        valid=valido[0, :m] != 0,
        clean=cleano[0, :m] != 0,
        order=ordero[0, :m],
        rel_pos=relo[0, :m],
    )
