"""SSD / Mamba2-style selective-scan Pallas kernel (hymba's SSM heads).

Same chunked machinery as rwkv6_scan (decay on the V channels, inclusive-diagonal
intra-chunk term, no bonus). See that module for the tiling story.
"""
from __future__ import annotations

from repro.kernels.rwkv6_scan import gla_pallas


def ssd_pallas(q, k, v, w, *, chunk=64, interpret=False):
    """q,k: (BH, S, dk=state); v: (BH, S, dv=head); w: (BH, S, dv) decay."""
    return gla_pallas(q, k, v, w, mode="v", chunk=chunk, interpret=interpret)
