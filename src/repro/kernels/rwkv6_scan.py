"""Chunked gated-linear-attention Pallas kernel (RWKV6 time-mix hot loop).

Grid = (batch*heads, n_chunks); chunks are the minor grid axis so TPU runs them
sequentially per head while the recurrent state S (dk x dv, f32) persists in VMEM
scratch — the cross-chunk carry never round-trips to HBM. Within a chunk everything
is (C x C) / (C x d) matmuls on the MXU, which is the entire point of the chunked
formulation (see models/linear_rnn.py for the math and the jnp twin).

``mode='k'`` = RWKV6 (decay on K channels, +bonus u on the diagonal).
``mode='v'`` = Mamba2-style SSD (decay on V channels) — reused by ssm_scan.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gla_kernel_k(q_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_out_ref, s_ref, *,
                  chunk: int, n_chunks: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    q = q_ref[0].astype(jnp.float32)          # (C, dk)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)          # (C, dv)
    w = w_ref[0].astype(jnp.float32)          # (C, dk)
    u = u_ref[0].astype(jnp.float32)          # (1, dk)
    S = s_ref[...]

    logw = jnp.log(w)
    qs = jnp.exp(jnp.cumsum(logw, axis=0))    # inclusive cumprod
    qx = qs / w                                # exclusive
    r_t = q * qx
    k_t = k / qs

    a = jax.lax.dot_general(r_t, k_t, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    a = jnp.where(jj < ii, a, 0.0)
    diag = jnp.sum(q * u * k, axis=1)
    a = a + jnp.where(jj == ii, diag[:, None], 0.0)

    out = (jax.lax.dot_general(r_t, S, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
           + jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32))
    o_ref[0] = out.astype(o_ref.dtype)

    qc = qs[-1]                                # (dk,)
    s_new = (S * qc[:, None]
             + jax.lax.dot_general(k_t * qc[None, :], v,
                                   (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32))
    s_ref[...] = s_new

    @pl.when(c == n_chunks - 1)
    def _flush():
        s_out_ref[0] = s_new


def _gla_kernel_v(q_ref, k_ref, v_ref, w_ref, o_ref, s_out_ref, s_ref, *,
                  chunk: int, n_chunks: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    q = q_ref[0].astype(jnp.float32)          # (C, dk)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)          # (C, dv)
    w = w_ref[0].astype(jnp.float32)          # (C, dv)
    S = s_ref[...]

    logw = jnp.log(w)
    qs = jnp.exp(jnp.cumsum(logw, axis=0))    # inclusive (C, dv)
    b = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    b = jnp.where(jj <= ii, b, 0.0)
    v_t = v / qs
    out = qs * (jax.lax.dot_general(q, S, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
                + jax.lax.dot_general(b, v_t, (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32))
    o_ref[0] = out.astype(o_ref.dtype)

    qc = qs[-1]                                # (dv,)
    s_new = qc[None, :] * (S + jax.lax.dot_general(
        k, v_t, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32))
    s_ref[...] = s_new

    @pl.when(c == n_chunks - 1)
    def _flush():
        s_out_ref[0] = s_new


def gla_pallas(q, k, v, w, u=None, *, mode="k", chunk=64, interpret=False):
    """q,k: (BH, S, dk); v: (BH, S, dv); w per mode; u: (BH, dk) for mode='k'.

    Returns (out (BH, S, dv), final_state (BH, dk, dv) f32).
    """
    bh, s, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, s)
    assert s % c == 0
    n = s // c

    spec3 = lambda d: pl.BlockSpec((1, c, d), lambda b, i: (b, i, 0))
    if mode == "k":
        kernel = functools.partial(_gla_kernel_k, chunk=c, n_chunks=n)
        in_specs = [spec3(dk), spec3(dk), spec3(dv), spec3(dk),
                    pl.BlockSpec((1, 1, dk), lambda b, i: (b, 0, 0))]
        args = (q, k, v, w, u[:, None, :])
    else:
        kernel = functools.partial(_gla_kernel_v, chunk=c, n_chunks=n)
        in_specs = [spec3(dk), spec3(dk), spec3(dv), spec3(dv)]
        args = (q, k, v, w)

    out, s_out = pl.pallas_call(
        kernel,
        grid=(bh, n),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, c, dv), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, dk, dv), lambda b, i: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, dv), q.dtype),
            jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(*args)
    return out, s_out
