"""Deterministic synthetic-token data pipeline.

Stateless step-seeded sampling: batch(step) is a pure function of (seed, step,
shard), so (a) restart-after-failure resumes mid-epoch with zero loss/dup, and
(b) elastic re-sharding (ft/elastic.py) just changes the shard divisor — every
host recomputes its slice of the same global batch. This is the property that
makes the checkpoint/restart story exact.

The synthetic distribution is a order-2 Markov chain over the vocab with a fixed
transition structure — enough signal for loss-decrease tests (a pure-uniform
stream has no learnable structure).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


def _fold(seed: int, *xs: int) -> jax.Array:
    key = jax.random.PRNGKey(seed)
    for x in xs:
        key = jax.random.fold_in(key, x)
    return key


def global_batch_at(cfg: DataConfig, step: int):
    """Full (global_batch, seq_len+1) token block for one step (host-side)."""
    key = _fold(cfg.seed, step)
    b, s, v = cfg.global_batch, cfg.seq_len + 1, cfg.vocab
    # order-2 structure: t_{i+1} = (a * t_i + b * t_{i-1} + noise) mod v
    k1, k2, k3 = jax.random.split(key, 3)
    t0 = jax.random.randint(k1, (b, 2), 0, v)
    noise = jax.random.randint(k2, (b, s), 0, 7)

    def step_fn(carry, n):
        t1, t2 = carry
        nxt = (t1 * 31 + t2 * 17 + n) % v
        return (t2, nxt), nxt

    _, toks = jax.lax.scan(step_fn, (t0[:, 0], t0[:, 1]), noise.T)
    return toks.T.astype(jnp.int32)                      # (b, s)


def batch_for_shard(cfg: DataConfig, step: int, shard: int, n_shards: int):
    """This host's slice: {tokens, targets} of (b/n_shards, seq_len)."""
    assert cfg.global_batch % n_shards == 0
    block = global_batch_at(cfg, step)
    per = cfg.global_batch // n_shards
    mine = jax.lax.dynamic_slice_in_dim(block, shard * per, per, axis=0)
    return {"tokens": mine[:, :-1], "targets": mine[:, 1:]}


def batch_iterator(cfg: DataConfig, start_step: int = 0, shard: int = 0,
                   n_shards: int = 1):
    step = start_step
    while True:
        yield step, batch_for_shard(cfg, step, shard, n_shards)
        step += 1
