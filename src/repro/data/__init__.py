"""repro.data subpackage."""
