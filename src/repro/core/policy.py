"""Monitoring-driven adaptive exec width — the LISA -> scheduler loop (C3).

The paper's control thesis (§4.1) is that the monitoring system feeds the
scheduler, which adapts the simulation's execution to the observed load. The
engine's per-window knob is ``exec_cap``: how many of the earliest safe events
one conservative window executes. PR 1 fixed it at ``min(pool_cap, 256)``;
since PR 2 execution is vectorized (no longer serial in exec_cap), so the
right width is load-dependent:

* **too narrow** under dense windows: safe events spill (``C_EXEC_SPILL``)
  and the run pays extra windows — extra GVT collectives — for the same
  events;
* **too narrow** near pool saturation: a compacted window frees at most
  ``exec_cap`` slots of insert headroom, so a nearly-full pool needs a wide
  window to avoid counted drops (``C_DROP_POOL``);
* **too wide** on sparse windows: the vectorized dispatch pays for lanes that
  execute nothing.

:class:`ExecPolicy` picks the next window's width from a small fixed ladder
of pre-compiled widths. The ladder (not a continuous knob) is what keeps the
jit caches warm: the engine compiles one window program per rung on first
use and every later window reuses it, so adaptation costs a dictionary
lookup, not a recompile. Decisions consume the per-window monitoring vector —
the spill rate, the batched-merge scatter volume (``C_BATCH_ROWS``), and the
pool-lifecycle occupancy gauges (``C_POOL_OCC`` / ``C_POOL_FREE``) published
by the free-ring pool — and are pure host-side functions, so an adaptive run
is exactly reproducible.

Correctness is free: spilling is oracle-exact for *any* exec width sequence
(spilled events stay below the unchanged horizon — see engine.py step 4), so
the policy trades only window count and per-window cost, never accuracy.
Colaso et al. (2019) frame this knob as an accuracy-vs-cost tradeoff for
sampled simulators; here the spill semantics make the accuracy term zero.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import monitoring as mon


@dataclasses.dataclass(frozen=True)
class ExecPolicy:
    """A ladder of per-window execution widths + the movement thresholds.

    ``ladder`` is a strictly ascending tuple of widths (each a static shape
    the engine compiles one window program for). One decision moves at most
    one rung — hysteresis against oscillation on bursty workloads.

    Grow (rung + 1) when either
      * spill pressure: this window spilled more than ``grow_spill`` x the
        current width (dense windows: pay one compile, save many windows), or
      * pool saturation: occupancy exceeded ``grow_occupancy`` of pool_cap
        (a wider window frees more slots of insert headroom).
    Shrink (rung - 1) when the window was sparse: nothing spilled, occupancy
    is comfortable, and both the executed-event count and the scatter volume
    (``C_BATCH_ROWS``) fit inside ``shrink_util`` x the *next lower* width.
    """

    ladder: tuple[int, ...]
    init_rung: int = 0
    grow_spill: float = 0.10
    grow_occupancy: float = 0.75
    shrink_util: float = 0.50

    def __post_init__(self):
        if not self.ladder:
            raise ValueError("ExecPolicy needs a non-empty width ladder")
        lad = tuple(int(w) for w in self.ladder)
        if any(w <= 0 for w in lad):
            raise ValueError(f"ladder widths must be positive: {lad}")
        if any(b <= a for a, b in zip(lad, lad[1:])):
            raise ValueError(f"ladder must be strictly ascending: {lad}")
        object.__setattr__(self, "ladder", lad)
        if not 0 <= self.init_rung < len(lad):
            raise ValueError(f"init_rung {self.init_rung} outside ladder "
                             f"{lad}")


def default_ladder(pool_cap: int, base: int = 256) -> tuple[int, ...]:
    """A geometric ladder around the historical static default: base/4,
    base, base*4, ... capped at ``pool_cap`` (always included)."""
    widths = {min(max(base // 4, 1), pool_cap), min(base, pool_cap)}
    w = base * 4
    while w < pool_cap:
        widths.add(w)
        w *= 4
    widths.add(pool_cap)
    return tuple(sorted(widths))


def normalize(exec_policy) -> ExecPolicy:
    """An ExecPolicy from a spec's ``exec_policy`` field (int -> one rung)."""
    if isinstance(exec_policy, ExecPolicy):
        return exec_policy
    return ExecPolicy(ladder=(int(exec_policy),))


@dataclasses.dataclass(frozen=True)
class WindowStats:
    """The per-window monitoring slice a policy decision consumes.

    Rates are per-window deltas, reduced ``max`` over agents (the fleet
    adapts to its hottest agent — one spilling agent stalls GVT progress for
    everyone); occupancy is the worst-agent fraction of pool_cap.
    """

    processed: int    # max over agents of this window's C_EVENTS delta
    spilled: int      # max over agents of this window's C_EXEC_SPILL delta
    rows: int         # max over agents of this window's C_BATCH_ROWS delta
    occupancy: float  # max over agents of C_POOL_OCC / pool_cap


def window_stats(prev_counters, counters, pool_cap: int) -> WindowStats:
    """Extract a :class:`WindowStats` from two (A, N) counter snapshots."""
    prev = np.asarray(prev_counters)
    cur = np.asarray(counters)
    delta = cur - prev
    return WindowStats(
        processed=int(delta[:, mon.C_EVENTS].max()),
        spilled=int(delta[:, mon.C_EXEC_SPILL].max()),
        rows=int(delta[:, mon.C_BATCH_ROWS].max()),
        occupancy=float(cur[:, mon.C_POOL_OCC].max()) / max(pool_cap, 1),
    )


def shard_window_stats(prev_counters, counters, pool_cap: int,
                       n_shards: int) -> tuple[WindowStats, ...]:
    """Per-shard :class:`WindowStats` from two (A, N) counter snapshots.

    Agents are packed shard-major (``A == n_shards * n_lanes``, the engine's
    shard_map x vmap layout), so shard d owns the contiguous row block
    ``[d*K, (d+1)*K)``. Each shard's stats are the max over its own lanes —
    the per-shard analog of :func:`window_stats`."""
    prev = np.asarray(prev_counters)
    cur = np.asarray(counters)
    k = prev.shape[0] // n_shards
    return tuple(
        window_stats(prev[d * k:(d + 1) * k], cur[d * k:(d + 1) * k], pool_cap)
        for d in range(n_shards))


def choose_rung(policy: ExecPolicy, rung: int, stats: WindowStats) -> int:
    """The next window's ladder rung (pure, host-side, deterministic)."""
    width = policy.ladder[rung]
    if stats.spilled > policy.grow_spill * width:
        return min(rung + 1, len(policy.ladder) - 1)
    if stats.occupancy > policy.grow_occupancy:
        return min(rung + 1, len(policy.ladder) - 1)
    if rung > 0:
        lo = policy.ladder[rung - 1]
        sparse = (stats.spilled == 0
                  and stats.occupancy <= policy.grow_occupancy
                  and stats.processed < policy.shrink_util * lo
                  and stats.rows < policy.shrink_util * lo)
        if sparse:
            return rung - 1
    return rung


def choose_rung_lockstep(policy: ExecPolicy, rung: int,
                         shard_stats: tuple[WindowStats, ...]) -> int:
    """The distributed next rung: max over per-shard decisions.

    Every shard must run the same jit-cached window program (the collectives
    inside a window are fleet-wide), so per-shard width choices reduce via
    max — the hottest shard sets the fleet's width, exactly as
    :func:`window_stats`'s max-over-agents does for the vmap driver. The two
    formulations are equivalent: every :func:`choose_rung` condition is
    monotone in (spilled, occupancy, processed, rows), so
    ``max_d choose_rung(stats_d) == choose_rung(max_d stats_d)`` — the
    distributed rung trajectory is byte-identical to ``run_adaptive``'s.
    """
    return max(choose_rung(policy, rung, s) for s in shard_stats)
