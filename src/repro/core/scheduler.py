"""The scheduling algorithm (paper §4.1), vectorized for TPU.

Paper: each agent publishes a performance value (workstation load + network load +
agent load). For a new simulation job: build a complete weighted graph over agents
with edge weight = arithmetic mean of the endpoint performance values; compute all
shortest paths; for each candidate node take the mean shortest-path value to the
nodes already participating in the run; the minimum wins. Successive placements of
one run therefore cluster into a minimum-weight neighborhood — "limiting ... the
number of messages that are exchanged between the logical processes".

TPU adaptation: all-pairs shortest paths by min-plus matrix squaring — ceil(log2 A)
dense (A,A,A) min-plus products instead of Dijkstra per node; the dense form is
MXU/VPU-friendly and jit-compiles to a handful of fused ops.

Because component state is replicated (C4), migrating an LP costs only (1) rewriting
``lp_agent`` and (2) re-homing its pending events — the paper's argument for
replication ("we are not imposing a limitation to where a logical process will be
executed") holds verbatim here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import monitoring as mon

_BIG = jnp.float32(1e18)


def performance_graph(perf: jax.Array, link_cost: jax.Array | None = None):
    """(A,) performance values -> (A, A) complete weighted graph (diag 0).

    Edge weight = (p_i + p_j) / 2 per the paper; an optional measured link-cost
    matrix (RTT) adds the network term when available.
    """
    w = 0.5 * (perf[:, None] + perf[None, :])
    if link_cost is not None:
        w = w + link_cost
    return w * (1.0 - jnp.eye(perf.shape[0], dtype=w.dtype))


def apsp(w: jax.Array) -> jax.Array:
    """All-pairs shortest paths via min-plus matrix squaring (log-depth)."""
    import math
    a = w.shape[0]
    d = w
    n_iters = max(math.ceil(math.log2(max(a - 1, 2))), 1)
    for _ in range(n_iters):
        d = jnp.min(d[:, :, None] + d[None, :, :], axis=1)
    return d


def placement_scores(dist: jax.Array, participating: jax.Array,
                     perf: jax.Array) -> jax.Array:
    """(A,) mean shortest-path cost to participating agents (paper's final value).

    "From this list we remove the values of the shortest paths between that node and
    nodes that are not yet participating in the simulation run. The remaining values
    are then used to obtain a new performance value [the arithmetic mean]."
    When no agent participates yet, the raw performance value decides.
    """
    p = participating.astype(dist.dtype)
    n = jnp.sum(p)
    mean_to_part = jnp.sum(dist * p[None, :], axis=1) / jnp.maximum(n, 1.0)
    return jnp.where(n > 0, mean_to_part, perf)


def choose_agent(perf: jax.Array, participating: jax.Array,
                 link_cost: jax.Array | None = None) -> jax.Array:
    """The paper's §4.1 decision: preferred agent for the next simulation job."""
    d = apsp(performance_graph(perf, link_cost))
    return jnp.argmin(placement_scores(d, participating, perf)).astype(jnp.int32)


def perf_values_from_counters(fleet_counters: jax.Array, n_owned: jax.Array,
                              pool_occ: jax.Array) -> jax.Array:
    """(A, N_COUNTERS), (A,), (A,) -> (A,) published performance values."""
    return jax.vmap(mon.performance_value)(fleet_counters, n_owned, pool_occ)


def plan_placement(perf: jax.Array, lp_ctx: jax.Array, n_agents: int,
                   link_cost: jax.Array | None = None,
                   load_weight: float = 3.0) -> jax.Array:
    """Place every LP with the paper's algorithm (greedy, run-clustered).

    LPs are placed in ascending id order; the participating set grows per context so
    LPs of the same run cluster. The load term is updated after each placement (the
    monitoring feedback loop, compressed to one pass); ``load_weight`` sets the
    paper's balance-vs-cluster trade-off (§4.1 discusses both pulls).
    """
    n_lp = lp_ctx.shape[0]
    n_ctx = int(jnp.max(lp_ctx)) + 1 if n_lp else 1

    def place_one(carry, i):
        perf_now, part = carry  # part: (n_ctx, A) participating per context
        ctx = lp_ctx[i]
        agent = choose_agent(perf_now, part[ctx], link_cost)
        part = part.at[ctx, agent].set(True)
        perf_now = perf_now.at[agent].add(load_weight)  # hosted-LP load feedback
        return (perf_now, part), agent

    part0 = jnp.zeros((n_ctx, n_agents), bool)
    (_, _), placement = jax.lax.scan(
        place_one, (perf.astype(jnp.float32), part0),
        jnp.arange(n_lp, dtype=jnp.int32))
    return placement.astype(jnp.int32)


def rebalance(fleet_counters: jax.Array, lp_agent: jax.Array, lp_ctx: jax.Array,
              pool_occ: jax.Array, threshold: float = 2.0) -> jax.Array:
    """Dynamic re-decomposition (paper §4: "dynamic decomposition ... linked together
    with a monitoring framework in order to correctly balance the computational
    load"). If the worst agent's performance value exceeds ``threshold``x the mean,
    recompute the full placement; otherwise keep the current one."""
    a = fleet_counters.shape[0]
    n_owned = jnp.zeros((a,), jnp.int32).at[lp_agent].add(1)
    perf = perf_values_from_counters(fleet_counters, n_owned, pool_occ)
    hot = jnp.max(perf) > threshold * jnp.maximum(jnp.mean(perf), 1e-6)
    fresh = plan_placement(perf, lp_ctx, a)
    return jnp.where(hot, fresh, lp_agent)
