"""Event handlers: the behavior of each simulation component (paper §4.2).

``make_handlers(lookahead, work_per_mb)`` builds the ``lax.switch`` dispatch table.
Every handler is a pure function ``(world, counters, event) -> (world, counters,
EventBatch[MAX_EMIT])`` operating on scalar event fields and component tables.

Lookahead contract (the conservative-sync invariant, see DESIGN.md §5): every emitted
event carries a delay of at least ``lookahead`` ticks. Handlers therefore clamp all
delays with ``_delay``. The sequential oracle implements byte-identical semantics, so
trace equality is exact.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import events as ev
from repro.core import monitoring as mon
from repro.core import network as net
from repro.core.components import MAXHOP, World


class Ev(NamedTuple):
    """Scalar view of one event."""

    time: jax.Array
    seq: jax.Array
    kind: jax.Array
    src: jax.Array
    dst: jax.Array
    ctx: jax.Array
    payload: jax.Array  # (PAYLOAD,)


def _no_emits() -> ev.EventBatch:
    return ev.empty_batch(ev.MAX_EMIT)


def _set_emit(batch: ev.EventBatch, slot: int, *, valid, time, kind, src, dst, ctx,
              payload, parent_seq) -> ev.EventBatch:
    """Write one emit slot. seq is the functional child id (oracle-identical)."""
    return ev.EventBatch(
        time=batch.time.at[slot].set(jnp.asarray(time, jnp.int32)),
        seq=batch.seq.at[slot].set(ev.child_seq(parent_seq, slot)),
        kind=batch.kind.at[slot].set(jnp.asarray(kind, jnp.int32)),
        src=batch.src.at[slot].set(jnp.asarray(src, jnp.int32)),
        dst=batch.dst.at[slot].set(jnp.asarray(dst, jnp.int32)),
        ctx=batch.ctx.at[slot].set(jnp.asarray(ctx, jnp.int32)),
        payload=batch.payload.at[slot].set(payload),
        valid=batch.valid.at[slot].set(valid),
    )


def _pad_payload(vals) -> jax.Array:
    out = jnp.zeros((ev.PAYLOAD,), jnp.float32)
    for i, v in enumerate(vals):
        out = out.at[i].set(jnp.asarray(v, jnp.float32))
    return out


def make_handlers(lookahead: int, work_per_mb: float = 1.0):
    """Build the handler dispatch table (list indexed by event kind)."""

    LA = jnp.int32(lookahead)

    def _delay(d) -> jax.Array:
        return jnp.maximum(jnp.asarray(d, jnp.int32), LA)

    # -- 0: NOOP ------------------------------------------------------------
    def h_noop(world: World, counters, e: Ev):
        return world, counters, _no_emits()

    # -- 7: GEN_TICK — activity generator ------------------------------------
    def h_gen_tick(world: World, counters, e: Ev):
        g = world.lp_res[e.dst]
        left = world.gen_left[g]
        fire = left > 0
        world = world._replace(gen_left=world.gen_left.at[g].add(
            jnp.where(fire, -1, 0)))
        out = _no_emits()
        # slot 0: the generated activity event
        out = _set_emit(out, 0, valid=fire,
                        time=e.time + _delay(1),
                        kind=world.gen_kind[g], src=e.dst,
                        dst=world.gen_target[g], ctx=e.ctx,
                        payload=world.gen_payload[g], parent_seq=e.seq)
        # slot 1: next tick to self
        out = _set_emit(out, 1, valid=fire & (left > 1),
                        time=e.time + _delay(world.gen_interval[g]),
                        kind=ev.K_GEN_TICK, src=e.dst, dst=e.dst, ctx=e.ctx,
                        payload=jnp.zeros((ev.PAYLOAD,), jnp.float32),
                        parent_seq=e.seq)
        return world, counters, out

    # -- 3: JOB_SUBMIT — compute farm ----------------------------------------
    # payload: [work, mem, notify_lp, notify_kind, size, _, _, _]
    def h_job_submit(world: World, counters, e: Ev):
        f = world.lp_res[e.dst]
        work, mem = e.payload[0], e.payload[1]
        counters = mon.bump(counters, mon.C_JOBS_SUBMITTED)

        free = (world.cpu_busy[f] == 0) & (world.cpu_power[f] > 0)
        has_free = jnp.any(free)
        slot = jnp.argmax(free).astype(jnp.int32)

        # start immediately on a free CPU
        power = world.cpu_power[f, slot]
        dur = jnp.ceil(work / jnp.maximum(power, 1e-6)).astype(jnp.int32)
        finish = e.time + _delay(dur)
        world = world._replace(
            cpu_busy=world.cpu_busy.at[f, slot].add(jnp.where(has_free, 1, 0)),
            cpu_mem=world.cpu_mem.at[f, slot].add(jnp.where(has_free, mem, 0.0)),
        )

        # or queue (FIFO) when all CPUs are busy
        qn = world.jobq_n[f]
        qcap = world.jobq.shape[1]
        can_q = (~has_free) & (qn < qcap)
        qrow = jnp.stack([e.payload[0], e.payload[1], e.payload[2], e.payload[3],
                          e.payload[4], 0.0])
        world = world._replace(
            jobq=world.jobq.at[f, jnp.where(can_q, qn, 0)].set(
                jnp.where(can_q, qrow, world.jobq[f, jnp.where(can_q, qn, 0)])),
            jobq_n=world.jobq_n.at[f].add(jnp.where(can_q, 1, 0)),
        )
        counters = mon.bump(counters, mon.C_DROP_QUEUE,
                            jnp.where((~has_free) & (qn >= qcap), 1, 0))

        out = _no_emits()
        out = _set_emit(out, 0, valid=has_free, time=finish, kind=ev.K_JOB_END,
                        src=e.dst, dst=e.dst, ctx=e.ctx,
                        payload=_pad_payload([slot, work, mem, e.payload[2],
                                              e.payload[3], e.payload[4]]),
                        parent_seq=e.seq)
        return world, counters, out

    # -- 4: JOB_END — compute farm -------------------------------------------
    # payload: [slot, work, mem, notify_lp, notify_kind, size, _, _]
    def h_job_end(world: World, counters, e: Ev):
        f = world.lp_res[e.dst]
        slot = e.payload[0].astype(jnp.int32)
        counters = mon.bump(counters, mon.C_JOBS_DONE)
        world = world._replace(
            cpu_busy=world.cpu_busy.at[f, slot].set(0),
            cpu_mem=world.cpu_mem.at[f, slot].set(0.0),
        )

        # pop FIFO head into the freed CPU
        qn = world.jobq_n[f]
        has_q = qn > 0
        head = world.jobq[f, 0]
        qcap = world.jobq.shape[1]
        shifted = jnp.concatenate([world.jobq[f, 1:], jnp.zeros((1, 6), jnp.float32)])
        world = world._replace(
            jobq=world.jobq.at[f].set(jnp.where(has_q, shifted, world.jobq[f])),
            jobq_n=world.jobq_n.at[f].add(jnp.where(has_q, -1, 0)),
            cpu_busy=world.cpu_busy.at[f, slot].set(jnp.where(has_q, 1, 0)),
            cpu_mem=world.cpu_mem.at[f, slot].set(jnp.where(has_q, head[1], 0.0)),
        )
        power = world.cpu_power[f, slot]
        dur = jnp.ceil(head[0] / jnp.maximum(power, 1e-6)).astype(jnp.int32)

        out = _no_emits()
        # slot 0: completion of the popped job
        out = _set_emit(out, 0, valid=has_q, time=e.time + _delay(dur),
                        kind=ev.K_JOB_END, src=e.dst, dst=e.dst, ctx=e.ctx,
                        payload=_pad_payload([slot, head[0], head[1], head[2],
                                              head[3], head[4]]),
                        parent_seq=e.seq)
        # slot 1: notification (e.g. DATA_WRITE to storage after an analysis job)
        nlp = e.payload[3].astype(jnp.int32)
        nkind = e.payload[4].astype(jnp.int32)
        out = _set_emit(out, 1, valid=nlp >= 0, time=e.time + _delay(1),
                        kind=nkind, src=e.dst, dst=jnp.maximum(nlp, 0), ctx=e.ctx,
                        payload=_pad_payload([e.payload[5]]),
                        parent_seq=e.seq)
        return world, counters, out

    # -- network helpers ------------------------------------------------------
    def _reshare_and_schedule(world: World, counters, e: Ev, r):
        """Recompute fair shares for region r and schedule the next completion."""
        inc = net.incidence(world.flow_links[r], world.link_bw.shape[1])
        rates = net.maxmin_rates(inc, world.link_bw[r], world.flow_active[r])
        world = world._replace(flow_rate=world.flow_rate.at[r].set(rates))
        counters = mon.bump(counters, mon.C_INTERRUPTS)
        gen = world.net_gen[r] + 1
        world = world._replace(net_gen=world.net_gen.at[r].set(gen))
        t_fin = net.completion_times(world.flow_rem[r], rates,
                                     world.flow_tlast[r], world.flow_active[r])
        tmin = jnp.min(t_fin)
        any_active = jnp.any(world.flow_active[r])
        t_next = jnp.maximum(tmin, e.time + LA)
        return world, counters, gen, any_active, t_next

    # -- 1: FLOW_START — network region ---------------------------------------
    # payload: [size, l0, l1, l2, notify_lp, notify_kind, notify2_lp, notify2_kind]
    def h_flow_start(world: World, counters, e: Ev):
        r = world.lp_res[e.dst]
        size = e.payload[0]
        counters = mon.bump(counters, mon.C_FLOWS_STARTED)

        # progress flows to now (the paper's interrupt scheme: shares change now)
        rem2, tlast2 = net.progress_flows(world.flow_rem[r], world.flow_rate[r],
                                          world.flow_tlast[r],
                                          world.flow_active[r], e.time)
        world = world._replace(flow_rem=world.flow_rem.at[r].set(rem2),
                               flow_tlast=world.flow_tlast.at[r].set(tlast2))

        free = ~world.flow_active[r]
        has_free = jnp.any(free)
        s = jnp.argmax(free).astype(jnp.int32)
        counters = mon.bump(counters, mon.C_DROP_FLOW, jnp.where(has_free, 0, 1))

        route = e.payload[1:4].astype(jnp.int32)  # -1 padded
        notify = jnp.stack([e.payload[4], e.payload[5], size * work_per_mb, size,
                            e.payload[6], e.payload[7]])
        world = world._replace(
            flow_active=world.flow_active.at[r, s].set(
                jnp.where(has_free, True, world.flow_active[r, s])),
            flow_rem=world.flow_rem.at[r, s].set(
                jnp.where(has_free, size, world.flow_rem[r, s])),
            flow_tlast=world.flow_tlast.at[r, s].set(
                jnp.where(has_free, e.time, world.flow_tlast[r, s])),
            flow_links=world.flow_links.at[r, s].set(
                jnp.where(has_free, route, world.flow_links[r, s])),
            flow_notify=world.flow_notify.at[r, s].set(
                jnp.where(has_free, notify, world.flow_notify[r, s])),
        )

        world, counters, gen, any_active, t_next = _reshare_and_schedule(
            world, counters, e, r)
        out = _no_emits()
        out = _set_emit(out, 2, valid=any_active, time=t_next, kind=ev.K_FLOW_END,
                        src=e.dst, dst=e.dst, ctx=e.ctx,
                        payload=_pad_payload([gen]), parent_seq=e.seq)
        return world, counters, out

    # -- 2: FLOW_END — network region ------------------------------------------
    # payload: [gen]
    def h_flow_end(world: World, counters, e: Ev):
        r = world.lp_res[e.dst]
        gen_ok = e.payload[0].astype(jnp.int32) == world.net_gen[r]
        counters = mon.bump(counters, mon.C_STALE, jnp.where(gen_ok, 0, 1))

        def stale(world, counters):
            return world, counters, _no_emits()

        def live(world, counters):
            rem2, tlast2 = net.progress_flows(world.flow_rem[r], world.flow_rate[r],
                                              world.flow_tlast[r],
                                              world.flow_active[r], e.time)
            world = world._replace(flow_rem=world.flow_rem.at[r].set(rem2),
                                   flow_tlast=world.flow_tlast.at[r].set(tlast2))
            done = world.flow_active[r] & (world.flow_rem[r] <= 1e-3)
            # complete up to 2 flows this event; a follow-up FLOW_END drains the rest
            order = jnp.argsort(jnp.where(done, jnp.arange(done.shape[0]), 1 << 20))
            d0, d1 = order[0], order[1]
            c0 = done[d0]
            c1 = done[d1]
            world = world._replace(
                flow_active=world.flow_active.at[r, d0].set(
                    jnp.where(c0, False, world.flow_active[r, d0])))
            world = world._replace(
                flow_active=world.flow_active.at[r, d1].set(
                    jnp.where(c1, False, world.flow_active[r, d1])))
            n_done = c0.astype(jnp.int32) + c1.astype(jnp.int32)
            counters2 = mon.bump(counters, mon.C_FLOWS_DONE, n_done)
            mb = (jnp.where(c0, world.flow_notify[r, d0, 3], 0.0)
                  + jnp.where(c1, world.flow_notify[r, d1, 3], 0.0))
            counters2 = mon.bump(counters2, mon.C_MB_TRANSFERRED,
                                 jnp.round(mb).astype(jnp.int32))

            world, counters2, gen, any_active, t_next = _reshare_and_schedule(
                world, counters2, e, r)

            out = _no_emits()
            for slot, (di, ci) in enumerate([(d0, c0), (d1, c1)]):
                note = world.flow_notify[r, di]
                nlp = note[0].astype(jnp.int32)
                # notification payload: [work, mem(=size), notify2_lp, notify2_kind, size]
                out = _set_emit(out, slot, valid=ci & (nlp >= 0),
                                time=e.time + _delay(1),
                                kind=note[1].astype(jnp.int32), src=e.dst,
                                dst=jnp.maximum(nlp, 0), ctx=e.ctx,
                                payload=_pad_payload([note[2], note[3], note[4],
                                                      note[5], note[3]]),
                                parent_seq=e.seq)
            out = _set_emit(out, 2, valid=any_active, time=t_next,
                            kind=ev.K_FLOW_END, src=e.dst, dst=e.dst, ctx=e.ctx,
                            payload=_pad_payload([gen]), parent_seq=e.seq)
            return world, counters2, out

        return jax.lax.cond(gen_ok, live, stale, world, counters)

    # -- 5: DATA_WRITE — storage ------------------------------------------------
    # payload: [size]
    def h_data_write(world: World, counters, e: Ev):
        s = world.lp_res[e.dst]
        size = e.payload[0]
        counters = mon.bump(counters, mon.C_WRITES)
        counters = mon.bump(counters, mon.C_MB_WRITTEN,
                            jnp.round(size).astype(jnp.int32))
        used = world.sto_used[s, 0] + size
        world = world._replace(sto_used=world.sto_used.at[s, 0].set(used))

        over = (used > 0.9 * world.sto_cap[s, 0]) & (world.sto_flag[s] == 0)
        amount = jnp.maximum(used - 0.7 * world.sto_cap[s, 0], 0.0)
        dur = jnp.ceil(amount / jnp.maximum(world.sto_rate[s], 1e-6)).astype(jnp.int32)
        world = world._replace(
            sto_flag=world.sto_flag.at[s].set(jnp.where(over, 1, world.sto_flag[s])))
        out = _no_emits()
        out = _set_emit(out, 0, valid=over, time=e.time + _delay(dur),
                        kind=ev.K_MIGRATE, src=e.dst, dst=e.dst, ctx=e.ctx,
                        payload=_pad_payload([amount]), parent_seq=e.seq)
        return world, counters, out

    # -- 6: MIGRATE — storage (db server -> mass storage, paper §4.2) -----------
    def h_migrate(world: World, counters, e: Ev):
        s = world.lp_res[e.dst]
        amount = jnp.minimum(e.payload[0], world.sto_used[s, 0])
        world = world._replace(
            sto_used=world.sto_used.at[s, 0].add(-amount)
                                 .at[s, 1].add(amount),
            sto_flag=world.sto_flag.at[s].set(0),
        )
        counters = mon.bump(counters, mon.C_MIGRATIONS)
        return world, counters, _no_emits()

    table = [None] * ev.N_KINDS
    table[ev.K_NOOP] = h_noop
    table[ev.K_FLOW_START] = h_flow_start
    table[ev.K_FLOW_END] = h_flow_end
    table[ev.K_JOB_SUBMIT] = h_job_submit
    table[ev.K_JOB_END] = h_job_end
    table[ev.K_DATA_WRITE] = h_data_write
    table[ev.K_MIGRATE] = h_migrate
    table[ev.K_GEN_TICK] = h_gen_tick
    return table


def apply_handler(table, world: World, counters, e: Ev):
    """Dispatch one event through the handler table (lax.switch over kind)."""
    kind = jnp.clip(e.kind, 0, len(table) - 1)
    return jax.lax.switch(kind, table, world, counters, e)


# World fields a handler may write — everything else (topology, capacities,
# placement, LP columns) is immutable inside a window or owned by the engine
# wrapper. Mirrors the owner-wins field list in components.sync_world minus
# lp_state/lp_lvt, which the engine applies as segment scatters over the
# event batch. Restricting the vectorized merge to these fields keeps the
# batched dispatch O(lanes x component tables) instead of O(lanes x world).
MUTABLE_FIELDS = ("cpu_busy", "cpu_mem", "jobq", "jobq_n",
                  "flow_active", "flow_rem", "flow_rate", "flow_tlast",
                  "flow_links", "flow_notify", "net_gen",
                  "sto_used", "sto_flag", "gen_left")


def apply_handler_batch(table, world: World, rows: ev.EventBatch,
                        active: jax.Array):
    """Dispatch a window's candidate rows through one vectorized handler call.

    Batch-safety contract: every handler is a pure ``world``-indexed function —
    it reads and writes only the component row owned by its destination LP
    (``lp_res[e.dst]``) plus write-only commutative counters. The caller
    guarantees ``active`` rows have pairwise-distinct destination LPs and
    component rows (sync.conflict_mask), so each world element is written by
    at most one active lane and the element-wise segment scatter below ("take
    the one lane that changed it") is exact — no arithmetic on state values,
    hence byte-identical to folding the same rows sequentially in any order.
    The per-LP LVT/lifecycle columns are likewise disjoint across lanes and
    are applied as two direct segment scatters (max commutes; the RUNNING
    mark is idempotent).

    Returns ``(world', counter_delta, emits)`` with emits shaped
    (B, MAX_EMIT) per field, lane-aligned with ``rows`` and masked by
    ``active``.
    """
    n_lanes = rows.time.shape[0]

    def lane(row):
        e = Ev(time=row.time, seq=row.seq, kind=row.kind, src=row.src,
               dst=row.dst, ctx=row.ctx, payload=row.payload)
        w2, c2, out = apply_handler(table, world, mon.zero_counters(), e)
        return {f: getattr(w2, f) for f in MUTABLE_FIELDS}, c2, out

    lanes_mut, lanes_counters, lanes_out = jax.vmap(lane)(rows)

    # counters: write-only int adds commute, so summing the active lanes'
    # deltas equals bumping them one by one in window order.
    cdelta = jnp.sum(jnp.where(active[:, None], lanes_counters, 0), axis=0)

    def merge(lane_field, base):
        m = active.reshape((n_lanes,) + (1,) * base.ndim)
        changed = m & (lane_field != base[None])
        pick = jnp.argmax(changed, axis=0)
        picked = jnp.take_along_axis(lane_field, pick[None], axis=0)[0]
        return jnp.where(jnp.any(changed, axis=0), picked, base)

    world = world._replace(**{
        f: merge(lanes_mut[f], getattr(world, f)) for f in MUTABLE_FIELDS})

    # per-LP columns: disjoint dst across active lanes -> one scatter each
    dst = jnp.where(active, rows.dst, world.lp_lvt.shape[0])  # OOB -> drop
    world = world._replace(
        lp_lvt=world.lp_lvt.at[dst].max(rows.time, mode="drop"),
        lp_state=world.lp_state.at[dst].set(2, mode="drop"),  # RUNNING
    )

    out_valid = lanes_out.valid & active[:, None]
    return world, cdelta, lanes_out._replace(valid=out_valid)
