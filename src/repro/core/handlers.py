"""Event handlers: the behavior of each simulation component (paper §4.2).

``make_handlers(lookahead, work_per_mb)`` builds the ``lax.switch`` dispatch table.
Every handler is a *per-row segment-scatter kernel*: it gathers only the component
row owned by its destination LP (``lp_res[e.dst]``), computes the row-local update,
and returns a compact :class:`WorldDelta` — a typed ``(table row index, new row)``
write set — instead of a whole mutated :class:`World`. Deltas are applied by
:func:`apply_delta` (one ``.at[row].set`` scatter per field), which serves both the
sequential paths (one event at a time) and the engine's batched dispatch (all
lanes' deltas in one segment scatter, see :func:`apply_handler_batch`). This keeps
the vectorized merge O(lanes x row) instead of O(lanes x pool-wide tables).

Invariants the engine and the conflict mask rely on (the **delta contract**):

1. **Row locality** — the handler for kind ``k`` reads and writes exactly one row
   of one component table: row ``lp_res[e.dst]`` of table ``events.KIND_TABLE[k]``
   (plus immutable topology/capacity columns, which are never written, and
   write-only commutative counters). A handler never touches another LP's row.
2. **Whole-row writes** — a handler that writes a table writes *every* mutable
   field of that table's row (unchanged fields carry their old bytes), so a delta
   applies with plain ``.at[row].set`` scatters and needs no per-element masks.
3. **Disjoint-write guarantee** — ``sync.conflict_mask`` keys on exactly the
   ``(KIND_TABLE[kind], lp_res[dst])`` row each handler declares, so the batched
   dispatcher only ever scatters pairwise-distinct rows in one call; combined
   with (1) this makes the batched execution byte-identical to folding the same
   events sequentially in any order.
4. **Lookahead contract** (the conservative-sync invariant, see
   docs/architecture.md): every emitted event carries a delay of at least
   ``lookahead`` ticks; handlers clamp all delays with ``_delay``. The sequential
   oracle reuses these same kernels, so trace equality is exact.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import events as ev
from repro.core import monitoring as mon
from repro.core import network as net
from repro.core.components import MAXHOP, World

# Sentinel row index meaning "this handler writes no row of that table".
# Out of bounds for every component table, so ``mode="drop"`` scatters skip it.
NO_ROW = jnp.int32(2**31 - 1)


class Ev(NamedTuple):
    """Scalar view of one event."""

    time: jax.Array
    seq: jax.Array
    kind: jax.Array
    src: jax.Array
    dst: jax.Array
    ctx: jax.Array
    payload: jax.Array  # (PAYLOAD,)


class WorldDelta(NamedTuple):
    """Typed per-row write set of one handler invocation (the delta schema).

    One row index per component table (``NO_ROW`` == table untouched) plus the
    new row value for every mutable field of that table. ``DELTA_SCHEMA`` maps
    each field to its row-index column; everything not listed there (topology,
    capacities, placement, per-LP columns) is immutable inside a window or owned
    by the engine wrapper. Shapes below are per-row (no leading table dim); the
    batched dispatcher stacks a ``(lanes,)`` axis in front of every field.
    """

    farm_row: jax.Array     # i32 — compute-farm row, or NO_ROW
    cpu_busy: jax.Array     # i32 (MAXCPU,)
    cpu_mem: jax.Array      # f32 (MAXCPU,)
    jobq: jax.Array         # f32 (QCAP, 6)
    jobq_n: jax.Array       # i32 scalar
    net_row: jax.Array      # i32 — network-region row, or NO_ROW
    flow_active: jax.Array  # bool (MAXFLOW,)
    flow_rem: jax.Array     # f32 (MAXFLOW,)
    flow_rate: jax.Array    # f32 (MAXFLOW,)
    flow_tlast: jax.Array   # i32 (MAXFLOW,)
    flow_links: jax.Array   # i32 (MAXFLOW, MAXHOP)
    flow_notify: jax.Array  # f32 (MAXFLOW, 6)
    net_gen: jax.Array      # i32 scalar
    sto_row: jax.Array      # i32 — storage row, or NO_ROW
    sto_used: jax.Array     # f32 (2,)
    sto_flag: jax.Array     # i32 scalar
    gen_row: jax.Array      # i32 — generator row, or NO_ROW
    gen_left: jax.Array     # i32 scalar


# The typed delta schema: mutable World field -> the WorldDelta row-index column
# that addresses it. Replaces the PR 2 MUTABLE_FIELDS whole-table merge list:
# restricting writes to declared rows is what drops the batched merge from
# O(lanes x component tables) to O(lanes x row). Mirrors the owner-wins field
# list in components.sync_world minus lp_state/lp_lvt, which the engine applies
# as segment scatters over the event batch (max / idempotent-set, so they
# commute even across duplicate-dst lanes).
DELTA_SCHEMA: dict[str, str] = {
    "cpu_busy": "farm_row", "cpu_mem": "farm_row",
    "jobq": "farm_row", "jobq_n": "farm_row",
    "flow_active": "net_row", "flow_rem": "net_row", "flow_rate": "net_row",
    "flow_tlast": "net_row", "flow_links": "net_row", "flow_notify": "net_row",
    "net_gen": "net_row",
    "sto_used": "sto_row", "sto_flag": "sto_row",
    "gen_left": "gen_row",
}
MUTABLE_FIELDS = tuple(DELTA_SCHEMA)
ROW_FIELDS = ("farm_row", "net_row", "sto_row", "gen_row")


def empty_delta(world: World) -> WorldDelta:
    """The identity delta: no rows declared, zero-filled row payloads."""
    def z(f: str) -> jax.Array:
        return jnp.zeros_like(getattr(world, f)[0])
    return WorldDelta(
        farm_row=NO_ROW, cpu_busy=z("cpu_busy"), cpu_mem=z("cpu_mem"),
        jobq=z("jobq"), jobq_n=z("jobq_n"),
        net_row=NO_ROW, flow_active=z("flow_active"), flow_rem=z("flow_rem"),
        flow_rate=z("flow_rate"), flow_tlast=z("flow_tlast"),
        flow_links=z("flow_links"), flow_notify=z("flow_notify"),
        net_gen=z("net_gen"),
        sto_row=NO_ROW, sto_used=z("sto_used"), sto_flag=z("sto_flag"),
        gen_row=NO_ROW, gen_left=z("gen_left"),
    )


def apply_delta(world: World, delta: WorldDelta) -> World:
    """Scatter a delta's declared rows into the world.

    Polymorphic over the lane axis: with scalar row indices this applies one
    handler's delta (the sequential paths); with ``(lanes,)`` row indices and
    ``(lanes, ...)`` row payloads it applies a whole window's deltas in one
    segment scatter per field. ``NO_ROW`` (and any masked-out lane) is out of
    bounds and dropped. Exact under the disjoint-write guarantee: every
    scattered row index appears at most once, so ``.set`` has a unique winner.
    """
    return world._replace(**{
        f: getattr(world, f).at[getattr(delta, rf)].set(
            getattr(delta, f), mode="drop")
        for f, rf in DELTA_SCHEMA.items()})


def _no_emits() -> ev.EventBatch:
    return ev.empty_batch(ev.MAX_EMIT)


def _set_emit(batch: ev.EventBatch, slot: int, *, valid, time, kind, src, dst, ctx,
              payload, parent_seq) -> ev.EventBatch:
    """Write one emit slot. seq is the functional child id (oracle-identical)."""
    return ev.EventBatch(
        time=batch.time.at[slot].set(jnp.asarray(time, jnp.int32)),
        seq=batch.seq.at[slot].set(ev.child_seq(parent_seq, slot)),
        kind=batch.kind.at[slot].set(jnp.asarray(kind, jnp.int32)),
        src=batch.src.at[slot].set(jnp.asarray(src, jnp.int32)),
        dst=batch.dst.at[slot].set(jnp.asarray(dst, jnp.int32)),
        ctx=batch.ctx.at[slot].set(jnp.asarray(ctx, jnp.int32)),
        payload=batch.payload.at[slot].set(payload),
        valid=batch.valid.at[slot].set(valid),
    )


def _pad_payload(vals) -> jax.Array:
    out = jnp.zeros((ev.PAYLOAD,), jnp.float32)
    for i, v in enumerate(vals):
        out = out.at[i].set(jnp.asarray(v, jnp.float32))
    return out


def make_handlers(lookahead: int, work_per_mb: float = 1.0):
    """Build the handler dispatch table (list indexed by event kind).

    Each entry is a row kernel ``(world, counters, e) -> (delta, counters,
    EventBatch[MAX_EMIT])`` honoring the delta contract in the module docstring.
    """

    LA = jnp.int32(lookahead)

    def _delay(d) -> jax.Array:
        return jnp.maximum(jnp.asarray(d, jnp.int32), LA)

    # -- 0: NOOP ------------------------------------------------------------
    def h_noop(world: World, counters, e: Ev):
        return empty_delta(world), counters, _no_emits()

    # -- 7: GEN_TICK — activity generator ------------------------------------
    def h_gen_tick(world: World, counters, e: Ev):
        g = world.lp_res[e.dst]
        left = world.gen_left[g]
        fire = left > 0
        new_left = left + jnp.where(fire, -1, 0)
        out = _no_emits()
        # slot 0: the generated activity event
        out = _set_emit(out, 0, valid=fire,
                        time=e.time + _delay(1),
                        kind=world.gen_kind[g], src=e.dst,
                        dst=world.gen_target[g], ctx=e.ctx,
                        payload=world.gen_payload[g], parent_seq=e.seq)
        # slot 1: next tick to self
        out = _set_emit(out, 1, valid=fire & (left > 1),
                        time=e.time + _delay(world.gen_interval[g]),
                        kind=ev.K_GEN_TICK, src=e.dst, dst=e.dst, ctx=e.ctx,
                        payload=jnp.zeros((ev.PAYLOAD,), jnp.float32),
                        parent_seq=e.seq)
        delta = empty_delta(world)._replace(gen_row=g, gen_left=new_left)
        return delta, counters, out

    # -- 3: JOB_SUBMIT — compute farm ----------------------------------------
    # payload: [work, mem, notify_lp, notify_kind, size, _, _, _]
    def h_job_submit(world: World, counters, e: Ev):
        f = world.lp_res[e.dst]
        busy = world.cpu_busy[f]       # (MAXCPU,) row gathers
        memr = world.cpu_mem[f]
        jq = world.jobq[f]
        qn0 = world.jobq_n[f]
        power_row = world.cpu_power[f]
        work, mem = e.payload[0], e.payload[1]
        counters = mon.bump(counters, mon.C_JOBS_SUBMITTED)

        free = (busy == 0) & (power_row > 0)
        has_free = jnp.any(free)
        slot = jnp.argmax(free).astype(jnp.int32)

        # start immediately on a free CPU
        power = power_row[slot]
        dur = jnp.ceil(work / jnp.maximum(power, 1e-6)).astype(jnp.int32)
        finish = e.time + _delay(dur)
        busy = busy.at[slot].add(jnp.where(has_free, 1, 0))
        memr = memr.at[slot].add(jnp.where(has_free, mem, 0.0))

        # or queue (FIFO) when all CPUs are busy
        qcap = jq.shape[0]
        can_q = (~has_free) & (qn0 < qcap)
        qrow = jnp.stack([e.payload[0], e.payload[1], e.payload[2], e.payload[3],
                          e.payload[4], 0.0])
        qi = jnp.where(can_q, qn0, 0)
        jq = jq.at[qi].set(jnp.where(can_q, qrow, jq[qi]))
        new_qn = qn0 + jnp.where(can_q, 1, 0)
        counters = mon.bump(counters, mon.C_DROP_QUEUE,
                            jnp.where((~has_free) & (qn0 >= qcap), 1, 0))

        out = _no_emits()
        out = _set_emit(out, 0, valid=has_free, time=finish, kind=ev.K_JOB_END,
                        src=e.dst, dst=e.dst, ctx=e.ctx,
                        payload=_pad_payload([slot, work, mem, e.payload[2],
                                              e.payload[3], e.payload[4]]),
                        parent_seq=e.seq)
        delta = empty_delta(world)._replace(
            farm_row=f, cpu_busy=busy, cpu_mem=memr, jobq=jq, jobq_n=new_qn)
        return delta, counters, out

    # -- 4: JOB_END — compute farm -------------------------------------------
    # payload: [slot, work, mem, notify_lp, notify_kind, size, _, _]
    def h_job_end(world: World, counters, e: Ev):
        f = world.lp_res[e.dst]
        slot = e.payload[0].astype(jnp.int32)
        counters = mon.bump(counters, mon.C_JOBS_DONE)
        busy = world.cpu_busy[f].at[slot].set(0)
        memr = world.cpu_mem[f].at[slot].set(0.0)

        # pop FIFO head into the freed CPU
        jq = world.jobq[f]
        qn0 = world.jobq_n[f]
        has_q = qn0 > 0
        head = jq[0]
        shifted = jnp.concatenate([jq[1:], jnp.zeros((1, 6), jnp.float32)])
        new_jq = jnp.where(has_q, shifted, jq)
        new_qn = qn0 + jnp.where(has_q, -1, 0)
        busy = busy.at[slot].set(jnp.where(has_q, 1, 0))
        memr = memr.at[slot].set(jnp.where(has_q, head[1], 0.0))
        power = world.cpu_power[f, slot]
        dur = jnp.ceil(head[0] / jnp.maximum(power, 1e-6)).astype(jnp.int32)

        out = _no_emits()
        # slot 0: completion of the popped job
        out = _set_emit(out, 0, valid=has_q, time=e.time + _delay(dur),
                        kind=ev.K_JOB_END, src=e.dst, dst=e.dst, ctx=e.ctx,
                        payload=_pad_payload([slot, head[0], head[1], head[2],
                                              head[3], head[4]]),
                        parent_seq=e.seq)
        # slot 1: notification (e.g. DATA_WRITE to storage after an analysis job)
        nlp = e.payload[3].astype(jnp.int32)
        nkind = e.payload[4].astype(jnp.int32)
        out = _set_emit(out, 1, valid=nlp >= 0, time=e.time + _delay(1),
                        kind=nkind, src=e.dst, dst=jnp.maximum(nlp, 0), ctx=e.ctx,
                        payload=_pad_payload([e.payload[5]]),
                        parent_seq=e.seq)
        delta = empty_delta(world)._replace(
            farm_row=f, cpu_busy=busy, cpu_mem=memr, jobq=new_jq, jobq_n=new_qn)
        return delta, counters, out

    # -- network helpers ------------------------------------------------------
    def _reshare_and_schedule(counters, e: Ev, links_row, bw_row, active_row,
                              rem_row, tlast_row, gen0):
        """Recompute fair shares for one region row, schedule the next completion."""
        inc = net.incidence(links_row, bw_row.shape[0])
        rates = net.maxmin_rates(inc, bw_row, active_row)
        counters = mon.bump(counters, mon.C_INTERRUPTS)
        gen = gen0 + 1
        t_fin = net.completion_times(rem_row, rates, tlast_row, active_row)
        tmin = jnp.min(t_fin)
        any_active = jnp.any(active_row)
        t_next = jnp.maximum(tmin, e.time + LA)
        return rates, gen, counters, any_active, t_next

    # -- 1: FLOW_START — network region ---------------------------------------
    # payload: [size, l0, l1, l2, notify_lp, notify_kind, notify2_lp, notify2_kind]
    def h_flow_start(world: World, counters, e: Ev):
        r = world.lp_res[e.dst]
        active = world.flow_active[r]  # (MAXFLOW,) row gathers
        rate = world.flow_rate[r]
        links = world.flow_links[r]
        notif = world.flow_notify[r]
        size = e.payload[0]
        counters = mon.bump(counters, mon.C_FLOWS_STARTED)

        # progress flows to now (the paper's interrupt scheme: shares change now)
        rem, tlast = net.progress_flows(world.flow_rem[r], rate,
                                        world.flow_tlast[r], active, e.time)

        free = ~active
        has_free = jnp.any(free)
        s = jnp.argmax(free).astype(jnp.int32)
        counters = mon.bump(counters, mon.C_DROP_FLOW, jnp.where(has_free, 0, 1))

        route = e.payload[1:4].astype(jnp.int32)  # -1 padded
        nrow = jnp.stack([e.payload[4], e.payload[5], size * work_per_mb, size,
                          e.payload[6], e.payload[7]])
        active = active.at[s].set(jnp.where(has_free, True, active[s]))
        rem = rem.at[s].set(jnp.where(has_free, size, rem[s]))
        tlast = tlast.at[s].set(jnp.where(has_free, e.time, tlast[s]))
        links = links.at[s].set(jnp.where(has_free, route, links[s]))
        notif = notif.at[s].set(jnp.where(has_free, nrow, notif[s]))

        rates, gen, counters, any_active, t_next = _reshare_and_schedule(
            counters, e, links, world.link_bw[r], active, rem, tlast,
            world.net_gen[r])
        out = _no_emits()
        out = _set_emit(out, 2, valid=any_active, time=t_next, kind=ev.K_FLOW_END,
                        src=e.dst, dst=e.dst, ctx=e.ctx,
                        payload=_pad_payload([gen]), parent_seq=e.seq)
        delta = empty_delta(world)._replace(
            net_row=r, flow_active=active, flow_rem=rem, flow_rate=rates,
            flow_tlast=tlast, flow_links=links, flow_notify=notif, net_gen=gen)
        return delta, counters, out

    # -- 2: FLOW_END — network region ------------------------------------------
    # payload: [gen]
    def h_flow_end(world: World, counters, e: Ev):
        r = world.lp_res[e.dst]
        gen_ok = e.payload[0].astype(jnp.int32) == world.net_gen[r]
        counters = mon.bump(counters, mon.C_STALE, jnp.where(gen_ok, 0, 1))

        def stale(counters):
            return empty_delta(world), counters, _no_emits()

        def live(counters):
            active = world.flow_active[r]
            rem, tlast = net.progress_flows(world.flow_rem[r], world.flow_rate[r],
                                            world.flow_tlast[r], active, e.time)
            done = active & (rem <= 1e-3)
            # complete up to 2 flows this event; a follow-up FLOW_END drains the rest
            order = jnp.argsort(jnp.where(done, jnp.arange(done.shape[0]), 1 << 20))
            d0, d1 = order[0], order[1]
            c0 = done[d0]
            c1 = done[d1]
            active = active.at[d0].set(jnp.where(c0, False, active[d0]))
            active = active.at[d1].set(jnp.where(c1, False, active[d1]))
            n_done = c0.astype(jnp.int32) + c1.astype(jnp.int32)
            counters2 = mon.bump(counters, mon.C_FLOWS_DONE, n_done)
            notif = world.flow_notify[r]
            mb = (jnp.where(c0, notif[d0, 3], 0.0)
                  + jnp.where(c1, notif[d1, 3], 0.0))
            counters2 = mon.bump(counters2, mon.C_MB_TRANSFERRED,
                                 jnp.round(mb).astype(jnp.int32))

            rates, gen, counters2, any_active, t_next = _reshare_and_schedule(
                counters2, e, world.flow_links[r], world.link_bw[r], active,
                rem, tlast, world.net_gen[r])

            out = _no_emits()
            for slot, (di, ci) in enumerate([(d0, c0), (d1, c1)]):
                note = notif[di]
                nlp = note[0].astype(jnp.int32)
                # notification payload: [work, mem(=size), notify2_lp, notify2_kind, size]
                out = _set_emit(out, slot, valid=ci & (nlp >= 0),
                                time=e.time + _delay(1),
                                kind=note[1].astype(jnp.int32), src=e.dst,
                                dst=jnp.maximum(nlp, 0), ctx=e.ctx,
                                payload=_pad_payload([note[2], note[3], note[4],
                                                      note[5], note[3]]),
                                parent_seq=e.seq)
            out = _set_emit(out, 2, valid=any_active, time=t_next,
                            kind=ev.K_FLOW_END, src=e.dst, dst=e.dst, ctx=e.ctx,
                            payload=_pad_payload([gen]), parent_seq=e.seq)
            delta = empty_delta(world)._replace(
                net_row=r, flow_active=active, flow_rem=rem, flow_rate=rates,
                flow_tlast=tlast, flow_links=world.flow_links[r],
                flow_notify=notif, net_gen=gen)
            return delta, counters2, out

        return jax.lax.cond(gen_ok, live, stale, counters)

    # -- 5: DATA_WRITE — storage ------------------------------------------------
    # payload: [size]
    def h_data_write(world: World, counters, e: Ev):
        s = world.lp_res[e.dst]
        size = e.payload[0]
        counters = mon.bump(counters, mon.C_WRITES)
        counters = mon.bump(counters, mon.C_MB_WRITTEN,
                            jnp.round(size).astype(jnp.int32))
        used_row = world.sto_used[s]   # (2,) [disk, tape]
        used = used_row[0] + size
        used_row = used_row.at[0].set(used)

        flag0 = world.sto_flag[s]
        over = (used > 0.9 * world.sto_cap[s, 0]) & (flag0 == 0)
        amount = jnp.maximum(used - 0.7 * world.sto_cap[s, 0], 0.0)
        dur = jnp.ceil(amount / jnp.maximum(world.sto_rate[s], 1e-6)).astype(jnp.int32)
        new_flag = jnp.where(over, 1, flag0)
        out = _no_emits()
        out = _set_emit(out, 0, valid=over, time=e.time + _delay(dur),
                        kind=ev.K_MIGRATE, src=e.dst, dst=e.dst, ctx=e.ctx,
                        payload=_pad_payload([amount]), parent_seq=e.seq)
        delta = empty_delta(world)._replace(
            sto_row=s, sto_used=used_row, sto_flag=new_flag)
        return delta, counters, out

    # -- 6: MIGRATE — storage (db server -> mass storage, paper §4.2) -----------
    def h_migrate(world: World, counters, e: Ev):
        s = world.lp_res[e.dst]
        used_row = world.sto_used[s]
        amount = jnp.minimum(e.payload[0], used_row[0])
        used_row = used_row.at[0].add(-amount).at[1].add(amount)
        counters = mon.bump(counters, mon.C_MIGRATIONS)
        delta = empty_delta(world)._replace(
            sto_row=s, sto_used=used_row, sto_flag=jnp.int32(0))
        return delta, counters, _no_emits()

    table = [None] * ev.N_KINDS
    table[ev.K_NOOP] = h_noop
    table[ev.K_FLOW_START] = h_flow_start
    table[ev.K_FLOW_END] = h_flow_end
    table[ev.K_JOB_SUBMIT] = h_job_submit
    table[ev.K_JOB_END] = h_job_end
    table[ev.K_DATA_WRITE] = h_data_write
    table[ev.K_MIGRATE] = h_migrate
    table[ev.K_GEN_TICK] = h_gen_tick
    return table


def dispatch_delta(table, world: World, counters, e: Ev):
    """Dispatch one event to its kind's row kernel (lax.switch over kind).

    Returns ``(delta, counters, emits)`` without applying the delta — the
    building block shared by the sequential wrapper and the batched dispatcher.
    """
    kind = jnp.clip(e.kind, 0, len(table) - 1)
    return jax.lax.switch(kind, table, world, counters, e)


def apply_handler(table, world: World, counters, e: Ev):
    """Dispatch one event and apply its delta (the sequential contract).

    Byte-identical to the pre-delta in-place handlers: a row kernel computes its
    new row from the same gathered values the old whole-world handler read, and
    writing the full row stores unchanged elements back with their old bytes.
    Used by the sequential oracle, the engine's scan path, and the conflict
    fallback.
    """
    delta, counters, out = dispatch_delta(table, world, counters, e)
    return apply_delta(world, delta), counters, out


def _dispatch_lanes(table, world: World, rows: ev.EventBatch):
    """vmap the row kernels over a window's candidate rows (no apply)."""
    def lane(row):
        e = Ev(time=row.time, seq=row.seq, kind=row.kind, src=row.src,
               dst=row.dst, ctx=row.ctx, payload=row.payload)
        return dispatch_delta(table, world, mon.zero_counters(), e)
    return jax.vmap(lane)(rows)


def _mask_lanes(lanes_delta: WorldDelta, active: jax.Array) -> WorldDelta:
    """OOB the row declarations of inactive lanes so their scatters drop."""
    return lanes_delta._replace(**{
        rf: jnp.where(active, getattr(lanes_delta, rf), NO_ROW)
        for rf in ROW_FIELDS})


def _count_rows(masked: WorldDelta) -> jax.Array:
    """Component-table rows this window's batched phase will scatter."""
    counts = [jnp.sum((getattr(masked, rf) != NO_ROW).astype(jnp.int32))
              for rf in ROW_FIELDS]
    return sum(counts[1:], counts[0])


def _finalize_batch(world: World, rows: ev.EventBatch, active: jax.Array,
                    lanes_counters, lanes_out: ev.EventBatch, n_rows):
    """Shared batched-dispatch tail: counters, per-LP columns, emit masking.

    Counters are write-only int adds, so summing the active lanes' deltas
    equals bumping them one by one in window order. The per-LP LVT/lifecycle
    columns commute even across duplicate-dst lanes (max is commutative; the
    RUNNING mark is an idempotent constant set), so two direct segment
    scatters are exact.
    """
    cdelta = jnp.sum(jnp.where(active[:, None], lanes_counters, 0), axis=0)
    cdelta = cdelta.at[mon.C_BATCH_ROWS].add(n_rows)
    dst = jnp.where(active, rows.dst, world.lp_lvt.shape[0])  # OOB -> drop
    world = world._replace(
        lp_lvt=world.lp_lvt.at[dst].max(rows.time, mode="drop"),
        lp_state=world.lp_state.at[dst].set(2, mode="drop"),  # RUNNING
    )
    out_valid = lanes_out.valid & active[:, None]
    return world, cdelta, lanes_out._replace(valid=out_valid)


def apply_handler_batch(table, world: World, rows: ev.EventBatch,
                        active: jax.Array):
    """Dispatch a window's candidate rows through one vectorized handler call
    and merge the results with per-row segment scatters (the delta path).

    Batch-safety contract: the caller guarantees ``active`` rows declare
    pairwise-distinct component rows (sync.conflict_mask keys on the exact
    ``(KIND_TABLE[kind], lp_res[dst])`` row of the delta contract), so every
    scattered row is written by at most one lane and ``apply_delta``'s
    ``.at[rows].set`` merge is exact — no arithmetic on state values, hence
    byte-identical to folding the same rows sequentially in any order. Cost is
    O(lanes x row) per mutable field, independent of component-table width or
    count — the point of the delta rewrite.

    Returns ``(world', counter_delta, emits)`` with emits shaped (B, MAX_EMIT)
    per field, lane-aligned with ``rows`` and masked by ``active``. The
    counter delta includes C_BATCH_ROWS (rows scattered this window).
    """
    lanes_delta, lanes_counters, lanes_out = _dispatch_lanes(table, world, rows)
    masked = _mask_lanes(lanes_delta, active)
    n_rows = _count_rows(masked)
    world = apply_delta(world, masked)
    return _finalize_batch(world, rows, active, lanes_counters, lanes_out,
                           n_rows)


def apply_handler_batch_dense(table, world: World, rows: ev.EventBatch,
                              active: jax.Array):
    """PR 2 reference merge: per-lane whole tables + element-wise pick.

    Materializes each lane's delta into a full copy of every mutable table and
    merges element-wise ("take the one lane that changed it") — the
    O(lanes x pool-wide tables) strategy the delta path replaces. Kept as the
    ``spec.merge_mode="dense"`` engine option so equivalence tests can pin
    delta == dense == sequential and the wide-component benchmark can measure
    the delta win as a machine-normalized in-process ratio.
    """
    lanes_delta, lanes_counters, lanes_out = _dispatch_lanes(table, world, rows)
    masked = _mask_lanes(lanes_delta, active)
    n_rows = _count_rows(masked)
    n_lanes = rows.time.shape[0]

    def lane_tables(d):
        w2 = apply_delta(world, d)
        return {f: getattr(w2, f) for f in MUTABLE_FIELDS}

    lanes_mut = jax.vmap(lane_tables)(masked)

    def merge(lane_field, base):
        m = active.reshape((n_lanes,) + (1,) * base.ndim)
        changed = m & (lane_field != base[None])
        pick = jnp.argmax(changed, axis=0)
        picked = jnp.take_along_axis(lane_field, pick[None], axis=0)[0]
        return jnp.where(jnp.any(changed, axis=0), picked, base)

    world = world._replace(**{
        f: merge(lanes_mut[f], getattr(world, f)) for f in MUTABLE_FIELDS})
    return _finalize_batch(world, rows, active, lanes_counters, lanes_out,
                           n_rows)
