"""Workload bridge: simulate a multi-pod training job with the paper's DES.

This is the 2026 rendering of the paper's thesis — "it is important to simulate
Grid resources as realistically as possible before they are used on real Grids"
— applied to TPU fleets: an (arch x shape x mesh) cell's dry-run roofline terms
parameterize a DES scenario whose components are pods (compute farms), ICI/DCN
fabrics (network regions with the interrupt-based traffic model) and the
training step dependency chain (compute -> gradient reduction -> next step).

Scenario per pod p:
  farm_p: one CPU unit per host-group, power calibrated so a per-step compute
          job lasts t_compute ticks
  gen:    emits step-0 JOB_SUBMITs; each JOB_END fires the cross-pod gradient
          FLOW_START on the DCN region; flow completion submits the next step's
          job — so congestion, stragglers (slow farm) and bandwidth contention
          show up as longer simulated step times, exactly the effects the
          scheduler (C3) is meant to absorb.

Payloads are packed through the registry's named ``PayloadSpec`` views
(``JOB_SUBMIT.pack(work=..., ...)``) instead of positional index lists — the
field names and defaults live with the kind declarations in ``components.py``.

``simulate_training`` returns the simulated seconds/step to compare against the
analytic roofline estimate (EXPERIMENTS.md §Dry-run cross-check).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core import monitoring as mon
from repro.core.components import (FLOW_START, JOB_SUBMIT, K_FLOW_START,
                                   K_JOB_SUBMIT, ScenarioBuilder)
from repro.core.engine import Engine

TICK = 1e-6            # 1 tick = 1 us simulated


@dataclasses.dataclass(frozen=True)
class CellModel:
    """Distilled cell description (from roofline terms)."""
    n_pods: int
    t_compute_s: float        # per-step per-chip compute+memory time
    dcn_bytes_per_pod: float  # cross-pod gradient traffic per step
    dcn_gbps: float = 25.0    # per-pod DCN bandwidth (GB/s)
    n_steps: int = 8
    slow_pod_factor: float = 1.0   # >1: one pod is a straggler


def simulate_training(cell: CellModel, *, n_agents: int = 1,
                      max_windows: int = 200_000) -> dict:
    """Chained step simulation; returns simulated step time + counters."""
    b = ScenarioBuilder(max_cpu=4, queue_cap=16, max_link=4,
                        max_flow=max(16, 2 * cell.n_pods))
    t_comp_ticks = max(int(cell.t_compute_s / TICK), 10)
    mb_per_tick = cell.dcn_gbps * 1e3 * TICK
    grad_mb = max(cell.dcn_bytes_per_pod / 1e6, 1e-3)

    farms = [b.add_farm([1.0]) for _ in range(cell.n_pods)]
    wan = b.add_net_region(link_bws=[mb_per_tick] * cell.n_pods,
                           link_lats=[50] * cell.n_pods)

    # per pod: the step-0 compute job; its completion notifies the WAN region
    # (size-only forward — see below). Named packing replaces the old
    # positional [work, mem, notify_lp, notify_kind, size] list.
    for p, f in enumerate(farms):
        work = t_comp_ticks * (cell.slow_pod_factor if p == 0 else 1.0)
        b.add_event(time=1, kind=K_JOB_SUBMIT, src=f, dst=f,
                    payload=JOB_SUBMIT.pack(work=work, mem=1.0, notify_lp=wan,
                                            notify_kind=K_FLOW_START,
                                            size=grad_mb))
    # NOTE: JOB_END forwards [size] only into the notification payload — the
    # WAN handler needs the full route/notify payload, so generators per pod
    # drive the repeating steps instead of a deep notify chain:
    horizon = int(cell.n_steps * (t_comp_ticks * cell.slow_pod_factor
                                  + grad_mb / mb_per_tick + 200) * 2)
    for p, f in enumerate(farms):
        work = t_comp_ticks * (cell.slow_pod_factor if p == 0 else 1.0)
        step_ticks = int(work + grad_mb / mb_per_tick + 120)
        b.add_generator(target_lp=wan, kind=K_FLOW_START,
                        payload=FLOW_START.pack(size=grad_mb, l0=p,
                                                notify_lp=f,
                                                notify_kind=K_JOB_SUBMIT),
                        interval=step_ticks, count=cell.n_steps,
                        start=int(work))

    world, own, init_ev, spec = b.build(
        n_agents=n_agents, lookahead=10, t_end=max(horizon, 1000),
        pool_cap=1024, work_per_mb=t_comp_ticks / grad_mb)
    eng = Engine(world, own, init_ev, spec)
    st = eng.run_local(max_windows=max_windows)
    c = np.asarray(st.counters).sum(axis=0)
    w = jax.tree.map(lambda x: np.asarray(x[0]), st.world)
    t_end_sim = int(np.max(w.lp_lvt))
    steps_done = int(c[mon.C_FLOWS_DONE]) / max(cell.n_pods, 1)
    sim_step_s = (t_end_sim * TICK / max(steps_done, 1e-9))
    analytic_s = cell.t_compute_s + cell.dcn_bytes_per_pod / (
        cell.dcn_gbps * 1e9)
    return {
        "simulated_step_s": sim_step_s,
        "analytic_step_s": analytic_s,
        "steps_done": steps_done,
        "events": int(c[mon.C_EVENTS]),
        "interrupts": int(c[mon.C_INTERRUPTS]),
        "stale": int(c[mon.C_STALE]),
        "windows": int(np.asarray(st.windows)[0]),
    }


def cell_from_roofline(row: dict, *, n_pods: int = 2, n_steps: int = 8,
                       slow_pod_factor: float = 1.0) -> CellModel:
    """Build a CellModel from a dry-run roofline row (results/dryrun/*.json)."""
    t_cm = max(row["t_compute_s"], row["t_memory_s"])
    # cross-pod traffic ~ the all-reduce share of collective bytes
    dcn = row.get("coll_by_kind", {}).get("all-reduce", 0.0)
    return CellModel(n_pods=n_pods, t_compute_s=t_cm,
                     dcn_bytes_per_pod=dcn, n_steps=n_steps,
                     slow_pod_factor=slow_pod_factor)
