"""The simulation engine (paper fig 4): conservative-window superstep + run loop.

Per window (one "simulation step" in the paper's event-scheduler terms):

  1. GVT: per-context local min pending timestamp -> collective min (sync.py, C2).
  2. Safe mask: events strictly below the per-context horizon may execute.
  3. Order + compact: stable (time, seq) sort with unsafe slots keyed T_INF — on
     TPU the ``event_select`` Pallas kernel, on CPU the XLA lexsort reference
     (identical prefixes) — keeping only the first ``spec.exec_cap`` gather
     indices (the earliest safe slots).
  4. Execute (grouped vectorized dispatch, the default): the ``exec_cap``
     gathered slots are partitioned by ``kind`` (``group_by_kind``) and checked
     for write conflicts (``sync.conflict_mask``: two events declaring the same
     component row — the exact ``(KIND_TABLE[kind], lp_res[dst])`` row of the
     handlers' delta contract). Conflict-free slots — by construction touching
     pairwise-disjoint world state — execute in ONE vmapped handler call that
     returns per-row ``WorldDelta``s, merged with one O(lanes x row) segment
     scatter per mutable field (``handlers.apply_handler_batch``;
     ``spec.merge_mode="dense"`` selects the PR 2 whole-table reference merge
     instead, kept for equivalence tests and benchmarks). The few
     conflicted slots fall back to a sequential fold compacted to just those
     slots (a while_loop that runs zero iterations on clean windows). Each
     slot's emits land in a per-slot row of an (exec_cap, MAX_EMIT) matrix, so
     flattening it row-major reproduces the sequential fold's emit-append order
     byte-for-byte (``events.compact_batch``), and the trace is written in
     (time, seq) window order independently of execution order — the batched
     path is byte-identical to the sequential fold (and hence to the oracle) in
     traces, counters, and world state. ``spec.batched_dispatch=False``
     restores the PR 1 sequential lax.scan over all exec_cap slots. Safe
     events beyond ``exec_cap`` *spill* either way: they stay in the pool and
     execute in a later window (counted by C_EXEC_SPILL). Spilling preserves
     exactness — the horizon/GVT math is untouched, spilled events remain
     below the horizon, and emits of later windows carry timestamps >=
     horizon > any spilled timestamp, so the per-agent execution order (and
     hence the oracle-merged trace) is unchanged; only the window count grows.
     Caveat: a compacted window frees at most exec_cap pool slots before
     insert, so a near-saturated pool has less headroom for the window's
     emits than a full-pool scan would leave — as everywhere in this engine,
     any resulting overflow is counted (C_DROP_POOL), never silent, and results
     are exact iff the drop counters stay zero. Size pool_cap with that
     headroom (or raise exec_cap) for emit-heavy dense scenarios.
  5. Route: emits are bucketed by destination agent (``lp_agent``) and exchanged with
     one ``all_to_all`` (the Jini remote-event adaptation); overflow is counted.
  6. Insert: received events enter pool free slots. The pool's free-list ring
     (events.py, PR 5) makes this an O(n_insert) ring pop and the
     post-execution reclaim an O(exec_cap) ``events.release`` scatter —
     ``spec.insert_mode="ref"`` restores the PR 1-4 O(pool_cap) rank-scan
     insert + pool-wide pop mask, byte-identical in everything but slot
     layout and the C_RING_WRAP diagnostic.
  7. Sync world: owner-wins all-reduce of replicated component state (C4),
     then the pool occupancy/headroom gauges (C_POOL_OCC / C_POOL_FREE).

The per-window execution width is ``spec.exec_policy``: a static int (the
historical ``exec_cap``) under ``run_local`` / ``run_distributed``, or a
``policy.ExecPolicy`` ladder driven by the per-window monitoring vector under
``run_adaptive`` — one jitted window program per rung, cached, so adaptation
never recompiles (docs/architecture.md, "Pool lifecycle").

The same per-agent program runs under ``jax.vmap(axis_name='agents')`` (LocalComm:
tests, benchmarks, single host) and under ``shard_map`` over a device mesh
(CollectiveComm: production) — collectives are axis-name-polymorphic, so the two
drivers are semantically identical by construction. The distributed driver
composes both: ``run_distributed`` packs ``K = ceil(n_agents / n_devices)``
agents per device (``shard_map`` over the mesh axis x ``vmap`` over an
in-shard lane axis — a :class:`ShardAxes` pair), so agent count is decoupled
from device count (thousands of LPs on a 4-8 device mesh). Collectives then
reduce over the (shard, lane) *tuple* — one fleet-global GVT/psum — and the
routing all_to_all runs in two stages (shards, then lanes) whose flattened
receive order equals the flat single-axis exchange's, keeping the distributed
results byte-identical to ``run_local`` down to pool slot layouts.
``run_distributed_adaptive`` is the per-shard analog of ``run_adaptive``:
per-shard monitoring -> per-shard rung decision -> max-reduce so every shard
stays in lockstep on one jit-cached window program.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import io_callback
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import events as ev
from repro.core import monitoring as mon
from repro.core import policy as pol
from repro.core import sync
from repro.core.components import ScenarioSpec, World, WorldOwnership
from repro.core.handlers import (Ev, apply_handler, apply_handler_batch,
                                 apply_handler_batch_dense)
from repro.core.registry import registry_of
# the fused front-end's result container only — kernels.event_select imports
# nothing from repro.core, so this cannot cycle
from repro.kernels.event_select import FusedSelect

AXIS = "agents"

# jax >= 0.6 exposes shard_map at top level with check_vma; older releases keep
# it in jax.experimental with the check_rep spelling.
if hasattr(jax, "shard_map"):
    _shard_map = functools.partial(jax.shard_map, check_vma=False)
else:  # pragma: no cover - exercised only on older jax
    from jax.experimental.shard_map import shard_map as _sm
    _shard_map = functools.partial(_sm, check_rep=False)


class ShardAxes(NamedTuple):
    """The shard_map x vmap agent packing of ``run_distributed``.

    ``shard`` names the 1-D mesh axis (``n_shards`` devices); ``lane`` names
    the vmap axis inside each shard (``n_lanes`` agents packed per device).
    The stacked state is laid out shard-major, so the global agent id is
    ``lax.axis_index((shard, lane)) == shard_idx * n_lanes + lane_idx`` —
    exactly the row index of the agent in the (A, ...) state. Collectives
    that accept axis-name tuples (pmin/psum/axis_index) reduce over both
    axes directly; all_gather/all_to_all do not, and are staged per axis
    (monitoring.gather_counters, engine._route_and_insert)."""

    shard: str
    lane: str
    n_shards: int
    n_lanes: int

    @property
    def names(self) -> tuple[str, str]:
        return (self.shard, self.lane)

    @property
    def size(self) -> int:
        return self.n_shards * self.n_lanes


def axis_names(axis: "str | ShardAxes | None"):
    """The collective axis-name argument for an engine axis spec."""
    return axis.names if isinstance(axis, ShardAxes) else axis


def lexsort_time_seq(time_key: jax.Array, seq: jax.Array) -> jax.Array:
    """Stable (time, seq) sort permutation — the XLA reference for event_select."""
    perm = jnp.argsort(seq, stable=True)
    perm2 = jnp.argsort(time_key[perm], stable=True)
    return perm[perm2]


def select_events_xla(time_key: jax.Array, seq: jax.Array,
                      exec_cap: int) -> jax.Array:
    """Compacted gather indices (sort + safe-prefix) — XLA default select_fn."""
    return lexsort_time_seq(time_key, seq)[:exec_cap]


def route_rank_xla(dst_agent: jax.Array) -> jax.Array:
    """Stable within-bucket routing ranks — the XLA default route_fn.

    ``rank[i]`` counts earlier rows with the same destination bucket, so the
    emit-routing pack scatters row i to flat slot ``dst * route_cap + rank``:
    sort by bucket, rank within group, scatter back to input order. The
    Pallas predecessor-count kernel (kernels.ops.route_rank) is the hookable
    alternative; kernels.ref.route_rank_ref mirrors this exactly.
    """
    sperm = jnp.argsort(dst_agent, stable=True)
    skey = dst_agent[sperm]
    group_start = jnp.searchsorted(skey, skey, side="left")
    rank_sorted = jnp.arange(skey.shape[0], dtype=jnp.int32) - group_start
    return jnp.zeros_like(rank_sorted).at[sperm].set(rank_sorted)


def group_by_kind_xla(kind: jax.Array, active: jax.Array,
                      n_kinds: int = ev.N_KINDS):
    """Same-kind grouping — the XLA reference for kernels.ops.group_by_kind.

    Returns ``(order, rank, counts)``: ``order`` is the stable permutation
    putting active rows first, grouped by ascending kind and original position
    within a kind (inactive rows trail in original order); ``rank`` is aligned
    with ``order`` and gives each grouped row's index within its segment;
    ``counts`` is the (n_kinds,) active-row population per kind.
    """
    key = jnp.where(active, jnp.clip(kind, 0, n_kinds - 1), n_kinds)
    order = jnp.argsort(key, stable=True).astype(jnp.int32)
    ks = key[order]
    start = jnp.searchsorted(ks, ks, side="left").astype(jnp.int32)
    rank = jnp.arange(ks.shape[0], dtype=jnp.int32) - start
    counts = jnp.zeros((n_kinds,), jnp.int32).at[key].add(1, mode="drop")
    return order, rank, counts


def fused_select_xla(time_key, seq, safe, time, kind, src, dst, ctx, payload,
                     valid, table_id, res, free_tail, exec_cap, *,
                     n_kinds: int, n_res: int, n_tables: int) -> FusedSelect:
    """XLA-stitched twin of the fused window front-end.

    The exact composition the non-fused superstep runs — select
    (``select_events_xla``), exec mask (``sync.exec_selection_ring``), field
    gathers, conflict mask (``sync.conflict_mask``), group
    (``group_by_kind_xla``), and the free-ring release ranks of
    ``events.release`` — packaged behind the same signature as the Pallas
    megakernel (``kernels.ops.fused_select``), so the two are drop-in
    interchangeable ``fused_fn`` hooks and every output must match
    byte-for-byte. Retained as the reference path for tests and the
    ``fused_superstep`` benchmark.
    """
    cap = time_key.shape[0]
    m = max(min(exec_cap, cap), 1)
    exec_idx = select_events_xla(time_key, seq, m)
    exec_safe = sync.exec_selection_ring(safe, exec_idx)
    dirty = sync.conflict_mask(exec_safe, table_id[exec_idx], res[exec_idx],
                               n_res=n_res, n_tables=n_tables)
    clean = exec_safe & ~dirty
    kind_w = kind[exec_idx]
    order, _rank, _counts = group_by_kind_xla(kind_w, clean, n_kinds=n_kinds)
    w = exec_safe.astype(jnp.int32)
    rel = (jnp.asarray(free_tail, jnp.int32) + jnp.cumsum(w) - w) % jnp.int32(
        cap)
    return FusedSelect(
        exec_idx=exec_idx, exec_safe=exec_safe, time=time[exec_idx],
        seq=seq[exec_idx], kind=kind_w, src=src[exec_idx],
        dst=dst[exec_idx], ctx=ctx[exec_idx], payload=payload[exec_idx],
        valid=valid[exec_idx], clean=clean, order=order, rel_pos=rel)


class EngineState(NamedTuple):
    world: World
    pool: ev.EventPool
    counters: jax.Array   # i32 (N_COUNTERS,)
    t_now: jax.Array      # i32 scalar — agent LVT (== last horizon)
    done: jax.Array       # bool scalar (globally uniform)
    windows: jax.Array    # i32 scalar
    trace: jax.Array      # i32 (trace_cap, 4): processed (time, seq, kind, dst)
    trace_n: jax.Array    # i32 scalar — total rows ever written
    trace_tail: jax.Array  # i32 scalar — rows already drained to host
    #                       (streaming mode: the buffer is a ring holding
    #                       positions [trace_tail, trace_n) at index % cap;
    #                       bounded mode keeps it 0)


class Engine:
    """Binds a built scenario to the superstep program."""

    def __init__(self, world: World, own: WorldOwnership,
                 init_events: ev.EventBatch, spec: ScenarioSpec,
                 trace_cap: int = 0,
                 select_fn: Callable[[jax.Array, jax.Array, int], jax.Array]
                 | None = None,
                 group_fn: Callable[[jax.Array, jax.Array], tuple]
                 | None = None,
                 route_fn: Callable[[jax.Array], jax.Array] | None = None,
                 trace_fn: Callable[[jax.Array], jax.Array] | None = None,
                 fused_fn: Callable[..., FusedSelect] | None = None,
                 slot_fn: Callable[[jax.Array, jax.Array, jax.Array],
                                   jax.Array] | None = None,
                 trace_stream: "mon.TraceStream | None" = None,
                 metrics_stream: "mon.MetricsStream | None" = None,
                 drain_every: int = 16,
                 checkpointer=None,
                 window_hook: Callable[[int, EngineState], None]
                 | None = None):
        self.world = world
        self.own = own
        self.init_events = init_events
        self.spec = spec
        self.trace_cap = trace_cap
        # host-streaming observability (docs/architecture.md, "Streaming
        # trace"): with a TraceStream attached, trace_cap sizes a device-side
        # *ring* drained to the host through an unordered io_callback at
        # window boundaries (every `drain_every` windows, plus forced drains
        # whenever the next window could overrun the ring), so runs of any
        # length keep C_TRACE_DROP == 0 and the streamed trace byte-identical
        # to the sequential oracle. A MetricsStream ships every window's
        # counter vector the same way (periodic JSON-lines snapshots). Either
        # stream switches run_local/run_distributed to a host-stepped window
        # loop — io_callback is unsupported inside a vmapped while_loop — the
        # same driver shape run_adaptive always uses.
        self.trace_stream = trace_stream
        self.metrics_stream = metrics_stream
        # durable checkpoint/resume (docs/architecture.md, "Checkpoint /
        # resume"): a repro.checkpoint.SimCheckpointer saves the full
        # unpadded EngineState (pool ring + cursors, world tables incl. LCG
        # fields, counters, trace ring + trace_tail) every
        # `checkpointer.every` windows. The window boundary is the GVT sync
        # point, so the snapshot is globally consistent by construction; a
        # restored state re-enters any of the four drivers via their
        # ``state=`` (and ``rung=``) arguments — on a different device
        # count, since the distributed drivers re-pad for whatever mesh
        # they get. Like streaming, an attached checkpointer switches the
        # static drivers to the host-stepped window loop.
        self.checkpointer = checkpointer
        # host observation point for the fleet orchestrator
        # (repro.fleet.Orchestrator): called as ``window_hook(window, state)``
        # after every host-stepped window, *after* any due checkpoint save —
        # so an exception raised here (e.g. an injected shard-loss probe)
        # always leaves the latest due checkpoint committed. Only the
        # host-stepped drivers fire it (run_adaptive and, with a stream or
        # checkpointer attached, run_local/run_distributed); the fused
        # while_loop drivers have no host window boundary to hook.
        self.window_hook = window_hook
        self.drain_every = int(drain_every)
        if self.drain_every < 1:
            raise ValueError(f"drain_every must be >= 1, got {drain_every}")
        if trace_stream is not None and trace_cap <= 0:
            raise ValueError(
                "a TraceStream needs a device-side ring: pass trace_cap > 0")
        # the registry that generated this world's model: the source of the
        # dispatch table, the kind->table map, and the sync/delta schemas —
        # extended models (BUILTIN.extend()) plug in with zero engine edits
        self.registry = registry_of(world)
        # select_fn(time_key, seq, exec_cap) -> (exec_cap,) distinct pool-slot
        # indices: the prefix of the stable (time, seq) sort. Hook point for the
        # Pallas kernel (kernels.ops.select_events); default is the XLA lexsort.
        self.select_fn = select_fn or select_events_xla
        # group_fn(kind, active) -> (order, rank, counts): same-kind grouping
        # for the batched dispatch. Hook point for the Pallas segment-rank
        # kernel (kernels.ops.group_by_kind); default is the XLA argsort.
        self.group_fn = group_fn or functools.partial(
            group_by_kind_xla, n_kinds=self.registry.n_kinds)
        # route_fn(dst_agent) -> stable within-bucket ranks: the emit-routing
        # pack for the all_to_all exchange (and the migration re-home). Hook
        # point for the Pallas predecessor-count kernel
        # (kernels.ops.route_rank); default is the XLA sort-based rank.
        self.route_fn = route_fn or route_rank_xla
        # trace_fn(mask) -> exclusive prefix ranks: the trace-append position
        # math (events.trace_append). Hook point for the Pallas prefix-sum
        # kernel (kernels.ops.trace_rank); default is the XLA cumsum inside
        # trace_append (None passes through).
        self.trace_fn = trace_fn
        if spec.merge_mode not in ("delta", "dense"):
            raise ValueError(
                f"spec.merge_mode must be 'delta' or 'dense', got "
                f"{spec.merge_mode!r}")
        if spec.insert_mode not in ("ring", "ref"):
            raise ValueError(
                f"spec.insert_mode must be 'ring' or 'ref', got "
                f"{spec.insert_mode!r}")
        self.table = self.registry.make_handlers(spec.lookahead,
                                                 spec.work_per_mb)
        # widest resource table: bound for the conflict-detection key space
        self._n_res = self.registry.max_rows(world)
        # fused front-end (spec.fused_select, default off): ONE call replaces
        # the select_fn/gather/conflict_mask/group_fn stitch — and the free
        # ring's insert math rides the same lane (slot_fn -> events.insert).
        # fused_fn(time_key, seq, safe, time, kind, src, dst, ctx, payload,
        # valid, table_id, res, free_tail, exec_cap) -> FusedSelect. Default
        # binding is the Pallas superstep megakernel (kernels.ops.fused_select
        # — compiled on TPU, interpreted elsewhere); fused_select_xla above is
        # the stitched twin, drop-in for tests and benchmarks. Only consulted
        # when the spec flag is on.
        if not isinstance(spec.fused_select, bool):
            raise ValueError(
                f"spec.fused_select must be a bool, got {spec.fused_select!r}")
        self.fused_fn = fused_fn
        self.slot_fn = slot_fn
        if spec.fused_select and self.fused_fn is None:
            from repro.kernels import ops as _ops
            self.fused_fn = functools.partial(
                _ops.fused_select, n_kinds=self.registry.n_kinds,
                n_res=self._n_res, n_tables=self.registry.n_tables)
            if self.slot_fn is None:
                self.slot_fn = _ops.ring_slots
        # jitted-driver cache: run_local/step_local build a fresh closure per
        # call, which would otherwise defeat jax.jit's function-identity cache
        # and recompile the whole superstep on every invocation
        self._jit_cache: dict = {}

    # ------------------------------------------------------------------ init
    def init_state(self) -> EngineState:
        """Stacked (A, ...) initial state; initial events homed to owner agents."""
        A = self.spec.n_agents
        cap = self.spec.pool_cap
        pools = []
        drops = []
        lp_agent = self.world.lp_agent
        # the seed insert also seeds the free ring: an empty pool's ring is
        # the identity permutation, so the ring fast path assigns the same
        # ascending slots as the reference scan here
        ins = ev.insert if self.spec.insert_mode == "ring" else ev.insert_ref
        for a in range(A):
            mine = self.init_events.valid & (lp_agent[self.init_events.dst] == a)
            batch = self.init_events._replace(valid=mine)
            pool, dropped = ins(ev.empty_pool(cap), batch)
            pools.append(pool)
            drops.append(dropped)
        pool = jax.tree.map(lambda *xs: jnp.stack(xs), *pools)
        rep = lambda x: jnp.broadcast_to(x, (A,) + x.shape)
        world = jax.tree.map(rep, self.world)
        tc = max(self.trace_cap, 1)
        # oversubscribed seeds (init events beyond pool_cap) are visible, not
        # silent: the per-agent insert drop count lands in C_DROP_POOL.
        # Counter width comes from the registry: declared extension counters
        # ride in the same per-agent vector as the builtins.
        counters = jnp.zeros((A, self.registry.n_counters), jnp.int32).at[
            :, mon.C_DROP_POOL].set(jnp.stack(drops))
        return EngineState(
            world=world,
            pool=pool,
            counters=counters,
            t_now=jnp.zeros((A,), jnp.int32),
            done=jnp.zeros((A,), bool),
            windows=jnp.zeros((A,), jnp.int32),
            trace=jnp.zeros((A, tc, 4), jnp.int32),
            trace_n=jnp.zeros((A,), jnp.int32),
            trace_tail=jnp.zeros((A,), jnp.int32),
        )

    # ------------------------------------------------------------- superstep
    def _superstep(self, st: EngineState, axis: "str | ShardAxes | None",
                   exec_cap: int | None = None,
                   stream: bool = False) -> EngineState:
        """One conservative window. ``exec_cap`` overrides the spec's static
        width — the adaptive driver (``run_adaptive``) traces one program per
        ladder rung through this hook. ``axis`` is the vmap axis name, a
        :class:`ShardAxes` pair under the shard_map x vmap driver, or None
        for a single agent. ``stream`` (static) bakes the host-streaming
        hooks into the program: the window-boundary trace-ring drain and the
        metrics snapshot io_callbacks — only the host-stepped window drivers
        may set it (io_callback cannot live inside a vmapped while_loop)."""
        spec = self.spec
        world, pool, counters = st.world, st.pool, st.counters
        xcap = max(min(exec_cap if exec_cap is not None else spec.exec_cap,
                       spec.pool_cap), 1)
        stream_trace = stream and self.trace_stream is not None
        stream_metrics = stream and self.metrics_stream is not None
        if stream_trace or stream_metrics:
            # the global agent id tags every callback payload: under vmap it
            # is the lane, under shard_map x vmap the shard-major state row —
            # so host-side reassembly is driver-independent (and pad agents,
            # whose spans are always empty, are simply ignored)
            me = (jax.lax.axis_index(axis_names(axis)) if axis is not None
                  else jnp.int32(0))
        if stream_trace:
            # window-boundary drain (before this window's writes): ship the
            # un-drained span [trace_tail, trace_n) when the cadence hits or
            # when this window's worst case (xcap rows) could overrun the
            # ring. The callback fires every window — a vmapped cond would
            # run both branches anyway — but a masked count of 0 makes the
            # non-drain windows host-side no-ops; the span tag (me, start)
            # keeps delivery order-independent and duplicates idempotent.
            # Post-drain invariant: trace_n - trace_tail + xcap <= trace_cap,
            # so the ring never overwrites an un-drained row (C_TRACE_DROP
            # stays 0) as long as the ring holds one window (checked by the
            # streaming drivers).
            tcap = st.trace.shape[0]
            pending = st.trace_n - st.trace_tail
            do = ((pending + jnp.int32(xcap) > tcap)
                  | (st.windows % jnp.int32(self.drain_every) == 0))
            io_callback(self._on_trace_drain, None, me, st.trace_tail,
                        jnp.where(do, pending, 0), st.trace, ordered=False)
            st = st._replace(trace_tail=jnp.where(do, st.trace_n,
                                                  st.trace_tail))

        # 1-2. GVT + safe mask (C2)
        lmin = sync.local_min_per_ctx(pool, spec.n_ctx)
        gvt = sync.global_min(lmin, axis_names(axis))
        horizon = sync.horizons(gvt, spec.lookahead, spec.t_end)
        done = sync.all_done(gvt, spec.t_end)
        safe = sync.safe_mask(pool, horizon)

        # 3. order (time, seq) + compact: unsafe slots sort to the back, and only
        # the first exec_cap gather indices (the earliest safe slots) are kept
        time_key = jnp.where(safe, pool.time, ev.T_INF)
        if spec.fused_select:
            # fused front-end: select + gather + conflict + group + release
            # ranks in ONE fused_fn call (the Pallas megakernel by default).
            # The conflict key columns are precomputed pool-wide — two cheap
            # registry gathers; clip-then-gather commutes with the gather the
            # stitched path does per window, so the bytes match exactly.
            tbl_pool = jnp.asarray(self.registry.kind_table, jnp.int32)[
                jnp.clip(pool.kind, 0, self.registry.n_kinds - 1)]
            res_pool = world.lp_res[jnp.clip(pool.dst, 0, spec.n_lp - 1)]
            fs = self.fused_fn(time_key, pool.seq, safe, pool.time, pool.kind,
                               pool.src, pool.dst, pool.ctx, pool.payload,
                               pool.valid, tbl_pool, res_pool, pool.free_tail,
                               xcap)
            exec_idx, exec_safe = fs.exec_idx, fs.exec_safe
            cand = ev.EventBatch(time=fs.time, seq=fs.seq, kind=fs.kind,
                                 src=fs.src, dst=fs.dst, ctx=fs.ctx,
                                 payload=fs.payload, valid=fs.valid)
            pre = (fs.clean, fs.order)
            rel_pos = fs.rel_pos
        else:
            exec_idx = self.select_fn(time_key, pool.seq, xcap)
            exec_safe = sync.exec_selection_ring(safe, exec_idx)
            cand = ev.gather(pool, exec_idx)
            pre = None
            rel_pos = None

        # 4. execute the window: grouped vectorized dispatch (default) or the
        # sequential fold — byte-identical results either way; safe events
        # beyond exec_cap spill to the next window
        execute = (self._execute_batched if spec.batched_dispatch
                   else self._execute_scan)
        world, counters, emits, trace, trace_n = execute(
            world, counters, cand, exec_safe, st.trace, st.trace_n,
            ring=stream_trace, pre=pre)
        if stream_trace:
            # ring overwrite accounting: rows written this window on top of
            # un-drained ones (structurally 0 under the drain invariant above;
            # exact when a caller bypasses the ring-size check)
            pb = st.trace_n - st.trace_tail
            pa = trace_n - st.trace_tail
            tcap = st.trace.shape[0]
            counters = mon.bump(
                counters, mon.C_TRACE_DROP,
                jnp.maximum(pa - tcap, 0) - jnp.maximum(pb - tcap, 0))

        n_processed = jnp.sum(exec_safe.astype(jnp.int32))
        n_spill = jnp.sum(safe.astype(jnp.int32)) - n_processed
        counters = mon.bump(counters, mon.C_EVENTS, n_processed)
        counters = mon.bump(counters, mon.C_EXEC_SPILL, n_spill)
        counters = mon.bump(counters, mon.C_WINDOWS, 1)
        # slot reclaim: ring mode pushes the executed slots onto the free
        # ring's tail (O(exec_cap)); ref mode keeps the pool-wide pop mask
        if spec.insert_mode == "ring":
            counters = mon.bump(
                counters, mon.C_RING_WRAP,
                pool.free_tail + n_processed >= jnp.int32(spec.pool_cap))
            pool = ev.release(pool, exec_idx, exec_safe, pos=rel_pos)
        else:
            slot_mask, _ = sync.exec_selection(safe, exec_idx)
            pool = ev.pop_mask_ref(pool, slot_mask)

        # processed LPs drop back to WAITING at window end (thread states -> data)
        world = world._replace(
            lp_state=jnp.where(world.lp_state == 2, 3, world.lp_state))

        # 5-6. route + insert
        pool, counters = self._route_and_insert(world, pool, counters, emits, axis)

        # 7. replicated-state sync (C4) — field lists generated by the registry
        world = self.registry.sync_world(world, self.own, axis_names(axis))

        # pool-lifecycle gauges: the occupancy/headroom signals the adaptive
        # exec policy reads (O(1) off the ring's free count in either mode)
        counters = mon.gauge(counters, mon.C_POOL_OCC, ev.occupancy(pool))
        counters = mon.gauge(counters, mon.C_POOL_FREE, pool.free_count)

        if stream_metrics:
            # end-of-window metrics snapshot: every agent ships its counter
            # vector; the host sink assembles a fleet view per window and
            # emits JSON lines on the configured cadence
            io_callback(self._on_metrics, None, me, st.windows + 1,
                        jnp.max(horizon), counters, ordered=False)

        return EngineState(world=world, pool=pool, counters=counters,
                           t_now=jnp.max(horizon), done=done,
                           windows=st.windows + 1, trace=trace,
                           trace_n=trace_n, trace_tail=st.trace_tail)

    # ------------------------------------------------- step 4: sequential fold
    def _execute_scan(self, world, counters, cand: ev.EventBatch,
                      exec_safe: jax.Array, trace, trace_n, ring: bool = False,
                      pre=None):
        """PR 1 path: lax.scan over the gathered slots in (time, seq) order.

        ``pre`` (the fused front-end's precomputed conflict/group pair) is
        accepted for signature parity with ``_execute_batched`` and ignored —
        the sequential fold needs neither."""
        del pre
        ecap = self.spec.emit_cap
        emit0 = ev.empty_batch(ecap)
        trace0, trace_n0 = trace, trace_n

        def body(carry, x):
            world, counters, emits, emit_n, trace, trace_n = carry
            row, is_safe = x
            e = Ev(time=row.time, seq=row.seq, kind=row.kind,
                   src=row.src, dst=row.dst, ctx=row.ctx,
                   payload=row.payload)

            def run(w, c):
                w2, c2, out = apply_handler(self.table, w, c, e)
                w2 = w2._replace(
                    lp_lvt=w2.lp_lvt.at[e.dst].max(e.time),
                    lp_state=w2.lp_state.at[e.dst].set(2),  # RUNNING
                )
                return w2, c2, out

            def skip(w, c):
                return w, c, ev.empty_batch(ev.MAX_EMIT)

            world, counters, out = jax.lax.cond(is_safe, run, skip, world, counters)

            # append emits to the window emit buffer (overflow counted)
            val = out.valid
            offs = jnp.cumsum(val.astype(jnp.int32)) - 1
            pos = emit_n + offs
            ok = val & (pos < ecap)
            widx = jnp.where(ok, pos, ecap)  # ecap == OOB -> dropped
            emits = ev.EventBatch(
                time=emits.time.at[widx].set(out.time, mode="drop"),
                seq=emits.seq.at[widx].set(out.seq, mode="drop"),
                kind=emits.kind.at[widx].set(out.kind, mode="drop"),
                src=emits.src.at[widx].set(out.src, mode="drop"),
                dst=emits.dst.at[widx].set(out.dst, mode="drop"),
                ctx=emits.ctx.at[widx].set(out.ctx, mode="drop"),
                payload=emits.payload.at[widx].set(out.payload, mode="drop"),
                valid=emits.valid.at[widx].set(ok, mode="drop"),
            )
            emit_n = emit_n + jnp.sum(val.astype(jnp.int32))
            counters = mon.bump(counters, mon.C_DROP_POOL,
                                jnp.sum((val & ~ok).astype(jnp.int32)))

            # trace (bounded buffer, or ring under the streaming drain).
            # Bounded overflow is counted (C_TRACE_DROP), never silent —
            # merged_engine_trace refuses to return a truncated trace; ring
            # overwrites are accounted at the window boundary (_superstep).
            tcap = trace.shape[0]
            trow = jnp.stack([e.time, e.seq, e.kind, e.dst])
            if ring:
                tidx = jnp.where(is_safe, trace_n % tcap, tcap)
                trace = trace.at[tidx].set(trow, mode="drop")
            else:
                tidx = jnp.where(is_safe & (trace_n < tcap), trace_n, tcap)
                trace = trace.at[tidx].set(trow, mode="drop")
                if self.trace_cap > 0:
                    counters = mon.bump(
                        counters, mon.C_TRACE_DROP,
                        jnp.where(is_safe & (trace_n >= tcap), 1, 0))
            trace_n = trace_n + jnp.where(is_safe, 1, 0)
            return (world, counters, emits, emit_n, trace, trace_n), None

        carry0 = (world, counters, emit0, jnp.int32(0), trace0, trace_n0)
        (world, counters, emits, _, trace, trace_n), _ = jax.lax.scan(
            body, carry0, (cand, exec_safe))
        return world, counters, emits, trace, trace_n

    # -------------------------------------------- step 4: vectorized dispatch
    def _execute_batched(self, world, counters, cand: ev.EventBatch,
                         exec_safe: jax.Array, trace, trace_n,
                         ring: bool = False, pre=None):
        """Grouped vectorized dispatch (see module docstring).

        Conflict-free slots run in one vmapped handler call per window; slots
        whose declared component rows collide fall back to a sequential fold
        compacted to just those slots. Emits land in a per-slot
        (exec_cap, MAX_EMIT) matrix and the trace is written in (time, seq)
        window order, so the results are byte-identical to ``_execute_scan``.
        """
        spec = self.spec
        xcap = cand.time.shape[0]

        if pre is None:
            # conflict detection on the delta contract's declared rows: two
            # safe slots collide iff they address the same (component table,
            # lp_res row)
            table_id = jnp.asarray(self.registry.kind_table, jnp.int32)[
                jnp.clip(cand.kind, 0, self.registry.n_kinds - 1)]
            res = world.lp_res[jnp.clip(cand.dst, 0, spec.n_lp - 1)]
            dirty = sync.conflict_mask(exec_safe, table_id, res,
                                       n_res=self._n_res,
                                       n_tables=self.registry.n_tables)
            clean = exec_safe & ~dirty

            # batched phase: group the clean rows by kind, dispatch once. The
            # grouped order keeps same-kind lanes contiguous (coherent
            # segments on wide-vector backends); the merge itself is
            # order-independent under the disjoint-write contract, and a
            # vmapped switch traces every handler per lane either way — on
            # CPU the permutation costs a few percent of the window and buys
            # layout, not fewer handler evals.
            order, _rank, _counts = self.group_fn(cand.kind, clean)
        else:
            # fused front-end (spec.fused_select): the megakernel already
            # computed the conflict mask and grouping in-VMEM; dirty is
            # recoverable because clean == exec_safe & ~dirty with
            # dirty ⊆ exec_safe
            clean, order = pre
            dirty = exec_safe & ~clean
        rows_g = jax.tree.map(lambda x: x[order], cand)
        clean_g = clean[order]
        batch_fn = (apply_handler_batch if spec.merge_mode == "delta"
                    else apply_handler_batch_dense)
        world, cdelta, emits_g = batch_fn(self.table, world, rows_g, clean_g)
        counters = counters + cdelta
        counters = mon.bump(counters, mon.C_BATCH_EXEC,
                            jnp.sum(clean.astype(jnp.int32)))

        # per-slot emit matrix in window order (grouped lanes scattered back)
        emit_mat = jax.tree.map(lambda x: jnp.zeros_like(x).at[order].set(x),
                                emits_g)

        # conflict fallback: sequential fold compacted to the dirty slots
        # (zero while_loop iterations on a conflict-free window)
        n_dirty = jnp.sum(dirty.astype(jnp.int32))
        counters = mon.bump(counters, mon.C_BATCH_FALLBACK, n_dirty)
        pos = jnp.arange(xcap, dtype=jnp.int32)
        dpos = jnp.sort(jnp.where(dirty, pos, xcap))

        def cond(carry):
            return carry[0] < n_dirty

        def body(carry):
            k, world, counters, emit_mat = carry
            p = dpos[jnp.minimum(k, xcap - 1)]
            row = jax.tree.map(lambda x: x[jnp.minimum(p, xcap - 1)], cand)
            e = Ev(time=row.time, seq=row.seq, kind=row.kind,
                   src=row.src, dst=row.dst, ctx=row.ctx,
                   payload=row.payload)
            active = k < n_dirty

            def run(w, c):
                w2, c2, out = apply_handler(self.table, w, c, e)
                w2 = w2._replace(
                    lp_lvt=w2.lp_lvt.at[e.dst].max(e.time),
                    lp_state=w2.lp_state.at[e.dst].set(2),  # RUNNING
                )
                return w2, c2, out

            def skip(w, c):
                return w, c, ev.empty_batch(ev.MAX_EMIT)

            world, counters, out = jax.lax.cond(active, run, skip,
                                                world, counters)
            emit_mat = ev.EventBatch(
                time=emit_mat.time.at[p].set(out.time, mode="drop"),
                seq=emit_mat.seq.at[p].set(out.seq, mode="drop"),
                kind=emit_mat.kind.at[p].set(out.kind, mode="drop"),
                src=emit_mat.src.at[p].set(out.src, mode="drop"),
                dst=emit_mat.dst.at[p].set(out.dst, mode="drop"),
                ctx=emit_mat.ctx.at[p].set(out.ctx, mode="drop"),
                payload=emit_mat.payload.at[p].set(out.payload, mode="drop"),
                valid=emit_mat.valid.at[p].set(out.valid & active,
                                               mode="drop"),
            )
            return k + 1, world, counters, emit_mat

        _, world, counters, emit_mat = jax.lax.while_loop(
            cond, body, (jnp.int32(0), world, counters, emit_mat))

        # trace in (time, seq) window order — independent of execution order.
        # events.trace_append holds the position math (ring writes wrap under
        # the streaming drain; bounded overflow is counted, never silent).
        rows4 = jnp.stack([cand.time, cand.seq, cand.kind, cand.dst], axis=1)
        trace, trace_n, clipped = ev.trace_append(
            trace, trace_n, rows4, exec_safe, ring=ring,
            rank_fn=self.trace_fn)
        if not ring and self.trace_cap > 0:
            counters = mon.bump(counters, mon.C_TRACE_DROP, clipped)

        # segmented emit merge: flatten the per-slot matrix row-major (== the
        # sequential append order) and compact into the window emit buffer
        flat = jax.tree.map(
            lambda x: x.reshape((xcap * ev.MAX_EMIT,) + x.shape[2:]), emit_mat)
        emits, _n_emit, dropped = ev.compact_batch(flat, spec.emit_cap)
        counters = mon.bump(counters, mon.C_DROP_POOL, dropped)
        return world, counters, emits, trace, trace_n

    # ---------------------------------------------------------------- routing
    def _insert(self, pool: ev.EventPool, counters, batch: ev.EventBatch):
        """Pool insert via the spec's lifecycle path (+ wrap accounting).

        ``slot_fn`` (wired by the fused front-end, or explicitly) swaps the
        ring's XLA slot math for the Pallas prefix-sum + ring-gather kernel —
        identical destination slots by the kernel-vs-ref sweeps."""
        if self.spec.insert_mode == "ring":
            pool2, dropped = ev.insert(pool, batch, slot_fn=self.slot_fn)
            n_take = pool.free_count - pool2.free_count
            counters = mon.bump(
                counters, mon.C_RING_WRAP,
                pool.free_head + n_take >= jnp.int32(self.spec.pool_cap))
            return pool2, counters, dropped
        pool2, dropped = ev.insert_ref(pool, batch)
        return pool2, counters, dropped

    def _route_and_insert(self, world: World, pool: ev.EventPool, counters,
                          emits: ev.EventBatch, axis: "str | ShardAxes | None",
                          migrate: bool = False):
        """Route a batch by destination agent, exchange, insert (steps 5-6).

        ``migrate=True`` is the placement-migration flavor: it additionally
        books shipped rows into C_MIGRATE_OUT (donor side, post route-cap —
        route overflow stays C_DROP_ROUTE as everywhere) and received rows
        into C_MIGRATE_IN (pre-insert), so ``sum(C_MIGRATE_OUT) ==
        sum(C_MIGRATE_IN)`` holds globally and exactly; receiving-pool
        overflow lands in C_DROP_POOL, never silent.
        """
        spec = self.spec
        A = axis.size if isinstance(axis, ShardAxes) else spec.n_agents
        if axis is None or A == 1:
            pool, counters, dropped = self._insert(pool, counters, emits)
            counters = mon.bump(counters, mon.C_DROP_POOL, dropped)
            counters = mon.bump(counters, mon.C_LP_LOCAL,
                                jnp.sum(emits.valid.astype(jnp.int32)))
            return pool, counters

        me = jax.lax.axis_index(axis_names(axis))
        rcap = spec.route_cap
        dst_agent = jnp.where(emits.valid, world.lp_agent[emits.dst], A)

        # stable bucket ranks (route_fn hook; default XLA sort-based rank)
        rank = self.route_fn(dst_agent)

        ok = emits.valid & (rank < rcap)
        counters = mon.bump(counters, mon.C_DROP_ROUTE,
                            jnp.sum((emits.valid & ~ok).astype(jnp.int32)))
        counters = mon.bump(
            counters, mon.C_MSGS_REMOTE,
            jnp.sum((ok & (dst_agent != me)).astype(jnp.int32)))
        counters = mon.bump(
            counters, mon.C_LP_LOCAL,
            jnp.sum((ok & (dst_agent == me)).astype(jnp.int32)))
        if migrate:
            counters = mon.bump(
                counters, mon.C_MIGRATE_OUT,
                jnp.sum((ok & (dst_agent != me)).astype(jnp.int32)))

        flat = jnp.where(ok, dst_agent * rcap + rank, A * rcap)  # OOB -> drop

        def scatter(col, fill):
            buf = jnp.full((A * rcap,) + col.shape[1:], fill, col.dtype)
            return buf.at[flat].set(col, mode="drop").reshape(
                (A, rcap) + col.shape[1:])

        if isinstance(axis, ShardAxes):
            # all_to_all takes a single axis name, so the (shard x lane)
            # exchange is staged: reshape the (A, rcap, ...) buffer to the
            # shard-major (D, K, rcap, ...) packing, exchange shard blocks
            # across the mesh, then lane blocks inside each shard. The
            # flattened receive order is ascending global source agent —
            # exactly the flat single-axis exchange's — so pool slot layouts
            # (and hence traces/counters) stay byte-identical to run_local.
            d, k = axis.n_shards, axis.n_lanes

            def a2a(col):
                x = col.reshape((d, k) + col.shape[1:])
                x = jax.lax.all_to_all(x, axis.shard, split_axis=0,
                                       concat_axis=0)
                x = jax.lax.all_to_all(x, axis.lane, split_axis=1,
                                       concat_axis=1)
                return x.reshape(col.shape)
        else:
            a2a = functools.partial(jax.lax.all_to_all, axis_name=axis,
                                    split_axis=0, concat_axis=0)

        rx = ev.EventBatch(
            time=a2a(scatter(emits.time, ev.T_INF)).reshape(A * rcap),
            seq=a2a(scatter(emits.seq, 0)).reshape(A * rcap),
            kind=a2a(scatter(emits.kind, 0)).reshape(A * rcap),
            src=a2a(scatter(emits.src, 0)).reshape(A * rcap),
            dst=a2a(scatter(emits.dst, 0)).reshape(A * rcap),
            ctx=a2a(scatter(emits.ctx, 0)).reshape(A * rcap),
            payload=a2a(scatter(emits.payload, 0.0)).reshape(A * rcap,
                                                             ev.PAYLOAD),
            valid=a2a(scatter(emits.valid, False)).reshape(A * rcap),
        )
        if migrate:
            # received rows counted before insert: out/in balance is exact,
            # and any overflow below is a C_DROP_POOL, not a silent loss
            counters = mon.bump(counters, mon.C_MIGRATE_IN,
                                jnp.sum(rx.valid.astype(jnp.int32)))
        pool, counters, dropped = self._insert(pool, counters, rx)
        counters = mon.bump(counters, mon.C_DROP_POOL, dropped)
        return pool, counters

    # -------------------------------------------------- host-streaming layer
    @property
    def _streaming(self) -> bool:
        return self.trace_stream is not None or self.metrics_stream is not None

    # ------------------------------------------------------ checkpoint layer
    @property
    def _checkpointing(self) -> bool:
        ck = self.checkpointer
        return ck is not None and getattr(ck, "every", 0) > 0

    def _checkpoint_window(self, st: EngineState, rung: int | None = None,
                           padded: bool = False) -> None:
        """Window-boundary checkpoint hook (host-stepped drivers).

        Saves the *unpadded* state when the cadence is due: a checkpoint is
        device-layout-free, so restore re-pads for whatever mesh the resumed
        driver gets. ``rung`` is the adaptive rung already chosen for the
        next window (the adaptive loops call this after ``choose_rung``), so
        a resumed trajectory continues exactly."""
        ck = self.checkpointer
        if ck is None:
            return
        w = int(np.asarray(st.windows).reshape(-1)[0])
        if not ck.due(w):
            return
        ck.save_sim(w, self._slice_state(st) if padded else st,
                    engine=self, rung=rung)

    def restore(self, step: int | None = None):
        """Load a checkpoint written by this engine's checkpointer.

        Returns a ``SimCheckpoint(step, state, rung)``: pass ``state=`` (and
        for the adaptive drivers ``rung=``) to any driver to resume. Also
        reloads the checkpoint's drained trace spans into the attached
        :class:`TraceStream` (so a resumed streamed run reassembles the full
        ``[0, trace_n)`` trace with zero drops) and its emitted metrics
        records into the attached :class:`MetricsStream` (so the interval
        record sequence concatenates exactly across the boundary)."""
        if self.checkpointer is None:
            raise ValueError("no checkpointer attached to this engine")
        return self.checkpointer.restore_sim(self, step=step)

    def _on_trace_drain(self, agent, start, count, ring):
        """io_callback target (host thread): forward a drained span."""
        ts = self.trace_stream
        if ts is not None:
            ts.on_drain(agent, start, count, ring)

    def _on_metrics(self, agent, window, gvt, counters):
        """io_callback target (host thread): forward a window snapshot."""
        ms = self.metrics_stream
        if ms is not None:
            ms.on_window(agent, window, gvt, counters)

    def _begin_streams(self, widths) -> None:
        """Arm the attached streams for a run using exec widths ``widths``.

        The zero-drop invariant needs the ring to hold at least one window's
        worst case, so the widest rung bounds the minimum ``trace_cap``."""
        if self.trace_stream is not None:
            need = max(max(min(int(w), self.spec.pool_cap), 1)
                       for w in widths)
            if self.trace_cap < need:
                raise ValueError(
                    f"streaming trace ring too small: trace_cap="
                    f"{self.trace_cap} must hold one window's writes (max "
                    f"exec width {need}) or the drain cannot keep "
                    f"C_TRACE_DROP == 0")
            self.trace_stream.begin(self.spec.n_agents)
        if self.metrics_stream is not None:
            self.metrics_stream.begin(self.spec.n_agents, self.registry)

    def _finalize_streams(self, st: EngineState) -> EngineState:
        """Drain outstanding callbacks and flush the in-state tail spans.

        ``st`` must be the unpadded (A, ...) final state. effects_barrier
        makes every in-flight io_callback land before reassembly."""
        if not self._streaming:
            return st
        getattr(jax, "effects_barrier", lambda: None)()
        if self.trace_stream is not None:
            self.trace_stream.finalize(np.asarray(st.trace),
                                       np.asarray(st.trace_n),
                                       np.asarray(st.trace_tail))
        if self.metrics_stream is not None:
            self.metrics_stream.finalize(np.asarray(st.counters),
                                         np.asarray(st.windows),
                                         np.asarray(st.t_now))
        return st

    def _run_hosted(self, max_windows: int,
                    state: EngineState | None = None,
                    mesh: Mesh | None = None) -> EngineState:
        """Host-stepped static-width run with the host hooks live.

        ``run_local``/``run_distributed`` land here when a stream or a
        checkpointer is attached: the whole-run while_loop can carry neither
        io_callbacks under vmap nor a mid-run host save, so the driver steps
        the jit-cached window program from the host (the run_adaptive shape).
        Stream drains fire inside each window program at its boundary; the
        checkpoint hook runs between window programs — the GVT-aligned
        boundary where the state is globally consistent."""
        width = self.spec.exec_cap
        self._begin_streams([width])
        if mesh is None:
            st = self.init_state() if state is None else state
            fn = self._window_fn(width)
        else:
            axes = self._dist_axes(mesh)
            st = self._pad_state(self.init_state() if state is None else state,
                                 axes.size)
            fn = self._dist_window_fn(mesh, width)
        for _ in range(max_windows):
            if bool(np.asarray(st.done).all()):
                break
            st = fn(st)
            self._checkpoint_window(st, padded=mesh is not None)
            self._fire_window_hook(st)
        if mesh is not None:
            st = self._slice_state(st)
        return self._finalize_streams(st)

    def _fire_window_hook(self, st: EngineState) -> None:
        """Invoke the orchestrator's host observation point, if any.

        Runs after ``_checkpoint_window`` so a hook that aborts the run
        (raising e.g. ``repro.fleet.PreemptionError``) never outruns the
        latest due checkpoint."""
        if self.window_hook is not None:
            self.window_hook(int(np.asarray(st.windows).reshape(-1)[0]), st)

    # ------------------------------------------------------------------- run
    def _run_fn(self, axis: "str | ShardAxes | None", max_windows: int):
        def cond(st: EngineState):
            return (~st.done) & (st.windows < max_windows)

        def body(st: EngineState):
            return self._superstep(st, axis)

        def run(st: EngineState):
            return jax.lax.while_loop(cond, body, st)

        return run

    def run_local(self, max_windows: int = 10_000, jit: bool = True,
                  state: EngineState | None = None) -> EngineState:
        """Single-device multi-agent execution (vmap over the agents axis).

        ``state`` resumes from a prior EngineState (e.g. after a placement
        migration) instead of ``init_state()``.

        With a trace/metrics stream or a checkpointer attached the run is
        host-stepped (see :meth:`_run_hosted`) — the whole-run while_loop
        cannot carry the drain io_callbacks under a batched predicate, nor
        pause for a mid-run checkpoint save."""
        if self._streaming or self._checkpointing:
            return self._run_hosted(max_windows, state=state)
        st = self.init_state() if state is None else state
        key = ("run_local", max_windows, jit)
        fn = self._jit_cache.get(key)
        if fn is None:
            fn = jax.vmap(self._run_fn(AXIS if self.spec.n_agents > 1 else None,
                                       max_windows), axis_name=AXIS)
            if jit:
                fn = jax.jit(fn)
            self._jit_cache[key] = fn
        return fn(st)

    # ------------------------------------------------------- distributed run
    def _dist_axes(self, mesh: Mesh) -> ShardAxes:
        """The shard x lane packing of a mesh: ``K = ceil(A / D)`` agents per
        device, stacked state padded to ``D * K`` rows (shard-major)."""
        if len(mesh.axis_names) != 1:
            raise ValueError(
                f"run_distributed needs a 1-D mesh, got axes {mesh.axis_names}")
        shard = mesh.axis_names[0]
        d = int(mesh.devices.size)
        k = -(-self.spec.n_agents // d)
        lane = "lanes" if shard != "lanes" else "lanes2"
        return ShardAxes(shard=shard, lane=lane, n_shards=d, n_lanes=k)

    def _pad_state(self, st: EngineState, a_pad: int) -> EngineState:
        """Pad a stacked (A, ...) state to ``a_pad`` rows with inert agents.

        Pad agents exist so ``A % n_devices != 0`` still packs into a
        rectangular (D, K) layout. They must be *invisible*: an empty pool
        contributes T_INF to GVT, an ``lp_agent`` row copied from agent 0
        owns no LP at a pad index (all ``lp_agent`` values are real-agent
        ids), so owner-wins sync and the routing exchange see only zeros from
        them. Globally-uniform scalars (t_now/done/windows — and the
        replicated world copy) are broadcast from row 0, NOT zeroed: every
        row of the while_loop cond must stay uniform even when resuming from
        a mid-run state, or the shards' collective counts diverge. Counters
        and trace are zeroed (pad rows are sliced off before results are
        returned, and all-zero rows are neutral in the max-reduced adaptive
        stats).
        """
        n = a_pad - st.t_now.shape[0]
        if n == 0:
            return st
        rep0 = lambda x: jnp.concatenate(
            [x, jnp.broadcast_to(x[:1], (n,) + x.shape[1:])])
        zero = lambda x: jnp.concatenate(
            [x, jnp.zeros((n,) + x.shape[1:], x.dtype)])
        epool = ev.empty_pool(self.spec.pool_cap)
        pool = jax.tree.map(
            lambda x, e: jnp.concatenate(
                [x, jnp.broadcast_to(e[None], (n,) + e.shape)]),
            st.pool, epool)
        return EngineState(
            world=jax.tree.map(rep0, st.world),
            pool=pool,
            counters=zero(st.counters),
            t_now=rep0(st.t_now),
            done=rep0(st.done),
            windows=rep0(st.windows),
            trace=zero(st.trace),
            trace_n=zero(st.trace_n),
            trace_tail=zero(st.trace_tail),
        )

    def _slice_state(self, st: EngineState) -> EngineState:
        """Drop pad-agent rows: the real agents' (A, ...) state."""
        A = self.spec.n_agents
        if st.t_now.shape[0] == A:
            return st
        return jax.tree.map(lambda x: x[:A], st)

    def _dist_run_fn(self, mesh: Mesh, axes: ShardAxes, max_windows: int):
        key = ("run_distributed", mesh, max_windows)
        fn = self._jit_cache.get(key)
        if fn is None:
            inner = jax.vmap(self._run_fn(axes, max_windows),
                             axis_name=axes.lane)
            fn = jax.jit(_shard_map(inner, mesh=mesh, in_specs=P(axes.shard),
                                    out_specs=P(axes.shard)))
            self._jit_cache[key] = fn
        return fn

    def run_distributed(self, mesh: Mesh, max_windows: int = 10_000,
                        state: EngineState | None = None) -> EngineState:
        """shard_map x vmap execution over a 1-D device mesh.

        ``K = ceil(n_agents / n_devices)`` agents pack per device: shard_map
        partitions the stacked (padded) state's leading axis over the mesh
        and ``vmap`` runs the per-agent program over each shard's K-row
        block, so agent count is decoupled from device count. Collectives
        reduce over the (shard, lane) axis-name tuple (one fleet-global
        GVT/psum) and the routing all_to_all is staged per axis with a
        shard-major receive order — results are byte-identical to
        ``run_local`` (down to pool slot layouts) and hence to the
        sequential oracle. ``state`` resumes from a prior (unpadded)
        EngineState.

        With a trace/metrics stream or a checkpointer attached the run is
        host-stepped (see :meth:`_run_hosted`); per-shard rings drain
        independently and the host merge is shard-major, matching
        ``merged_engine_trace``. Checkpoints save the unpadded state, so a
        resumed run may use a different mesh."""
        if self._streaming or self._checkpointing:
            return self._run_hosted(max_windows, state=state, mesh=mesh)
        axes = self._dist_axes(mesh)
        st = self._pad_state(self.init_state() if state is None else state,
                             axes.size)
        out = self._dist_run_fn(mesh, axes, max_windows)(st)
        return self._slice_state(out)

    # -------------------------------------------------------------- migration
    def _apply_placement(self, st: EngineState, new_lp_agent: jax.Array,
                         axis: "str | ShardAxes | None") -> EngineState:
        """Move LPs to a new placement (paper §4.1 dynamic decomposition).

        Component state is replicated (C4), so migration only (1) rewrites
        ``lp_agent`` and (2) re-homes pending events whose destination LP
        moved — one extra all_to_all, reusing the routing path with
        ``migrate=True`` so shipped/received rows are booked into
        C_MIGRATE_OUT / C_MIGRATE_IN (globally balanced; receiver overflow
        is C_DROP_POOL). The donor pool is canonicalized by ``ev.pop_mask``'s
        ring rebuild, so slot layout after a migration is a pure function of
        the surviving events — identical across drivers.
        """
        world = st.world._replace(lp_agent=jnp.asarray(new_lp_agent,
                                                       jnp.int32))
        pool, counters = st.pool, st.counters
        if axis is None or self.spec.n_agents == 1:
            return st._replace(world=world)
        me = jax.lax.axis_index(axis_names(axis))
        moving = pool.valid & (world.lp_agent[pool.dst] != me)
        emits = ev.extract(pool, moving)
        pool = ev.pop_mask(pool, moving)
        pool, counters = self._route_and_insert(world, pool, counters, emits,
                                                axis, migrate=True)
        return st._replace(world=world, pool=pool, counters=counters)

    def apply_placement_local(self, st: EngineState,
                              new_lp_agent: jax.Array) -> EngineState:
        """vmap driver for migration (new_lp_agent is fleet-global, (NLP,))."""
        axis = AXIS if self.spec.n_agents > 1 else None
        fn = jax.vmap(lambda s: self._apply_placement(
            s, new_lp_agent, axis), axis_name=AXIS)
        return jax.jit(fn)(st)

    def apply_placement_distributed(self, st: EngineState,
                                    new_lp_agent: jax.Array,
                                    mesh: Mesh) -> EngineState:
        """shard_map x vmap driver for migration (cross-shard event re-home).

        ``st`` is an unpadded (A, ...) state (e.g. a ``run_distributed``
        result mid-run); ``new_lp_agent`` is fleet-global. Returns the
        unpadded migrated state — byte-identical to
        ``apply_placement_local`` on the same state."""
        axes = self._dist_axes(mesh)
        key = ("dist_placement", mesh)
        fn = self._jit_cache.get(key)
        if fn is None:
            inner = jax.vmap(
                lambda s, nla: self._apply_placement(s, nla, axes),
                in_axes=(0, None), axis_name=axes.lane)
            fn = jax.jit(_shard_map(inner, mesh=mesh,
                                    in_specs=(P(axes.shard), P()),
                                    out_specs=P(axes.shard)))
            self._jit_cache[key] = fn
        return self._slice_state(fn(self._pad_state(st, axes.size),
                                    new_lp_agent))

    def step_local(self, st: EngineState) -> EngineState:
        """One conservative window (vmap driver) — used by tests and benchmarks."""
        fn = self._jit_cache.get("step_local")
        if fn is None:
            fn = jax.jit(jax.vmap(
                lambda s: self._superstep(s, AXIS if self.spec.n_agents > 1
                                          else None),
                axis_name=AXIS))
            self._jit_cache["step_local"] = fn
        return fn(st)

    # ------------------------------------------------------ adaptive driver
    def _window_fn(self, width: int):
        """One jitted window program at a fixed exec width (cached per rung,
        so the adaptive ladder recompiles nothing after first use)."""
        stream = self._streaming
        key = ("window_stream" if stream else "window", width)
        fn = self._jit_cache.get(key)
        if fn is None:
            fn = jax.jit(jax.vmap(
                lambda s: self._superstep(
                    s, AXIS if self.spec.n_agents > 1 else None,
                    exec_cap=width, stream=stream),
                axis_name=AXIS))
            self._jit_cache[key] = fn
        return fn

    def run_adaptive(self, max_windows: int = 10_000,
                     policy: "pol.ExecPolicy | int | None" = None,
                     state: EngineState | None = None,
                     rung: int | None = None) -> EngineState:
        """Monitoring-driven execution (vmap driver): the per-window LISA
        loop of core/policy.py.

        Each window runs the jitted program of the current ladder rung, then
        the host reads the window's monitoring vector (spill rate, scatter
        volume, pool occupancy/headroom gauges) and picks the next rung —
        grow under spill pressure or near pool saturation, shrink on sparse
        windows. Exactness is unconditional: spilling is oracle-exact for any
        width sequence, so traces/world bytes match the static drivers and
        the sequential oracle; only the window count (and per-window cost)
        changes. The rung trajectory lands in ``self.adaptive_rungs``.

        ``policy`` overrides ``spec.exec_policy`` (a bare int means a
        single-rung ladder, i.e. the static behavior); ``state`` resumes
        from a prior EngineState and ``rung`` from a checkpointed rung
        (checkpoints save the rung *after* ``choose_rung``, and the restored
        counters are exactly the save-time ``cur``, so a resumed trajectory
        concatenates byte-identically with the prefix).
        """
        p = pol.normalize(self.spec.exec_policy if policy is None else policy)
        self._begin_streams(p.ladder)
        st = self.init_state() if state is None else state
        rung = p.init_rung if rung is None else int(rung)
        prev = np.asarray(st.counters)
        rungs: list[int] = []
        for _ in range(max_windows):
            if bool(np.asarray(st.done).all()):
                break
            rungs.append(rung)
            st = self._window_fn(p.ladder[rung])(st)
            cur = np.asarray(st.counters)
            stats = pol.window_stats(prev, cur, self.spec.pool_cap)
            rung = pol.choose_rung(p, rung, stats)
            prev = cur
            self._checkpoint_window(st, rung=rung)
            self._fire_window_hook(st)
        self.adaptive_rungs = tuple(rungs)
        return self._finalize_streams(st)

    def _dist_window_fn(self, mesh: Mesh, width: int):
        """One jitted shard_map x vmap window program at a fixed exec width
        (cached per (mesh, rung) — lockstep adaptation recompiles nothing
        after each rung's first use)."""
        stream = self._streaming
        key = ("dist_window_stream" if stream else "dist_window", mesh, width)
        fn = self._jit_cache.get(key)
        if fn is None:
            axes = self._dist_axes(mesh)
            inner = jax.vmap(
                lambda s: self._superstep(s, axes, exec_cap=width,
                                          stream=stream),
                axis_name=axes.lane)
            fn = jax.jit(_shard_map(inner, mesh=mesh, in_specs=P(axes.shard),
                                    out_specs=P(axes.shard)))
            self._jit_cache[key] = fn
        return fn

    def run_distributed_adaptive(self, mesh: Mesh, max_windows: int = 10_000,
                                 policy: "pol.ExecPolicy | int | None" = None,
                                 state: EngineState | None = None,
                                 rung: int | None = None) -> EngineState:
        """Monitoring-driven distributed execution: ``run_adaptive``'s LISA
        loop over the shard_map x vmap driver.

        Each window runs the jit-cached program of the current rung on every
        shard (the collectives inside a window are fleet-wide, so all shards
        must trace the same width). The host then reads per-shard
        :func:`pol.shard_window_stats` off the free ring's O(1) occupancy
        gauges, decides a rung per shard, and max-reduces the decisions
        (:func:`pol.choose_rung_lockstep`) — the hottest shard sets the
        fleet's width. Because every ``choose_rung`` condition is monotone in
        the max-reduced stats, the lockstep rung trajectory is byte-identical
        to ``run_adaptive``'s on the same scenario, and exactness is
        unconditional (spilling is oracle-exact for any width sequence). The
        trajectory lands in ``self.adaptive_rungs``. ``state``/``rung``
        resume from a checkpoint — on any mesh, since checkpoints hold the
        unpadded state and this driver re-pads for the mesh it is given."""
        p = pol.normalize(self.spec.exec_policy if policy is None else policy)
        self._begin_streams(p.ladder)
        axes = self._dist_axes(mesh)
        A = self.spec.n_agents
        st = self._pad_state(self.init_state() if state is None else state,
                             axes.size)
        rung = p.init_rung if rung is None else int(rung)
        prev = np.asarray(st.counters)
        rungs: list[int] = []
        for _ in range(max_windows):
            if bool(np.asarray(st.done)[:A].all()):
                break
            rungs.append(rung)
            st = self._dist_window_fn(mesh, p.ladder[rung])(st)
            cur = np.asarray(st.counters)
            stats = pol.shard_window_stats(prev, cur, self.spec.pool_cap,
                                           axes.n_shards)
            rung = pol.choose_rung_lockstep(p, rung, stats)
            prev = cur
            self._checkpoint_window(st, rung=rung, padded=True)
            self._fire_window_hook(st)
        self.adaptive_rungs = tuple(rungs)
        return self._finalize_streams(self._slice_state(st))

    # ------------------------------------------------------- ensemble driver
    def run_ensemble(self, seeds, max_windows: int = 10_000,
                     seed_fn=None) -> EngineState:
        """Monte Carlo vmap-over-seeds driver: R replicas, one fused launch.

        Stacks R copies of the initial state, perturbs each with
        ``seed_fn(state, seed)`` (default :func:`seed_rng_fields`, which
        jumps every ``*_rng`` world field — the in-handler LCG states), and
        runs the whole-run while_loop under an outer replica vmap, so
        hundreds of replicas execute as one XLA program. jax's while_loop
        batching rule freezes finished replicas with a per-lane select, so
        each replica's slice of the (R, A, ...) result is byte-identical to
        a ``run_local`` of the same seeded state. With a MetricsStream
        attached, per-replica counter totals are reduced into
        ``metrics_stream`` (``replica_counters`` + a summary JSON line).

        "Millions of users" traffic in the paper's terms is exactly this:
        one launch sweeping seeds, not one hand-built spec per run.
        """
        if self.trace_stream is not None:
            raise ValueError(
                "run_ensemble cannot stream traces (io_callback is "
                "unsupported under the nested replica vmap); use a bounded "
                "trace_cap for per-replica traces")
        if self._checkpointing:
            raise ValueError(
                "run_ensemble is one fused program with no window "
                "boundaries on the host; checkpoint cadence applies to the "
                "single-run drivers")
        seeds = jnp.asarray(seeds, jnp.int32).reshape(-1)
        sfn = seed_fn or seed_rng_fields
        skey = ("ensemble_seed", sfn)
        seed_all = self._jit_cache.get(skey)
        if seed_all is None:
            seed_all = jax.jit(jax.vmap(sfn, in_axes=(None, 0)))
            self._jit_cache[skey] = seed_all
        key = ("run_ensemble", max_windows)
        fn = self._jit_cache.get(key)
        if fn is None:
            inner = jax.vmap(self._run_fn(AXIS if self.spec.n_agents > 1
                                          else None, max_windows),
                             axis_name=AXIS)
            fn = jax.jit(jax.vmap(inner))
            self._jit_cache[key] = fn
        out = fn(seed_all(self.init_state(), seeds))
        ms = self.metrics_stream
        if ms is not None:
            ms.begin(self.spec.n_agents, self.registry)
            ms.ensemble(np.asarray(seeds), np.asarray(out.counters),
                        np.asarray(out.windows), np.asarray(out.t_now))
        return out


def seed_rng_fields(state: EngineState, seed) -> EngineState:
    """Default ensemble ``seed_fn``: decorrelate one replica's RNG streams.

    Folds the replica seed into every integer world field named ``rng`` or
    ``*_rng`` — the registry convention for in-handler LCG state (e.g. the
    failure LP's ``fp_rng``) — using the same affine jump the scenario
    builders use to space per-row streams. Any int32 is a valid LCG state,
    so the perturbed replica is exact under the sequential oracle with the
    same world. A model with no RNG fields yields identical replicas
    (still useful for throughput measurement)."""
    upd = {}
    for name in state.world._fields:
        if name != "rng" and not name.endswith("_rng"):
            continue
        f = getattr(state.world, name)
        if jnp.issubdtype(f.dtype, jnp.integer):
            upd[name] = f + jnp.asarray(seed, f.dtype) * jnp.asarray(
                7919, f.dtype)
    return state._replace(world=state.world._replace(**upd)) if upd else state
