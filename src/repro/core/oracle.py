"""Sequential discrete-event oracle.

Processes events one at a time in exact global (time, seq) order with a binary heap —
the textbook sequential DES the paper's distributed engine must be equivalent to.
Numeric state transitions reuse the *same* jitted handler code as the engine
(``handlers.apply_handler``), so any trace/state divergence observed in tests isolates
a bug in the distributed machinery (windowing, GVT, routing, replication sync), not in
float arithmetic.
"""
from __future__ import annotations

import heapq

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events as ev
from repro.core import monitoring as mon
from repro.core.components import ScenarioSpec, World, WorldOwnership
from repro.core.handlers import Ev, apply_handler
from repro.core.registry import registry_of


def run_sequential(world: World, own: WorldOwnership, init_events: ev.EventBatch,
                   spec: ScenarioSpec, max_events: int = 100_000):
    """Returns (final_world, counters, trace) with trace = [(time, seq, kind, dst)].

    The dispatch table comes from the world's own registry, so models defined
    outside core (``BUILTIN.extend()``) get their sequential reference for free
    — including any registry-declared monitoring counters, which size the
    counter vector here exactly as they do in the engine.
    """
    reg = registry_of(world)
    table = reg.make_handlers(spec.lookahead, spec.work_per_mb)

    @jax.jit
    def apply(w, c, e):
        w2, c2, out = apply_handler(table, w, c, e)
        w2 = w2._replace(
            lp_lvt=w2.lp_lvt.at[e.dst].max(e.time),
            lp_state=w2.lp_state.at[e.dst].set(3),  # WAITING after processing
        )
        return w2, c2, out

    heap: list[tuple[int, int, int]] = []
    rows: dict[int, dict] = {}
    uid = 0
    init = jax.tree.map(np.asarray, init_events)
    for i in range(init.valid.shape[0]):
        if not bool(init.valid[i]):
            continue
        rows[uid] = dict(time=int(init.time[i]), seq=int(init.seq[i]),
                         kind=int(init.kind[i]), src=int(init.src[i]),
                         dst=int(init.dst[i]), ctx=int(init.ctx[i]),
                         payload=np.asarray(init.payload[i], np.float32))
        heapq.heappush(heap, (int(init.time[i]), int(init.seq[i]), uid))
        uid += 1

    counters = mon.zero_counters(reg.n_counters)
    trace: list[tuple[int, int, int, int]] = []
    n = 0
    while heap and n < max_events:
        t, s, u = heapq.heappop(heap)
        if t >= spec.t_end:
            break  # beyond the simulation horizon: identical to the engine's clamp
        r = rows.pop(u)
        e = Ev(time=jnp.int32(r["time"]), seq=jnp.int32(r["seq"]),
               kind=jnp.int32(r["kind"]), src=jnp.int32(r["src"]),
               dst=jnp.int32(r["dst"]), ctx=jnp.int32(r["ctx"]),
               payload=jnp.asarray(r["payload"]))
        world, counters, out = apply(world, counters, e)
        trace.append((r["time"], r["seq"], r["kind"], r["dst"]))
        n += 1

        out = jax.tree.map(np.asarray, out)
        for i in range(out.valid.shape[0]):
            if not bool(out.valid[i]):
                continue
            rows[uid] = dict(time=int(out.time[i]), seq=int(out.seq[i]),
                             kind=int(out.kind[i]), src=int(out.src[i]),
                             dst=int(out.dst[i]), ctx=int(out.ctx[i]),
                             payload=np.asarray(out.payload[i], np.float32))
            heapq.heappush(heap, (int(out.time[i]), int(out.seq[i]), uid))
            uid += 1

    counters = mon.bump(counters, mon.C_EVENTS, n)
    return world, counters, trace


def merged_engine_trace(trace: np.ndarray, trace_n: np.ndarray):
    """Merge per-agent engine traces into global (time, seq) order.

    trace: (A, cap, 4) int32, trace_n: (A,). Returns [(time, seq, kind, dst)].

    Refuses to return a *truncated* trace: an agent whose ``trace_n`` exceeds
    the buffer cap overflowed it (counted by ``C_TRACE_DROP``), and comparing
    the surviving prefix against an oracle would silently pass on divergence
    beyond the cap. Raise instead — size ``trace_cap`` to the scenario.
    """
    rows = []
    trace = np.asarray(trace)
    trace_n = np.asarray(trace_n)
    over = [(a, int(trace_n[a])) for a in range(trace.shape[0])
            if int(trace_n[a]) > trace.shape[1]]
    if over:
        raise RuntimeError(
            f"trace buffer overflowed (cap={trace.shape[1]}): per-agent "
            f"(agent, events) {over}; C_TRACE_DROP counts the lost records — "
            "raise Engine(trace_cap=...) to cover the scenario")
    for a in range(trace.shape[0]):
        k = int(trace_n[a])
        for i in range(min(k, trace.shape[1])):
            t, s, kind, dst = (int(x) for x in trace[a, i])
            rows.append((t, s, kind, dst))
    rows.sort(key=lambda r: (r[0], r[1]))
    return rows
