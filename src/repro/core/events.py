"""Event pool: structure-of-arrays encoding of the paper's simulation events.

The paper (§4.3): "A simulation event is always created by a logical process and is
destined to the same or other logical process. A simulation event includes information
regarding the identifiers of the source logical process and of the destination logical
process."  We add a ``ctx`` column for simulation contexts (§4.3 / fig 9) and a
functional ``seq`` tie-break id so the vectorized engine and the sequential oracle
produce byte-identical execution orders.

Timestamps are integer ticks (int32, 1 tick == 1 simulated microsecond by convention):
exact causality comparisons, exact test oracles, TPU-friendly.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import registry as _registry

# Sentinel timestamp for empty slots: larger than any reachable simulation time.
T_INF = jnp.int32(2**31 - 1)

# Payload width (single source of truth: registry.PAYLOAD).
PAYLOAD = _registry.PAYLOAD

# Max events a single handler invocation may emit (paper: a job may spawn a new LP
# *and* schedule follow-up events; 4 covers every component model in this repo).
MAX_EMIT = 4

# Event-kind ids (K_*), the kind -> component-table map (KIND_TABLE) the
# conflict mask keys on, and the table ids (TBL_*) are *generated* by the
# builtin registry from the declarative model in components.py; this module
# keeps the historical ``events.K_FLOW_START`` spelling as lazy aliases.
# Extended registries (e.g. repro/scenarios/cache.py) carry their own kind
# table — the engine reads it from the registry, never from this module.
_MODEL_ATTRS = (
    "K_NOOP", "K_FLOW_START", "K_FLOW_END", "K_JOB_SUBMIT", "K_JOB_END",
    "K_DATA_WRITE", "K_MIGRATE", "K_GEN_TICK", "N_KINDS", "KIND_TABLE",
    "TBL_NONE", "TBL_FARM", "TBL_NET", "TBL_STORAGE", "TBL_GEN", "N_TABLES",
)


def __getattr__(name: str):
    if name in _MODEL_ATTRS:
        from repro.core import components as _components
        return getattr(_components, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


SEQ_MASK = 2**31 - 1


def child_seq(parent_seq, slot):
    """Functional tie-break id: identical in the JAX engine and the Python oracle.

    int32 multiply wraps two's-complement; masking the sign bit yields the same
    non-negative residue wherever this runs (engine scan or oracle handler call).
    """
    parent_seq = jnp.asarray(parent_seq, jnp.int32)
    return (parent_seq * MAX_EMIT + jnp.int32(slot + 1)) & jnp.int32(SEQ_MASK)


class EventPool(NamedTuple):
    """Per-agent pending-event store (capacity fixed at construction).

    Fields are parallel arrays of shape (cap,) (payload: (cap, PAYLOAD)). ``valid``
    marks live slots; dead slots carry time == T_INF so min-reductions are mask-free.
    """

    time: jax.Array     # i32 (cap,)  timestamp in ticks; T_INF when slot free
    seq: jax.Array      # i32 (cap,)  deterministic tie-break id
    kind: jax.Array     # i32 (cap,)
    src: jax.Array      # i32 (cap,)  source LP (global id)
    dst: jax.Array      # i32 (cap,)  destination LP (global id)
    ctx: jax.Array      # i32 (cap,)  simulation context (run) id
    payload: jax.Array  # f32 (cap, PAYLOAD)
    valid: jax.Array    # bool (cap,)

    @property
    def cap(self) -> int:
        return self.time.shape[-1]


def empty_pool(cap: int) -> EventPool:
    return EventPool(
        time=jnp.full((cap,), T_INF, jnp.int32),
        seq=jnp.zeros((cap,), jnp.int32),
        kind=jnp.zeros((cap,), jnp.int32),
        src=jnp.zeros((cap,), jnp.int32),
        dst=jnp.zeros((cap,), jnp.int32),
        ctx=jnp.zeros((cap,), jnp.int32),
        payload=jnp.zeros((cap, PAYLOAD), jnp.float32),
        valid=jnp.zeros((cap,), bool),
    )


class EventBatch(NamedTuple):
    """A dense batch of candidate events (same fields as the pool, plus a mask)."""

    time: jax.Array
    seq: jax.Array
    kind: jax.Array
    src: jax.Array
    dst: jax.Array
    ctx: jax.Array
    payload: jax.Array
    valid: jax.Array

    @property
    def size(self) -> int:
        return self.time.shape[-1]


def empty_batch(n: int) -> EventBatch:
    p = empty_pool(n)
    return EventBatch(*p)


def batch_from_rows(rows) -> EventBatch:
    """Stack a Python list of event dicts into an EventBatch (host-side helper)."""
    n = len(rows)
    b = empty_batch(max(n, 1))
    if n == 0:
        return b
    def col(name, dtype):
        return jnp.asarray([r[name] for r in rows], dtype)
    payload = jnp.zeros((n, PAYLOAD), jnp.float32)
    for i, r in enumerate(rows):
        pl = jnp.asarray(r.get("payload", ()), jnp.float32)
        payload = payload.at[i, : pl.shape[0]].set(pl)
    return EventBatch(
        time=col("time", jnp.int32),
        seq=col("seq", jnp.int32),
        kind=col("kind", jnp.int32),
        src=col("src", jnp.int32),
        dst=col("dst", jnp.int32),
        ctx=jnp.asarray([r.get("ctx", 0) for r in rows], jnp.int32),
        payload=payload,
        valid=jnp.ones((n,), bool),
    )


def insert(pool: EventPool, batch: EventBatch):
    """Insert ``batch`` (masked rows skipped) into free slots of ``pool``.

    Returns (pool', n_dropped). Free slots are assigned in ascending slot order to
    keep the layout deterministic. Overflowing events are *counted*, never silently
    lost (the monitoring counters surface them — paper §4.1's "load of the agents").
    """
    cap = pool.cap
    free = ~pool.valid
    free_rank = jnp.cumsum(free.astype(jnp.int32)) - 1          # rank among free slots
    n_free = jnp.sum(free.astype(jnp.int32))

    want = batch.valid
    want_rank = jnp.cumsum(want.astype(jnp.int32)) - 1          # rank among inserts
    n_want = jnp.sum(want.astype(jnp.int32))
    fits = want & (want_rank < n_free)
    n_drop = n_want - jnp.sum(fits.astype(jnp.int32))

    # slot index for insert-rank r == index of r-th free slot. Build mapping
    # rank -> slot via scatter: slots[free_rank[i]] = i for free i.
    rank_to_slot = jnp.zeros((cap,), jnp.int32).at[
        jnp.where(free, free_rank, cap - 1)
    ].set(jnp.where(free, jnp.arange(cap, dtype=jnp.int32), 0), mode="drop")
    # destination slot for each batch row (garbage for non-fitting rows, masked out).
    dst_slot = rank_to_slot[jnp.clip(want_rank, 0, cap - 1)]
    idx = jnp.where(fits, dst_slot, cap)                        # cap == out of bounds -> drop

    pool = EventPool(
        time=pool.time.at[idx].set(batch.time, mode="drop"),
        seq=pool.seq.at[idx].set(batch.seq, mode="drop"),
        kind=pool.kind.at[idx].set(batch.kind, mode="drop"),
        src=pool.src.at[idx].set(batch.src, mode="drop"),
        dst=pool.dst.at[idx].set(batch.dst, mode="drop"),
        ctx=pool.ctx.at[idx].set(batch.ctx, mode="drop"),
        payload=pool.payload.at[idx].set(batch.payload, mode="drop"),
        valid=pool.valid.at[idx].set(True, mode="drop"),
    )
    return pool, n_drop


def gather(pool: EventPool, idx: jax.Array) -> EventBatch:
    """Gather pool slots ``idx`` into a dense candidate batch.

    The engine's compacted window (step 4) gathers the safe prefix of the
    (time, seq) sort so the handler fold runs over ``exec_cap`` slots instead of
    the whole pool. ``valid`` carries the gathered slots' liveness.
    """
    return EventBatch(
        time=pool.time[idx],
        seq=pool.seq[idx],
        kind=pool.kind[idx],
        src=pool.src[idx],
        dst=pool.dst[idx],
        ctx=pool.ctx[idx],
        payload=pool.payload[idx],
        valid=pool.valid[idx],
    )


def compact_batch(batch: EventBatch, cap: int):
    """Segmented append: compact ``batch``'s valid rows, in order, into a fresh
    ``cap``-row batch.

    The batched dispatcher collects every executed slot's emits into a
    (exec_cap, MAX_EMIT) matrix; flattened row-major it is exactly the
    sequential fold's append order, so this compaction keeps the same rows in
    the same order as the scan's per-event appends — including which
    overflowing rows are dropped. Implemented as one stable argsort on the
    valid flag plus a ``cap``-row gather (XLA scatters are far slower than a
    sort at pool widths). Returns (batch', n_valid, n_dropped).
    """
    n = batch.size
    val = batch.valid
    take = min(cap, n)
    order = jnp.argsort(~val, stable=True).astype(jnp.int32)[:take]
    out = jax.tree.map(lambda x: x[order], batch)
    if take < cap:
        pad = empty_batch(cap - take)
        out = jax.tree.map(lambda a, b: jnp.concatenate([a, b]), out, pad)
    # keep the dead-slot convention: invalid rows carry T_INF
    out = out._replace(time=jnp.where(out.valid, out.time, T_INF))
    n_valid = jnp.sum(val.astype(jnp.int32))
    n_kept = jnp.sum(out.valid.astype(jnp.int32))
    return out, n_valid, n_valid - n_kept


def pop_mask(pool: EventPool, mask: jax.Array) -> EventPool:
    """Invalidate ``mask``-ed slots (processed events leave the pool)."""
    gone = pool.valid & mask
    return pool._replace(
        time=jnp.where(gone, T_INF, pool.time),
        valid=pool.valid & ~mask,
    )


def min_pending_time(pool: EventPool) -> jax.Array:
    """Local minimum pending timestamp (T_INF when the pool is empty)."""
    return jnp.min(pool.time)  # dead slots carry T_INF already


def min_pending_time_per_ctx(pool: EventPool, n_ctx: int) -> jax.Array:
    """(n_ctx,) minimum pending timestamp per simulation context."""
    t = jnp.where(pool.valid, pool.time, T_INF)
    seg = jnp.where(pool.valid, pool.ctx, 0)
    init = jnp.full((n_ctx,), T_INF, jnp.int32)
    return init.at[seg].min(t, mode="drop")
