"""Event pool: structure-of-arrays encoding of the paper's simulation events.

The paper (§4.3): "A simulation event is always created by a logical process and is
destined to the same or other logical process. A simulation event includes information
regarding the identifiers of the source logical process and of the destination logical
process."  We add a ``ctx`` column for simulation contexts (§4.3 / fig 9) and a
functional ``seq`` tie-break id so the vectorized engine and the sequential oracle
produce byte-identical execution orders.

Timestamps are integer ticks (int32, 1 tick == 1 simulated microsecond by convention):
exact causality comparisons, exact test oracles, TPU-friendly.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import registry as _registry

# Sentinel timestamp for empty slots: larger than any reachable simulation time.
T_INF = jnp.int32(2**31 - 1)

# Payload width (single source of truth: registry.PAYLOAD).
PAYLOAD = _registry.PAYLOAD

# Max events a single handler invocation may emit (paper: a job may spawn a new LP
# *and* schedule follow-up events; 4 covers every component model in this repo).
MAX_EMIT = 4

# Event-kind ids (K_*), the kind -> component-table map (KIND_TABLE) the
# conflict mask keys on, and the table ids (TBL_*) are *generated* by the
# builtin registry from the declarative model in components.py; this module
# keeps the historical ``events.K_FLOW_START`` spelling as lazy aliases.
# Extended registries (e.g. repro/scenarios/cache.py) carry their own kind
# table — the engine reads it from the registry, never from this module.
_MODEL_ATTRS = (
    "K_NOOP", "K_FLOW_START", "K_FLOW_END", "K_JOB_SUBMIT", "K_JOB_END",
    "K_DATA_WRITE", "K_MIGRATE", "K_GEN_TICK", "N_KINDS", "KIND_TABLE",
    "TBL_NONE", "TBL_FARM", "TBL_NET", "TBL_STORAGE", "TBL_GEN", "N_TABLES",
)


def __getattr__(name: str):
    if name in _MODEL_ATTRS:
        from repro.core import components as _components
        return getattr(_components, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


SEQ_MASK = 2**31 - 1


def child_seq(parent_seq, slot):
    """Functional tie-break id: identical in the JAX engine and the Python oracle.

    int32 multiply wraps two's-complement; masking the sign bit yields the same
    non-negative residue wherever this runs (engine scan or oracle handler call).
    """
    parent_seq = jnp.asarray(parent_seq, jnp.int32)
    return (parent_seq * MAX_EMIT + jnp.int32(slot + 1)) & jnp.int32(SEQ_MASK)


class EventPool(NamedTuple):
    """Per-agent pending-event store (capacity fixed at construction).

    Fields are parallel arrays of shape (cap,) (payload: (cap, PAYLOAD)). ``valid``
    marks live slots; dead slots carry time == T_INF so min-reductions are mask-free.

    The pool carries its own free-slot lifecycle state (PR 5): ``free_ring`` is
    a ring buffer of free slot indices and ``free_head`` / ``free_tail`` /
    ``free_count`` are the ring cursors, with the invariant that ring positions
    ``head, head+1, ..., head+count-1 (mod cap)`` hold exactly the indices of
    the free (invalid) slots. ``insert`` pops the next-k free slots off the
    head (O(n_insert) — no pool-wide rank scan) and ``release`` pushes
    reclaimed slot ids onto the tail (O(n_released)); ``pop_mask`` (whole-pool
    masks, e.g. migration) canonicalizes via ``rebuild_ring``. The *reference
    scan paths* are the exception: ``insert_ref`` / ``pop_mask_ref`` keep only
    ``free_count`` exact and let the ring contents/cursors go stale (they are
    the retained PR 1-4 cost model; the ``insert_mode="ref"`` engine never
    reads the ring) — run ``rebuild_ring`` before handing a ref-mutated pool
    back to the ring fast path. Ring contents outside the live window are
    unspecified-but-deterministic: a pure function of the event history, so
    byte-comparisons between two runs of the same configuration stay exact.
    """

    time: jax.Array       # i32 (cap,)  timestamp in ticks; T_INF when slot free
    seq: jax.Array        # i32 (cap,)  deterministic tie-break id
    kind: jax.Array       # i32 (cap,)
    src: jax.Array        # i32 (cap,)  source LP (global id)
    dst: jax.Array        # i32 (cap,)  destination LP (global id)
    ctx: jax.Array        # i32 (cap,)  simulation context (run) id
    payload: jax.Array    # f32 (cap, PAYLOAD)
    valid: jax.Array      # bool (cap,)
    free_ring: jax.Array  # i32 (cap,)  ring buffer of free slot indices
    free_head: jax.Array  # i32 scalar  ring position of the next free slot
    free_tail: jax.Array  # i32 scalar  ring position where released slots land
    free_count: jax.Array  # i32 scalar number of free slots

    @property
    def cap(self) -> int:
        return self.time.shape[-1]


def empty_pool(cap: int) -> EventPool:
    return EventPool(
        time=jnp.full((cap,), T_INF, jnp.int32),
        seq=jnp.zeros((cap,), jnp.int32),
        kind=jnp.zeros((cap,), jnp.int32),
        src=jnp.zeros((cap,), jnp.int32),
        dst=jnp.zeros((cap,), jnp.int32),
        ctx=jnp.zeros((cap,), jnp.int32),
        payload=jnp.zeros((cap, PAYLOAD), jnp.float32),
        valid=jnp.zeros((cap,), bool),
        free_ring=jnp.arange(cap, dtype=jnp.int32),
        free_head=jnp.int32(0),
        free_tail=jnp.int32(0),
        free_count=jnp.int32(cap),
    )


def occupancy(pool: EventPool) -> jax.Array:
    """Live slots in the pool — O(1) off the ring's free count.

    The monitoring gauge the adaptive exec policy reads (C_POOL_OCC /
    C_POOL_FREE): every mutation path keeps ``free_count`` exact, so this
    never needs a pool-wide ``valid`` reduction.
    """
    return jnp.int32(pool.cap) - pool.free_count


def rebuild_ring(pool: EventPool) -> EventPool:
    """Canonicalize the free ring from ``valid`` (O(cap) — reference paths).

    Free slots land first, in ascending slot order, with ``head == 0``; live
    slots fill the dead remainder of the ring (also ascending), keeping the
    ring a deterministic permutation of ``arange(cap)``.
    """
    ring = jnp.argsort(pool.valid, stable=True).astype(jnp.int32)
    n_free = jnp.sum((~pool.valid).astype(jnp.int32))
    return pool._replace(
        free_ring=ring,
        free_head=jnp.int32(0),
        free_tail=n_free % jnp.int32(pool.cap),
        free_count=n_free,
    )


class EventBatch(NamedTuple):
    """A dense batch of candidate events (same fields as the pool, plus a mask)."""

    time: jax.Array
    seq: jax.Array
    kind: jax.Array
    src: jax.Array
    dst: jax.Array
    ctx: jax.Array
    payload: jax.Array
    valid: jax.Array

    @property
    def size(self) -> int:
        return self.time.shape[-1]


def empty_batch(n: int) -> EventBatch:
    return EventBatch(
        time=jnp.full((n,), T_INF, jnp.int32),
        seq=jnp.zeros((n,), jnp.int32),
        kind=jnp.zeros((n,), jnp.int32),
        src=jnp.zeros((n,), jnp.int32),
        dst=jnp.zeros((n,), jnp.int32),
        ctx=jnp.zeros((n,), jnp.int32),
        payload=jnp.zeros((n, PAYLOAD), jnp.float32),
        valid=jnp.zeros((n,), bool),
    )


def batch_from_rows(rows) -> EventBatch:
    """Stack a Python list of event dicts into an EventBatch (host-side helper)."""
    n = len(rows)
    b = empty_batch(max(n, 1))
    if n == 0:
        return b
    def col(name, dtype):
        return jnp.asarray([r[name] for r in rows], dtype)
    payload = jnp.zeros((n, PAYLOAD), jnp.float32)
    for i, r in enumerate(rows):
        pl = jnp.asarray(r.get("payload", ()), jnp.float32)
        payload = payload.at[i, : pl.shape[0]].set(pl)
    return EventBatch(
        time=col("time", jnp.int32),
        seq=col("seq", jnp.int32),
        kind=col("kind", jnp.int32),
        src=col("src", jnp.int32),
        dst=col("dst", jnp.int32),
        ctx=jnp.asarray([r.get("ctx", 0) for r in rows], jnp.int32),
        payload=payload,
        valid=jnp.ones((n,), bool),
    )


def _scatter_batch(pool: EventPool, batch: EventBatch, idx: jax.Array,
                   fits: jax.Array) -> EventPool:
    """Write the fitting batch rows into pool slots ``idx`` (cap == dropped)."""
    return pool._replace(
        time=pool.time.at[idx].set(batch.time, mode="drop"),
        seq=pool.seq.at[idx].set(batch.seq, mode="drop"),
        kind=pool.kind.at[idx].set(batch.kind, mode="drop"),
        src=pool.src.at[idx].set(batch.src, mode="drop"),
        dst=pool.dst.at[idx].set(batch.dst, mode="drop"),
        ctx=pool.ctx.at[idx].set(batch.ctx, mode="drop"),
        payload=pool.payload.at[idx].set(batch.payload, mode="drop"),
        valid=pool.valid.at[idx].set(fits, mode="drop"),
    )


def insert(pool: EventPool, batch: EventBatch, slot_fn=None):
    """Insert ``batch`` (masked rows skipped) into free slots of ``pool``.

    Returns (pool', n_dropped). The ring fast path: the r-th fitting row takes
    the slot at ring position ``(free_head + r) % cap`` — an O(n_insert)
    prefix-sum + gather, with no O(pool_cap) rank scan (that reference path is
    retained as :func:`insert_ref`). Slot assignment is deterministic (ring
    order is a pure function of the event history), and overflowing events are
    *counted*, never silently lost (the monitoring counters surface them —
    paper §4.1's "load of the agents").

    ``slot_fn(free_ring, free_head, want) -> dst_slot`` is the kernel hook for
    the Pallas free-ring gather (``kernels.ops.ring_slots``); the default is
    the XLA prefix-sum + gather below. ``dst_slot`` must hold, per batch row,
    the ring slot its insert rank addresses (garbage beyond ``free_count`` is
    fine — those rows are masked to the drop index).
    """
    cap = pool.cap
    want = batch.valid
    want_rank = jnp.cumsum(want.astype(jnp.int32)) - 1          # rank among inserts
    n_want = jnp.sum(want.astype(jnp.int32))
    fits = want & (want_rank < pool.free_count)
    n_take = jnp.sum(fits.astype(jnp.int32))

    if slot_fn is None:
        pos = (pool.free_head + jnp.maximum(want_rank, 0)) % jnp.int32(cap)
        dst_slot = pool.free_ring[pos]
    else:
        dst_slot = slot_fn(pool.free_ring, pool.free_head, want)
    idx = jnp.where(fits, dst_slot, cap)                        # cap == out of bounds -> drop

    pool = _scatter_batch(pool, batch, idx, fits)
    return pool._replace(
        free_head=(pool.free_head + n_take) % jnp.int32(cap),
        free_count=pool.free_count - n_take,
    ), n_want - n_take


def insert_ref(pool: EventPool, batch: EventBatch):
    """Reference insert: O(pool_cap) cumsum rank scan over the ``valid`` mask.

    The pre-ring (PR 1-4) insert path, retained as the oracle for the ring
    fast path (``spec.insert_mode="ref"``; the ``insert_churn`` benchmark
    gates the ring speedup against it). Free slots are assigned in ascending
    slot order. Semantically identical to :func:`insert` — same events kept,
    same events dropped — only the slot layout differs.

    Lifecycle state: only ``free_count`` is maintained (exact, for the
    occupancy gauges); the ring *contents* and cursors go stale — the ref
    engine path never reads them, and charging the reference a per-window
    ring rebuild would bias the benchmark it anchors. Run ``rebuild_ring``
    before handing a ref-inserted pool back to the ring fast path.
    """
    cap = pool.cap
    free = ~pool.valid
    free_rank = jnp.cumsum(free.astype(jnp.int32)) - 1          # rank among free slots
    n_free = jnp.sum(free.astype(jnp.int32))

    want = batch.valid
    want_rank = jnp.cumsum(want.astype(jnp.int32)) - 1          # rank among inserts
    n_want = jnp.sum(want.astype(jnp.int32))
    fits = want & (want_rank < n_free)
    n_drop = n_want - jnp.sum(fits.astype(jnp.int32))

    # slot index for insert-rank r == index of r-th free slot. Build mapping
    # rank -> slot via scatter: slots[free_rank[i]] = i for free i.
    rank_to_slot = jnp.zeros((cap,), jnp.int32).at[
        jnp.where(free, free_rank, cap - 1)
    ].set(jnp.where(free, jnp.arange(cap, dtype=jnp.int32), 0), mode="drop")
    # destination slot for each batch row (garbage for non-fitting rows, masked out).
    dst_slot = rank_to_slot[jnp.clip(want_rank, 0, cap - 1)]
    idx = jnp.where(fits, dst_slot, cap)                        # cap == out of bounds -> drop

    pool = _scatter_batch(pool, batch, idx, fits)
    pool = pool._replace(
        free_count=pool.free_count - (n_want - n_drop))
    return pool, n_drop


def release(pool: EventPool, slots: jax.Array, mask: jax.Array,
            pos: jax.Array | None = None) -> EventPool:
    """Reclaim executed slots: invalidate + push onto the free ring's tail.

    ``slots`` are distinct pool-slot indices (the engine's ``exec_idx`` window
    gather) and ``mask`` flags the rows that actually executed (``exec_safe``)
    — the caller guarantees masked slots are currently valid. O(len(slots)):
    the r-th masked slot lands at ring position ``(free_tail + r) % cap``, so
    reclaim order (and hence future insert layout) is the deterministic
    (time, seq) window order. The pool-wide-mask reference is
    :func:`pop_mask`.

    ``pos`` optionally supplies the per-row ring positions precomputed
    elsewhere (the fused front-end's ``FusedSelect.rel_pos``, ranked off the
    same ``free_tail``); it must equal the default prefix-sum math on every
    masked row — unmasked rows are dropped either way.
    """
    cap = pool.cap
    n = jnp.sum(mask.astype(jnp.int32))
    if pos is None:
        rank = jnp.cumsum(mask.astype(jnp.int32)) - 1
        pos = (pool.free_tail + jnp.maximum(rank, 0)) % jnp.int32(cap)
    ring = pool.free_ring.at[jnp.where(mask, pos, cap)].set(
        slots.astype(jnp.int32), mode="drop")
    gone = jnp.where(mask, slots, cap)
    return pool._replace(
        time=pool.time.at[gone].set(T_INF, mode="drop"),
        valid=pool.valid.at[gone].set(False, mode="drop"),
        free_ring=ring,
        free_tail=(pool.free_tail + n) % jnp.int32(cap),
        free_count=pool.free_count + n,
    )


def gather(pool: EventPool, idx: jax.Array) -> EventBatch:
    """Gather pool slots ``idx`` into a dense candidate batch.

    The engine's compacted window (step 4) gathers the safe prefix of the
    (time, seq) sort so the handler fold runs over ``exec_cap`` slots instead of
    the whole pool. ``valid`` carries the gathered slots' liveness.
    """
    return EventBatch(
        time=pool.time[idx],
        seq=pool.seq[idx],
        kind=pool.kind[idx],
        src=pool.src[idx],
        dst=pool.dst[idx],
        ctx=pool.ctx[idx],
        payload=pool.payload[idx],
        valid=pool.valid[idx],
    )


def compact_batch(batch: EventBatch, cap: int):
    """Segmented append: compact ``batch``'s valid rows, in order, into a fresh
    ``cap``-row batch.

    The batched dispatcher collects every executed slot's emits into a
    (exec_cap, MAX_EMIT) matrix; flattened row-major it is exactly the
    sequential fold's append order, so this compaction keeps the same rows in
    the same order as the scan's per-event appends — including which
    overflowing rows are dropped. Implemented as one stable argsort on the
    valid flag plus a ``cap``-row gather (XLA scatters are far slower than a
    sort at pool widths). Returns (batch', n_valid, n_dropped).
    """
    n = batch.size
    val = batch.valid
    take = min(cap, n)
    order = jnp.argsort(~val, stable=True).astype(jnp.int32)[:take]
    out = jax.tree.map(lambda x: x[order], batch)
    if take < cap:
        pad = empty_batch(cap - take)
        out = jax.tree.map(lambda a, b: jnp.concatenate([a, b]), out, pad)
    # keep the dead-slot convention: invalid rows carry T_INF
    out = out._replace(time=jnp.where(out.valid, out.time, T_INF))
    n_valid = jnp.sum(val.astype(jnp.int32))
    n_kept = jnp.sum(out.valid.astype(jnp.int32))
    return out, n_valid, n_valid - n_kept


def trace_append(trace: jax.Array, trace_n: jax.Array, rows4: jax.Array,
                 mask: jax.Array, *, ring: bool = False, rank_fn=None):
    """Append a window's processed-event rows to the (cap, 4) trace buffer.

    ``rows4`` is the window's (n, 4) candidate rows ``(time, seq, kind, dst)``
    and ``mask`` the processed lanes, in (time, seq) window order. The r-th
    masked row lands at absolute trace position ``trace_n + r``; ``rank_fn``
    is the hook computing that exclusive prefix rank of the mask (Pallas twin
    ``kernels.ops.trace_rank``; default XLA cumsum — the two are swept against
    each other in tests).

    Two write disciplines share the math:

    * bounded (``ring=False``, the historical buffer): positions past ``cap``
      are clipped out and counted — returns their number so the caller books
      ``C_TRACE_DROP``;
    * ring (``ring=True``, the streaming-trace device ring): positions wrap
      modulo ``cap`` and *every* row is written. Overwrite of un-drained rows
      is the caller's accounting (the drain keeps ``trace_n - trace_tail +
      width <= cap``, so it never happens between window-boundary drains) —
      the returned drop count is 0 here.

    Returns ``(trace, trace_n', n_clipped)``.
    """
    cap = trace.shape[0]
    n = mask.shape[0]
    w = mask.astype(jnp.int32)
    rank = (jnp.cumsum(w) - w) if rank_fn is None else rank_fn(mask)
    tpos = trace_n + rank
    if ring:
        tidx = jnp.where(mask, tpos % cap, n + cap)  # OOB -> dropped write
        clipped = jnp.int32(0)
    else:
        tidx = jnp.where(mask & (tpos < cap), tpos, n + cap)
        clipped = jnp.sum((mask & (tpos >= cap)).astype(jnp.int32))
    trace = trace.at[tidx].set(rows4, mode="drop")
    return trace, trace_n + jnp.sum(w), clipped


def extract(pool: EventPool, mask: jax.Array) -> EventBatch:
    """Pool rows as a routable batch: valid exactly where live and masked.

    The donor half of event migration (engine ``_apply_placement``): extract
    the moving rows, ``pop_mask`` them out (which canonicalizes the ring via
    ``rebuild_ring``), and hand the batch to the routing exchange. Rows stay
    in slot order, so the receiving inserts are deterministic."""
    return EventBatch(time=pool.time, seq=pool.seq, kind=pool.kind,
                      src=pool.src, dst=pool.dst, ctx=pool.ctx,
                      payload=pool.payload, valid=pool.valid & mask)


def pop_mask(pool: EventPool, mask: jax.Array) -> EventPool:
    """Invalidate ``mask``-ed slots and canonicalize the free ring.

    For rare whole-pool operations (LP migration re-homing) where the caller
    has a pool-wide mask rather than a slot list: the O(cap log cap) ring
    rebuild keeps the lifecycle state fully consistent for the ring fast
    path afterwards. The per-window reclaim is :func:`release`.
    """
    gone = pool.valid & mask
    pool = pool._replace(
        time=jnp.where(gone, T_INF, pool.time),
        valid=pool.valid & ~mask,
    )
    return rebuild_ring(pool)


def pop_mask_ref(pool: EventPool, mask: jax.Array) -> EventPool:
    """Invalidate ``mask``-ed slots — the PR 1-4 reclaim, O(cap) wheres.

    The ``insert_mode="ref"`` engine path: like :func:`insert_ref` it keeps
    ``free_count`` exact for the occupancy gauges but lets the ring contents
    go stale (nothing in ref mode reads them, and the retained scan path must
    carry its historical cost, not a ring-maintenance surcharge).
    """
    gone = pool.valid & mask
    return pool._replace(
        time=jnp.where(gone, T_INF, pool.time),
        valid=pool.valid & ~mask,
        free_count=pool.free_count + jnp.sum(gone.astype(jnp.int32)),
    )


def min_pending_time(pool: EventPool) -> jax.Array:
    """Local minimum pending timestamp (T_INF when the pool is empty)."""
    return jnp.min(pool.time)  # dead slots carry T_INF already


def min_pending_time_per_ctx(pool: EventPool, n_ctx: int) -> jax.Array:
    """(n_ctx,) minimum pending timestamp per simulation context."""
    t = jnp.where(pool.valid, pool.time, T_INF)
    seg = jnp.where(pool.valid, pool.ctx, 0)
    init = jnp.full((n_ctx,), T_INF, jnp.int32)
    return init.at[seg].min(t, mode="drop")
