"""repro.core — the paper's distributed discrete-event simulation framework.

Public surface (see docs/architecture.md for the full map, and
docs/scenario_api.md for the authoring guide):
  registry / Registry / FieldSpec / PayloadSpec — declarative model authoring;
                                              every engine table is generated
  BUILTIN (components.py)                    — the builtin four-component model
  ScenarioBuilder / World / ScenarioSpec     — model construction (components, C5)
  Engine / EngineState                       — conservative-window engine (C1, C2)
  handlers / WorldDelta                      — per-row event kernels + delta schema
  scheduler                                  — monitoring-driven placement (C3)
  oracle                                     — sequential reference DES

``__all__`` below *is* the supported public surface; ``tools/check_api.py``
gates it (and the generated schema exports) against registry drift in CI.
"""
from repro.core import (events, handlers, monitoring, network, oracle,
                        policy, registry, scheduler, sync)
from repro.core.components import (BUILTIN, LPK_FARM, LPK_GEN, LPK_IDLE,
                                   LPK_NET, LPK_STORAGE, ScenarioBuilder,
                                   ScenarioSpec, World, WorldOwnership,
                                   sync_world)
from repro.core.engine import (AXIS, Engine, EngineState, ShardAxes,
                               lexsort_time_seq)
from repro.core.handlers import WorldDelta
from repro.core.monitoring import MetricsStream, TraceStream
from repro.core.policy import ExecPolicy
from repro.core.oracle import merged_engine_trace, run_sequential
from repro.core.registry import (FieldSpec, PayloadSpec, Registry,
                                 RegistryError, registry_of)

__all__ = [
    "AXIS", "BUILTIN", "Engine", "EngineState", "ExecPolicy", "FieldSpec",
    "LPK_FARM", "LPK_GEN", "LPK_IDLE", "LPK_NET", "LPK_STORAGE",
    "MetricsStream",
    "PayloadSpec", "Registry", "RegistryError", "ScenarioBuilder",
    "ScenarioSpec", "ShardAxes", "TraceStream", "World", "WorldDelta",
    "WorldOwnership", "events",
    "handlers", "lexsort_time_seq", "merged_engine_trace", "monitoring",
    "network", "oracle", "policy", "registry", "registry_of",
    "run_sequential", "scheduler", "sync", "sync_world",
]
