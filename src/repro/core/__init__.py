"""repro.core — the paper's distributed discrete-event simulation framework.

Public surface (see docs/architecture.md for the full map):
  ScenarioBuilder / World / ScenarioSpec   — model construction (components, C5)
  Engine / EngineState                      — conservative-window engine (C1, C2)
  handlers / WorldDelta                     — per-row event kernels + delta schema
  scheduler                                 — monitoring-driven placement (C3)
  oracle                                    — sequential reference DES
"""
from repro.core import (events, handlers, monitoring, network, oracle,
                        scheduler, sync)
from repro.core.components import (LPK_FARM, LPK_GEN, LPK_NET, LPK_STORAGE,
                                   ScenarioBuilder, ScenarioSpec, World,
                                   WorldOwnership, sync_world)
from repro.core.engine import AXIS, Engine, EngineState, lexsort_time_seq
from repro.core.handlers import WorldDelta
from repro.core.oracle import merged_engine_trace, run_sequential

__all__ = [
    "AXIS", "Engine", "EngineState", "LPK_FARM", "LPK_GEN", "LPK_NET",
    "LPK_STORAGE", "ScenarioBuilder", "ScenarioSpec", "World", "WorldDelta",
    "WorldOwnership", "events", "handlers", "lexsort_time_seq",
    "merged_engine_trace", "monitoring", "network", "oracle", "run_sequential",
    "scheduler", "sync", "sync_world",
]
