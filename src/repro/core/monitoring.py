"""In-graph monitoring — the LISA adaptation (paper §4.1).

The paper couples the simulation with the LISA monitoring system so the scheduler can
read "the load of the physical workstation ... the load of the network ... and also
the load of the agents (number of logical processes already executing, what components
are already duplicated locally)". Here the same signals are JAX arrays carried through
the superstep: a per-agent counter vector plus derived *performance values*.

Counters are per-agent and local (never auto-synced); ``gather_counters`` exposes the
fleet view to the scheduler and to ``ft.straggler``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Counter indices.
C_EVENTS = 0          # events processed
C_MSGS_REMOTE = 1     # events routed to another agent
C_STALE = 2           # stale (interrupted) flow-completion events — paper's Fig-2 driver
C_INTERRUPTS = 3      # bandwidth-share recomputations
C_JOBS_SUBMITTED = 4
C_JOBS_DONE = 5
C_FLOWS_STARTED = 6
C_FLOWS_DONE = 7
C_MB_TRANSFERRED = 8  # rounded to int MB
C_DROP_POOL = 9       # event-pool overflow
C_DROP_ROUTE = 10     # routing-buffer overflow
C_DROP_FLOW = 11      # flow-table overflow
C_DROP_QUEUE = 12     # job-queue overflow
C_WINDOWS = 13        # conservative windows executed (sync rounds)
C_MIGRATIONS = 14     # disk -> tape migrations
C_WRITES = 15         # storage writes
C_MB_WRITTEN = 16
C_LP_LOCAL = 17       # events destined to locally-owned LPs (scheduler locality signal)
C_EXEC_SPILL = 18     # safe events deferred past exec_cap to the next window
C_BATCH_EXEC = 19     # events executed through the grouped vectorized dispatch
C_BATCH_FALLBACK = 20  # conflicted events executed via the sequential fallback
C_BATCH_ROWS = 21     # component-table rows scattered by the batched merge
C_TRACE_DROP = 22     # trace records lost to the fixed-cap trace buffer; any
                      # nonzero value makes trace-based oracle comparisons
                      # invalid, so oracle.merged_engine_trace refuses to
                      # return a truncated trace (fails loudly instead)
C_RING_WRAP = 23      # free-ring cursor wraps (head on insert, tail on release)
C_POOL_OCC = 24       # GAUGE: live pool slots at window end (occupancy)
C_POOL_FREE = 25      # GAUGE: free pool slots at window end (insert headroom)
C_MIGRATE_OUT = 26    # pending events shipped to another agent by placement
                      # migration (post route-cap; route overflow is
                      # C_DROP_ROUTE as everywhere)
C_MIGRATE_IN = 27     # migrated events received from another agent (counted
                      # pre-insert, so sum(out) == sum(in) globally; receiving
                      # pool overflow lands in C_DROP_POOL, never silent)
N_COUNTERS = 28

DROP_COUNTERS = (C_DROP_POOL, C_DROP_ROUTE, C_DROP_FLOW, C_DROP_QUEUE)

# Gauges: overwritten (not accumulated) every window — the pool-lifecycle
# occupancy signals the adaptive exec policy (core/policy.py) reads alongside
# the C_EXEC_SPILL / C_BATCH_ROWS rates.
GAUGE_COUNTERS = (C_POOL_OCC, C_POOL_FREE)

# Pool-lifecycle diagnostics: the only counters allowed to differ between the
# ring insert path and the retained insert_ref scan path of one scenario
# (the ref path never touches the ring cursors, so it never wraps them).
POOL_DIAG_COUNTERS = (C_RING_WRAP,)

# The engine-infrastructure counters every Registry starts with, in index
# order (Registry.__init__ seeds its counter table from this tuple, so the
# C_* constants above are the indices the registry assigns). Extensions
# declare additional counters with ``Registry.counter(name)`` — see
# docs/scenario_api.md — and size the engine's counter vector through
# ``Registry.n_counters``.
BUILTIN_COUNTERS = (
    ("EVENTS", "events processed"),
    ("MSGS_REMOTE", "events routed to another agent"),
    ("STALE", "stale (interrupted) flow-completion events"),
    ("INTERRUPTS", "bandwidth-share recomputations"),
    ("JOBS_SUBMITTED", "jobs accepted by a compute farm"),
    ("JOBS_DONE", "jobs completed"),
    ("FLOWS_STARTED", "WAN transfers started"),
    ("FLOWS_DONE", "WAN transfers completed"),
    ("MB_TRANSFERRED", "completed-flow megabytes (rounded to int)"),
    ("DROP_POOL", "event-pool overflow"),
    ("DROP_ROUTE", "routing-buffer overflow"),
    ("DROP_FLOW", "flow-table overflow"),
    ("DROP_QUEUE", "job-queue overflow"),
    ("WINDOWS", "conservative windows executed (sync rounds)"),
    ("MIGRATIONS", "disk -> tape migrations"),
    ("WRITES", "storage writes"),
    ("MB_WRITTEN", "written megabytes (rounded to int)"),
    ("LP_LOCAL", "events destined to locally-owned LPs"),
    ("EXEC_SPILL", "safe events deferred past exec_cap to the next window"),
    ("BATCH_EXEC", "events executed through grouped vectorized dispatch"),
    ("BATCH_FALLBACK", "conflicted events via the sequential fallback"),
    ("BATCH_ROWS", "component-table rows scattered by the batched merge"),
    ("TRACE_DROP", "trace records lost to the fixed-cap trace buffer"),
    ("RING_WRAP", "free-ring cursor wraps (head on insert, tail on release)"),
    ("POOL_OCC", "GAUGE: live pool slots at window end"),
    ("POOL_FREE", "GAUGE: free pool slots at window end"),
    ("MIGRATE_OUT", "pending events shipped to another agent by migration"),
    ("MIGRATE_IN", "migrated events received from another agent"),
)
assert len(BUILTIN_COUNTERS) == N_COUNTERS

# Dispatch-path diagnostics: the only counters allowed to differ between the
# batched and the sequential execution of the same scenario (everything else
# is byte-identical by the batched-dispatch equivalence contract).
# C_BATCH_ROWS measures the per-window scatter volume of the delta merge —
# the load signal the adaptive-exec_cap ROADMAP item keys on (a window that
# scatters few rows relative to exec_cap has headroom to grow the window).
BATCH_DIAG_COUNTERS = (C_BATCH_EXEC, C_BATCH_FALLBACK, C_BATCH_ROWS)


def zero_counters(n: int | None = None) -> jax.Array:
    """A zero counter vector. ``n`` sizes it for extended registries
    (``Registry.n_counters``); the default is the builtin width."""
    return jnp.zeros((N_COUNTERS if n is None else n,), jnp.int32)


def bump(counters: jax.Array, idx: int, amount=1) -> jax.Array:
    return counters.at[idx].add(jnp.asarray(amount, jnp.int32))


def gauge(counters: jax.Array, idx: int, value) -> jax.Array:
    """Overwrite a gauge counter (per-window level, not an accumulation)."""
    return counters.at[idx].set(jnp.asarray(value, jnp.int32))


def gather_counters(counters: jax.Array,
                    axis: str | tuple[str, ...] | None) -> jax.Array:
    """(A, N_COUNTERS) fleet view (identity reshape when single-agent).

    ``axis`` may be a (shard, lane) tuple for the shard_map x vmap driver
    (engine.ShardAxes agent packing): ``all_gather`` rejects mixed-axis
    tuples, so the gather is staged innermost-first — lanes, then shards —
    which flattens to the shard-major global agent order (== the global
    agent id ``lax.axis_index((shard, lane))`` yields)."""
    if axis is None:
        return counters[None]
    if isinstance(axis, (tuple, list)):
        out = counters
        for name in reversed(axis):
            out = jax.lax.all_gather(out, name)
        return out.reshape((-1,) + counters.shape)
    return jax.lax.all_gather(counters, axis)


def performance_value(counters: jax.Array, n_owned_lps: jax.Array,
                      pool_occupancy: jax.Array) -> jax.Array:
    """Scalar performance value an agent publishes (paper §4.1). Higher == worse.

    Folds the paper's three signal groups: workstation load (events processed per
    window ~ CPU load; pool occupancy ~ memory), network load (remote message ratio),
    and agent load (#LPs hosted).
    """
    c = counters.astype(jnp.float32)
    windows = jnp.maximum(c[C_WINDOWS], 1.0)
    events_per_window = c[C_EVENTS] / windows
    remote_ratio = c[C_MSGS_REMOTE] / jnp.maximum(c[C_EVENTS], 1.0)
    return (events_per_window
            + 4.0 * remote_ratio
            + 0.5 * n_owned_lps.astype(jnp.float32)
            + 2.0 * pool_occupancy.astype(jnp.float32))
