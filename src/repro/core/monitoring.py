"""In-graph monitoring — the LISA adaptation (paper §4.1).

The paper couples the simulation with the LISA monitoring system so the scheduler can
read "the load of the physical workstation ... the load of the network ... and also
the load of the agents (number of logical processes already executing, what components
are already duplicated locally)". Here the same signals are JAX arrays carried through
the superstep: a per-agent counter vector plus derived *performance values*.

Counters are per-agent and local (never auto-synced); ``gather_counters`` exposes the
fleet view to the scheduler and to ``ft.straggler``.

The host-streaming observability layer also lives here (paper §4.1's LISA
coupling, MONARC's dedicated monitoring layer): :class:`TraceStream` is the
host sink of the engine's device-side trace-ring drain
(``jax.experimental.io_callback`` at window boundaries — see
docs/architecture.md, "Streaming trace"), and :class:`MetricsStream` turns the
per-window counter vectors into periodic JSON-lines snapshots named by the
registry's declared counter table.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

# Counter indices.
C_EVENTS = 0          # events processed
C_MSGS_REMOTE = 1     # events routed to another agent
C_STALE = 2           # stale (interrupted) flow-completion events — paper's Fig-2 driver
C_INTERRUPTS = 3      # bandwidth-share recomputations
C_JOBS_SUBMITTED = 4
C_JOBS_DONE = 5
C_FLOWS_STARTED = 6
C_FLOWS_DONE = 7
C_MB_TRANSFERRED = 8  # rounded to int MB
C_DROP_POOL = 9       # event-pool overflow
C_DROP_ROUTE = 10     # routing-buffer overflow
C_DROP_FLOW = 11      # flow-table overflow
C_DROP_QUEUE = 12     # job-queue overflow
C_WINDOWS = 13        # conservative windows executed (sync rounds)
C_MIGRATIONS = 14     # disk -> tape migrations
C_WRITES = 15         # storage writes
C_MB_WRITTEN = 16
C_LP_LOCAL = 17       # events destined to locally-owned LPs (scheduler locality signal)
C_EXEC_SPILL = 18     # safe events deferred past exec_cap to the next window
C_BATCH_EXEC = 19     # events executed through the grouped vectorized dispatch
C_BATCH_FALLBACK = 20  # conflicted events executed via the sequential fallback
C_BATCH_ROWS = 21     # component-table rows scattered by the batched merge
C_TRACE_DROP = 22     # trace records lost to the fixed-cap trace buffer; any
                      # nonzero value makes trace-based oracle comparisons
                      # invalid, so oracle.merged_engine_trace refuses to
                      # return a truncated trace (fails loudly instead)
C_RING_WRAP = 23      # free-ring cursor wraps (head on insert, tail on release)
C_POOL_OCC = 24       # GAUGE: live pool slots at window end (occupancy)
C_POOL_FREE = 25      # GAUGE: free pool slots at window end (insert headroom)
C_MIGRATE_OUT = 26    # pending events shipped to another agent by placement
                      # migration (post route-cap; route overflow is
                      # C_DROP_ROUTE as everywhere)
C_MIGRATE_IN = 27     # migrated events received from another agent (counted
                      # pre-insert, so sum(out) == sum(in) globally; receiving
                      # pool overflow lands in C_DROP_POOL, never silent)
C_PREEMPT = 28        # FLEET: shard-loss preemptions observed by the
                      # orchestrator (host-side, never bumped in-graph)
C_RESUME = 29         # FLEET: automatic checkpoint resumes completed
C_RESHARD = 30        # FLEET: resumes that repacked onto a different
                      # device count (the unpadded-checkpoint reshard path)
N_COUNTERS = 31

DROP_COUNTERS = (C_DROP_POOL, C_DROP_ROUTE, C_DROP_FLOW, C_DROP_QUEUE)

# Fleet-orchestration counters: booked host-side by repro.fleet.Orchestrator
# (MetricsStream.book) and surfaced in its emitted records — NEVER bumped
# in-graph, so they are zero in any single engine run's counter state. That
# is deliberate: a preempted-and-resumed run's EngineState stays byte-
# identical to the uninterrupted run's, preemption bookkeeping included.
FLEET_COUNTERS = (C_PREEMPT, C_RESUME, C_RESHARD)

# Gauges: overwritten (not accumulated) every window — the pool-lifecycle
# occupancy signals the adaptive exec policy (core/policy.py) reads alongside
# the C_EXEC_SPILL / C_BATCH_ROWS rates.
GAUGE_COUNTERS = (C_POOL_OCC, C_POOL_FREE)

# Pool-lifecycle diagnostics: the only counters allowed to differ between the
# ring insert path and the retained insert_ref scan path of one scenario
# (the ref path never touches the ring cursors, so it never wraps them).
POOL_DIAG_COUNTERS = (C_RING_WRAP,)

# The engine-infrastructure counters every Registry starts with, in index
# order (Registry.__init__ seeds its counter table from this tuple, so the
# C_* constants above are the indices the registry assigns). Extensions
# declare additional counters with ``Registry.counter(name)`` — see
# docs/scenario_api.md — and size the engine's counter vector through
# ``Registry.n_counters``.
BUILTIN_COUNTERS = (
    ("EVENTS", "events processed (all execution paths)"),
    ("MSGS_REMOTE", "emits routed to another agent"),
    ("STALE", "stale (interrupted) flow-completion events — the paper's "
              "Fig-2 cost driver"),
    ("INTERRUPTS", "bandwidth-share recomputations (max-min refair)"),
    ("JOBS_SUBMITTED", "jobs accepted by a compute farm"),
    ("JOBS_DONE", "jobs completed"),
    ("FLOWS_STARTED", "WAN transfers started"),
    ("FLOWS_DONE", "WAN transfers completed"),
    ("MB_TRANSFERRED", "completed-flow megabytes (rounded to int)"),
    ("DROP_POOL", "event-pool overflow (including oversubscribed init "
                  "seeds)"),
    ("DROP_ROUTE", "routing-buffer overflow"),
    ("DROP_FLOW", "flow-table overflow (flow start refused)"),
    ("DROP_QUEUE", "job-queue overflow (job refused)"),
    ("WINDOWS", "conservative windows executed (collective sync rounds)"),
    ("MIGRATIONS", "disk -> tape migrations"),
    ("WRITES", "storage writes"),
    ("MB_WRITTEN", "written megabytes (rounded to int)"),
    ("LP_LOCAL", "emits destined to locally-owned LPs (scheduler locality "
                 "signal)"),
    ("EXEC_SPILL", "safe events deferred past exec_cap to the next window"),
    ("BATCH_EXEC", "events executed through the grouped vectorized dispatch"),
    ("BATCH_FALLBACK", "conflicted events executed via the sequential "
                       "fallback"),
    ("BATCH_ROWS", "component-table rows scattered by the batched merge — "
                   "the per-window scatter-volume signal for the adaptive "
                   "exec width"),
    ("TRACE_DROP", "trace records lost to the fixed-cap trace buffer, or "
                   "overwritten un-drained ring rows under streaming; "
                   "oracle.merged_engine_trace and TraceStream refuse a "
                   "truncated trace, so oracle-equivalence checks fail "
                   "loudly instead of passing on a prefix"),
    ("RING_WRAP", "free-ring cursor wraps (head on insert, tail on release) "
                  "— pool-recycling pressure"),
    ("POOL_OCC", "live pool slots at window end — the saturation signal the "
                 "adaptive exec policy grows on"),
    ("POOL_FREE", "free pool slots at window end (insert headroom)"),
    ("MIGRATE_OUT", "events shipped to another agent by a placement change "
                    "(donor side, post route-cap)"),
    ("MIGRATE_IN", "migrated events received (counted pre-insert, so "
                   "sum(OUT) == sum(IN) globally even when the receiving "
                   "pool overflows — the excess then lands in DROP_POOL on "
                   "the receiver)"),
    ("PREEMPT", "shard-loss preemptions the fleet orchestrator detected "
                "(injected probe or a process death discovered at restart)"),
    ("RESUME", "automatic checkpoint resumes the orchestrator completed "
               "after a preemption"),
    ("RESHARD", "resumes that repacked the unpadded checkpoint onto a "
                "different device count than it was saved from"),
)
assert len(BUILTIN_COUNTERS) == N_COUNTERS

# Dispatch-path diagnostics: the only counters allowed to differ between the
# batched and the sequential execution of the same scenario (everything else
# is byte-identical by the batched-dispatch equivalence contract).
# C_BATCH_ROWS measures the per-window scatter volume of the delta merge —
# the load signal the adaptive-exec_cap ROADMAP item keys on (a window that
# scatters few rows relative to exec_cap has headroom to grow the window).
BATCH_DIAG_COUNTERS = (C_BATCH_EXEC, C_BATCH_FALLBACK, C_BATCH_ROWS)


def zero_counters(n: int | None = None) -> jax.Array:
    """A zero counter vector. ``n`` sizes it for extended registries
    (``Registry.n_counters``); the default is the builtin width."""
    return jnp.zeros((N_COUNTERS if n is None else n,), jnp.int32)


def bump(counters: jax.Array, idx: int, amount=1) -> jax.Array:
    return counters.at[idx].add(jnp.asarray(amount, jnp.int32))


def gauge(counters: jax.Array, idx: int, value) -> jax.Array:
    """Overwrite a gauge counter (per-window level, not an accumulation)."""
    return counters.at[idx].set(jnp.asarray(value, jnp.int32))


def gather_counters(counters: jax.Array,
                    axis: str | tuple[str, ...] | None) -> jax.Array:
    """(A, N_COUNTERS) fleet view (identity reshape when single-agent).

    ``axis`` may be a (shard, lane) tuple for the shard_map x vmap driver
    (engine.ShardAxes agent packing): ``all_gather`` rejects mixed-axis
    tuples, so the gather is staged innermost-first — lanes, then shards —
    which flattens to the shard-major global agent order (== the global
    agent id ``lax.axis_index((shard, lane))`` yields)."""
    if axis is None:
        return counters[None]
    if isinstance(axis, (tuple, list)):
        out = counters
        for name in reversed(axis):
            out = jax.lax.all_gather(out, name)
        return out.reshape((-1,) + counters.shape)
    return jax.lax.all_gather(counters, axis)


def performance_value(counters: jax.Array, n_owned_lps: jax.Array,
                      pool_occupancy: jax.Array) -> jax.Array:
    """Scalar performance value an agent publishes (paper §4.1). Higher == worse.

    Folds the paper's three signal groups: workstation load (events processed per
    window ~ CPU load; pool occupancy ~ memory), network load (remote message ratio),
    and agent load (#LPs hosted).
    """
    c = counters.astype(jnp.float32)
    windows = jnp.maximum(c[C_WINDOWS], 1.0)
    events_per_window = c[C_EVENTS] / windows
    remote_ratio = c[C_MSGS_REMOTE] / jnp.maximum(c[C_EVENTS], 1.0)
    return (events_per_window
            + 4.0 * remote_ratio
            + 0.5 * n_owned_lps.astype(jnp.float32)
            + 2.0 * pool_occupancy.astype(jnp.float32))


# ------------------------------------------------------- host-streaming layer
def counter_class(idx: int) -> str:
    """The counter class of a builtin index: how a fleet snapshot should read
    it (``gauge`` = per-window level, everything else accumulates) and which
    equivalence contracts exempt it (``pool-diag`` / ``batch-diag``)."""
    if idx in GAUGE_COUNTERS:
        return "gauge"
    if idx in DROP_COUNTERS:
        return "drop"
    if idx in POOL_DIAG_COUNTERS:
        return "pool-diag"
    if idx in BATCH_DIAG_COUNTERS:
        return "batch-diag"
    if idx in FLEET_COUNTERS:
        return "fleet"
    return "counter"


def snapshot(counters, registry=None) -> dict:
    """Named view of a counter vector: ``{counter name: int total}``.

    ``counters`` is an (n,) vector or an (A, n) stacked fleet (summed over
    agents — gauges included, so a gauge reads as the fleet-total level).
    ``registry`` supplies the name table for extended models; the default is
    the builtin table.
    """
    names = (registry.counters if registry is not None
             else {name: i for i, (name, _doc) in enumerate(BUILTIN_COUNTERS)})
    c = np.asarray(counters)
    if c.ndim == 2:
        c = c.sum(axis=0)
    return {name: int(c[i]) for name, i in names.items()}


class TraceStream:
    """Host sink for the engine's device-side trace-ring drain.

    The engine appends processed-event rows ``(time, seq, kind, dst)`` to a
    per-agent ring of ``trace_cap`` rows and, at window boundaries, ships the
    un-drained span ``[tail, trace_n)`` through an unordered
    ``jax.experimental.io_callback`` tagged with the global agent id and the
    span start. Tagged spans are order-independent and idempotent, so callback
    arrival order (and duplicate delivery) cannot corrupt the stream: segments
    key on ``(agent, start)`` and reassembly verifies contiguous coverage of
    ``[0, trace_n)`` per agent. ``merged()`` reproduces
    ``oracle.merged_engine_trace`` — global (time, seq) order over all agents,
    shard-major under the distributed driver (the global agent id *is* the
    shard-major state row) — byte-identical to the sequential heapq oracle
    whenever ``C_TRACE_DROP == 0``.
    """

    def __init__(self):
        self._segments: dict[int, dict[int, np.ndarray]] = {}
        self._trace_n: np.ndarray | None = None
        self._resume: dict[int, dict[int, np.ndarray]] | None = None

    def begin(self, n_agents: int) -> None:
        """Reset for a run of ``n_agents`` (the engine calls this).

        If :meth:`load_state` staged checkpointed spans, they seed the
        segment map instead of an empty one — a resumed run's ring only
        re-drains ``[trace_tail, ...)``, so the pre-checkpoint prefix must
        come from the checkpoint for coverage of ``[0, trace_n)`` to close."""
        self.n_agents = n_agents
        self._segments = self._resume if self._resume is not None else {}
        self._resume = None
        self._trace_n = None

    # --------------------------------------------------- checkpoint support
    def state_dict(self) -> dict[str, np.ndarray]:
        """Drained spans as flat serializable arrays (``"<agent>/<start>"``
        keys) — what :class:`repro.checkpoint.SimCheckpointer` persists
        alongside the EngineState (call after ``jax.effects_barrier()``)."""
        return {f"{a}/{start}": seg
                for a, spans in self._segments.items()
                for start, seg in spans.items()}

    def load_state(self, segments: dict[str, np.ndarray]) -> None:
        """Stage checkpointed spans for the next ``begin()`` (restore path)."""
        staged: dict[int, dict[int, np.ndarray]] = {}
        for key, seg in segments.items():
            a, start = key.split("/")
            staged.setdefault(int(a), {})[int(start)] = np.asarray(seg)
        self._resume = staged

    def on_drain(self, agent, start, count, ring) -> None:
        """The io_callback target: one drained span of one agent's ring.

        ``ring`` is the raw (cap, 4) ring; rows are unrolled from positions
        ``(start + i) % cap``. A ``count`` of 0 (nothing pending, or a pad
        agent under the distributed driver) is a no-op.
        """
        agent = np.asarray(agent)
        if agent.ndim:  # batched delivery: unroll per lane
            for i in range(agent.shape[0]):
                self.on_drain(agent[i], np.asarray(start)[i],
                              np.asarray(count)[i], np.asarray(ring)[i])
            return
        n = int(count)
        if n <= 0:
            return
        ring = np.asarray(ring)
        idx = (int(start) + np.arange(n)) % ring.shape[0]
        self._segments.setdefault(int(agent), {})[int(start)] = ring[idx].copy()

    def finalize(self, trace, trace_n, trace_tail) -> None:
        """Flush the never-drained tail spans out of a finished EngineState
        and record the per-agent row counts (the engine calls this after
        ``jax.effects_barrier()``)."""
        trace = np.asarray(trace)
        self._trace_n = np.asarray(trace_n).copy()
        tail = np.asarray(trace_tail)
        for a in range(trace.shape[0]):
            n = int(self._trace_n[a]) - int(tail[a])
            if n > 0:
                idx = (int(tail[a]) + np.arange(n)) % trace.shape[1]
                self._segments.setdefault(a, {})[int(tail[a])] = (
                    trace[a, idx].copy())

    @property
    def n_streamed(self) -> int:
        """Total rows streamed (requires ``finalize``)."""
        if self._trace_n is None:
            raise RuntimeError("TraceStream not finalized — run the engine "
                               "with the stream attached first")
        return int(self._trace_n.sum())

    def agent_rows(self, agent: int) -> np.ndarray:
        """Agent's full (trace_n, 4) trace, reassembled from drained spans.

        Raises if the spans do not contiguously cover ``[0, trace_n)`` — a
        lost callback or an overwritten (dropped) span; ``C_TRACE_DROP``
        counts the latter.
        """
        if self._trace_n is None:
            raise RuntimeError("TraceStream not finalized — run the engine "
                               "with the stream attached first")
        n = int(self._trace_n[agent])
        segs = self._segments.get(agent, {})
        out, pos = [], 0
        for start in sorted(segs):
            seg = segs[start]
            if start != pos:
                raise RuntimeError(
                    f"trace stream gap for agent {agent}: have rows "
                    f"[0, {pos}), next span starts at {start}")
            out.append(seg)
            pos += seg.shape[0]
        if pos != n:
            raise RuntimeError(
                f"trace stream incomplete for agent {agent}: streamed {pos} "
                f"of {n} rows")
        if not out:
            return np.zeros((0, 4), np.int32)
        return np.concatenate(out, axis=0)

    def merged(self) -> list:
        """Global (time, seq)-ordered trace — ``merged_engine_trace``'s exact
        shape: a list of ``(time, seq, kind, dst)`` int tuples."""
        rows = []
        assert self._trace_n is not None
        for a in range(self._trace_n.shape[0]):
            rows.extend(tuple(int(x) for x in r) for r in self.agent_rows(a))
        rows.sort(key=lambda r: (r[0], r[1]))
        return rows


class MetricsStream:
    """Periodic fleet metrics snapshots fed by the registry counter table.

    The engine ships every agent's ``(window, gvt, counters)`` through the
    same window-boundary io_callback path as the trace drain; once all agents
    of a window whose index is a multiple of ``interval`` have reported, one
    JSON line lands on ``out`` (and in ``self.lines``):

        {"window": W, "gvt": T, "agents": A, "counters": {name: total}}

    Counter names and order come from the registry declaration (extension
    counters included); ``counter_class``/``Registry.counter_docs`` give the
    class and docstring of each name for richer consumers. A final snapshot
    (``"final": true``) is emitted when the run finishes, whatever the
    cadence.
    """

    def __init__(self, interval: int = 32, out=None):
        if interval <= 0:
            raise ValueError(f"interval must be positive, got {interval}")
        self.interval = int(interval)
        self.out = out
        self.lines: list[dict] = []
        self.latest: dict | None = None
        self._booked: dict[str, int] = {}
        self._resume: list[dict] | None = None

    def begin(self, n_agents: int, registry=None) -> None:
        """Reset for a run (the engine calls this with its registry).

        If :meth:`load_state` staged checkpointed records, they seed
        ``self.lines`` instead of an empty list (without re-writing them to
        ``out``) — a resumed run only emits records for post-checkpoint
        windows, so the pre-checkpoint prefix must come from the checkpoint
        for the record sequence to concatenate exactly onto an uninterrupted
        run's. ``_booked`` fleet counters deliberately survive the reset:
        they are host-side orchestration bookkeeping that spans engine runs.
        """
        self.n_agents = n_agents
        self._names = (registry.counters if registry is not None else {
            name: i for i, (name, _doc) in enumerate(BUILTIN_COUNTERS)})
        self._pending: dict[int, dict[int, tuple]] = {}
        self.lines = list(self._resume) if self._resume is not None else []
        self._resume = None
        self.latest = self.lines[-1] if self.lines else None

    # --------------------------------------------------- checkpoint support
    def state_dict(self) -> dict[str, np.ndarray]:
        """Emitted interval records as one serializable array (what
        :class:`repro.checkpoint.SimCheckpointer` persists alongside the
        EngineState; call after ``jax.effects_barrier()``). Mid-run there is
        no final record yet, so the checkpoint holds exactly the interval
        prefix a resumed run must not re-emit."""
        payload = json.dumps(self.lines).encode("utf-8")
        return {"lines": np.frombuffer(payload, dtype=np.uint8).copy()}

    def load_state(self, arrays: dict[str, np.ndarray]) -> None:
        """Stage checkpointed records for the next ``begin()`` (restore)."""
        payload = bytes(np.asarray(arrays["lines"]).tobytes())
        self._resume = json.loads(payload.decode("utf-8"))

    # ------------------------------------------------ fleet-counter overlay
    def book(self, name: str, amount: int = 1) -> None:
        """Accumulate a host-side counter into every later emitted record.

        The fleet orchestrator's preemption bookkeeping (``C_PREEMPT`` /
        ``C_RESUME`` / ``C_RESHARD``) cannot live in the in-graph counter
        vector — a resumed EngineState must stay byte-identical to the
        uninterrupted run's — so it lands here and is added to the named
        column of each record at emit time."""
        self._booked[name] = self._booked.get(name, 0) + int(amount)

    def on_window(self, agent, window, gvt, counters) -> None:
        """The io_callback target: one agent's end-of-window counter vector."""
        agent = np.asarray(agent)
        if agent.ndim:
            for i in range(agent.shape[0]):
                self.on_window(agent[i], np.asarray(window)[i],
                               np.asarray(gvt)[i], np.asarray(counters)[i])
            return
        a, w = int(agent), int(window)
        if a >= self.n_agents or w % self.interval:
            return
        got = self._pending.setdefault(w, {})
        got[a] = (int(gvt), np.asarray(counters).copy())
        if len(got) == self.n_agents:
            self._emit(w, self._pending.pop(w))

    def _emit(self, window: int, got: dict, final: bool = False) -> None:
        total = np.sum([c for _gvt, c in got.values()], axis=0)
        rec = {
            "window": window,
            "gvt": max(g for g, _c in got.values()),
            "agents": self.n_agents,
            "counters": {name: int(total[i])
                         for name, i in self._names.items()},
        }
        for name, v in self._booked.items():
            if name in rec["counters"]:
                rec["counters"][name] += v
        if final:
            rec["final"] = True
        self.latest = rec
        self.lines.append(rec)
        if self.out is not None:
            self.out.write(json.dumps(rec) + "\n")
            self.out.flush()

    def finalize(self, counters, windows, t_now) -> None:
        """Emit the end-of-run snapshot from the finished EngineState."""
        counters = np.asarray(counters)
        windows = np.asarray(windows)
        t_now = np.asarray(t_now)
        got = {a: (int(t_now[a]), counters[a])
               for a in range(min(self.n_agents, counters.shape[0]))}
        self._emit(int(windows[0]), got, final=True)

    # ------------------------------------------------------ ensemble support
    def ensemble(self, seeds, counters, windows, t_now) -> dict:
        """Reduce an ``Engine.run_ensemble`` result into the stream.

        ``counters`` is the (R, A, N) stacked counter table of R replicas;
        each replica's per-agent vectors sum to its fleet totals, stored as
        ``self.replica_counters`` (R, N) with ``self.replica_seeds`` — the
        per-replica books stay individually recoverable via
        :meth:`replica`. One summary JSON line (min/mean/max over replicas
        per counter, plus the ensemble-wide totals) lands on ``out`` /
        ``self.lines`` in the usual snapshot shape."""
        seeds = np.asarray(seeds)
        counters = np.asarray(counters)
        windows = np.asarray(windows)
        t_now = np.asarray(t_now)
        self.replica_seeds = seeds.copy()
        self.replica_counters = counters.sum(axis=1)  # (R, N): sum over agents
        total = self.replica_counters.sum(axis=0)
        rec = {
            "ensemble": int(seeds.shape[0]),
            "agents": self.n_agents,
            "windows": [int(windows.min()), int(windows.max())],
            "gvt": [int(t_now.min()), int(t_now.max())],
            "counters": {name: int(total[i])
                         for name, i in self._names.items()},
            "per_replica": {
                name: {"min": int(self.replica_counters[:, i].min()),
                       "mean": float(self.replica_counters[:, i].mean()),
                       "max": int(self.replica_counters[:, i].max())}
                for name, i in self._names.items()},
        }
        self.latest = rec
        self.lines.append(rec)
        if self.out is not None:
            self.out.write(json.dumps(rec) + "\n")
            self.out.flush()
        return rec

    def replica(self, r: int) -> dict:
        """One replica's fleet-total counters by name (post-``ensemble``)."""
        return {name: int(self.replica_counters[r, i])
                for name, i in self._names.items()}
