"""Simulation components (paper §4.2), declared through the registry.

The paper models Grid systems from basic components — CPU units, network links,
database servers + mass-storage centers, regional centers — implemented as Java
objects whose state is replicated across agents through JavaSpaces (C4). Here every
component class is a structure-of-arrays table inside ``World``; replication is
literal (every agent holds the full table) and synchronization is owner-wins /
commutative-delta all-reduce at conservative-window boundaries (see ``sync_world``).

Since PR 4 the four built-in component tables, the event kinds, and every
engine table derived from them (``World``, ``WorldDelta`` + ``DELTA_SCHEMA``,
``KIND_TABLE``, the owner-wins sync lists, ``WorldOwnership``, the builder's
``add_*`` methods) are **generated** by :mod:`repro.core.registry` from the
declarations in :func:`register_builtin_model` below — the hand-written
structs of PR 3 are now the generated output, pinned byte-identical by
``tests/test_registry.py`` and the ``tools/check_api.py`` drift gate. New
component types register the same way on ``BUILTIN.extend()`` with zero edits
here (see ``repro/scenarios/cache.py`` and ``docs/scenario_api.md``).

Logical processes (C1) own component rows: ``lp_res`` maps an LP to its resource row
(farm / network region / storage / generator). The paper's five LP lifecycle states
(§4.3: created, ready, running, waiting, finished) are kept as a data column — under
SPMD they are window-granular annotations, not thread states (see DESIGN.md §3).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.registry import (  # noqa: F401  (re-exported public surface)
    LPS_CREATED, LPS_FINISHED, LPS_READY, LPS_RUNNING, LPS_WAITING, PAYLOAD,
    FieldSpec, PayloadSpec, Registry, ScenarioBuilderBase, ScenarioSpec,
    registry_of)

MAXHOP = 3  # max links on a flow route


def register_builtin_model(reg: Registry) -> dict:
    """Declare the paper's four basic components and eight event kinds.

    This is the *entire* hand-maintained model description: everything the
    engine consumes (World/WorldDelta structs, KIND_TABLE, sync lists,
    builder methods, dispatch table slots) is generated from it. Handlers
    attach in ``handlers.register_builtin_handlers``. ``tools/check_api.py``
    re-runs this against a fresh registry to catch drift in core's exports.
    """
    reg.dim("max_cpu", 16)
    reg.dim("queue_cap", 32)
    reg.dim("max_link", 8)
    reg.dim("max_flow", 64)

    farm = reg.component("farm", doc="compute farm: CPU units + FIFO job queue", fields=dict(
        cpu_power=FieldSpec(("max_cpu",), jnp.float32, doc="ops/tick; 0 => slot absent"),
        cpu_busy=FieldSpec(("max_cpu",), jnp.int32, mutable=True, doc="1 while a job runs"),
        cpu_mem=FieldSpec(("max_cpu",), jnp.float32, mutable=True, doc="memory used by the running job"),
        jobq=FieldSpec(("queue_cap", 6), jnp.float32, mutable=True, doc="queued [work, mem, nlp, nkind, size, _]"),
        jobq_n=FieldSpec((), jnp.int32, mutable=True, doc="queue occupancy"),
    ))
    net = reg.component("net", doc="network region: links + flows (interrupt-based traffic model, C5)", fields=dict(
        link_bw=FieldSpec(("max_link",), jnp.float32, doc="MB/tick; 0 => absent"),
        link_lat=FieldSpec(("max_link",), jnp.int32, doc="ticks"),
        flow_active=FieldSpec(("max_flow",), jnp.bool_, mutable=True),
        flow_rem=FieldSpec(("max_flow",), jnp.float32, mutable=True, doc="MB remaining"),
        flow_rate=FieldSpec(("max_flow",), jnp.float32, mutable=True, doc="MB/tick (current fair share)"),
        flow_tlast=FieldSpec(("max_flow",), jnp.int32, mutable=True, doc="last progress timestamp"),
        flow_links=FieldSpec(("max_flow", MAXHOP), jnp.int32, mutable=True, fill=-1, doc="route; -1 pads"),
        flow_notify=FieldSpec(("max_flow", 6), jnp.float32, mutable=True, doc="[nlp, nkind, work, size, n2lp, n2kind]"),
        net_gen=FieldSpec((), jnp.int32, mutable=True, doc="interrupt generation counter"),
    ))
    sto = reg.component("sto", doc="storage: db server disk + mass-storage tape", fields=dict(
        sto_cap=FieldSpec((2,), jnp.float32, doc="[disk, tape] capacity MB"),
        sto_used=FieldSpec((2,), jnp.float32, mutable=True, doc="[disk, tape] used MB"),
        sto_rate=FieldSpec((), jnp.float32, doc="tape migration MB/tick"),
        sto_flag=FieldSpec((), jnp.int32, mutable=True, doc="1 while a disk->tape migration is scheduled"),
    ))
    gen = reg.component("gen", doc='activity generator ("production / analysis" job sources)', fields=dict(
        gen_interval=FieldSpec((), jnp.int32, fill=1, doc="ticks between emissions"),
        gen_left=FieldSpec((), jnp.int32, mutable=True, doc="remaining emissions"),
        gen_target=FieldSpec((), jnp.int32, doc="destination LP for generated events"),
        gen_kind=FieldSpec((), jnp.int32, doc="kind of generated event"),
        gen_payload=FieldSpec((PAYLOAD,), jnp.float32, doc="template payload"),
    ))

    kinds = dict(
        NOOP=reg.kind("NOOP"),
        FLOW_START=reg.kind("FLOW_START", table="net", payload=PayloadSpec(
            "size", ("l0", -1), ("l1", -1), ("l2", -1),
            ("notify_lp", -1), "notify_kind", ("notify2_lp", -1),
            "notify2_kind")),
        FLOW_END=reg.kind("FLOW_END", table="net", payload=PayloadSpec("gen")),
        JOB_SUBMIT=reg.kind("JOB_SUBMIT", table="farm", payload=PayloadSpec(
            "work", "mem", ("notify_lp", -1), "notify_kind", "size")),
        JOB_END=reg.kind("JOB_END", table="farm", payload=PayloadSpec(
            "slot", "work", "mem", ("notify_lp", -1), "notify_kind", "size")),
        DATA_WRITE=reg.kind("DATA_WRITE", table="sto",
                            payload=PayloadSpec("size")),
        MIGRATE=reg.kind("MIGRATE", table="sto", payload=PayloadSpec("amount")),
        GEN_TICK=reg.kind("GEN_TICK", table="gen"),
    )
    return dict(farm=farm, net=net, sto=sto, gen=gen, **kinds)


# The builtin registry: the model every ``repro.core`` export derives from.
# Handler bodies live in handlers.py and attach lazily (deferred import), so
# this module stays importable without pulling the numeric kernels in.
BUILTIN = Registry()
BUILTIN.deferred_handler_modules.append("repro.core.handlers")
_DEFS = register_builtin_model(BUILTIN)

FARM, NET, STO, GEN = _DEFS["farm"], _DEFS["net"], _DEFS["sto"], _DEFS["gen"]
NOOP, FLOW_START, FLOW_END = (_DEFS["NOOP"], _DEFS["FLOW_START"],
                              _DEFS["FLOW_END"])
JOB_SUBMIT, JOB_END = _DEFS["JOB_SUBMIT"], _DEFS["JOB_END"]
DATA_WRITE, MIGRATE, GEN_TICK = (_DEFS["DATA_WRITE"], _DEFS["MIGRATE"],
                                 _DEFS["GEN_TICK"])

# LP kinds (generated: a component's lp_kind is its table id; 0 = idle).
LPK_IDLE = 0      # placeholder / finished LP slot
LPK_FARM = FARM.lp_kind
LPK_NET = NET.lp_kind
LPK_STORAGE = STO.lp_kind
LPK_GEN = GEN.lp_kind

# Event-kind ids + the kind -> table map (generated; events.py re-exports
# these under the historical ``events.K_*`` spellings).
K_NOOP = NOOP.id
K_FLOW_START = FLOW_START.id
K_FLOW_END = FLOW_END.id
K_JOB_SUBMIT = JOB_SUBMIT.id
K_JOB_END = JOB_END.id
K_DATA_WRITE = DATA_WRITE.id
K_MIGRATE = MIGRATE.id
K_GEN_TICK = GEN_TICK.id
N_KINDS = BUILTIN.n_kinds
KIND_TABLE = BUILTIN.kind_table
TBL_NONE = 0
TBL_FARM = FARM.table_id
TBL_NET = NET.table_id
TBL_STORAGE = STO.table_id
TBL_GEN = GEN.table_id
N_TABLES = BUILTIN.n_tables

# The generated structs (identical, field for field, to the PR 3 hand-written
# NamedTuples — pinned by tests/test_registry.py).
World = BUILTIN.world_struct()
WorldOwnership = BUILTIN.ownership_struct()


def sync_world(world, own, axis: str | None):
    """Owner-wins replication sync (C4: the JavaSpaces adaptation).

    Every row of every component table has exactly one owning agent (the agent of the
    LP that owns the resource). After a conservative window, only the owner holds the
    fresh row; an all-reduce of ``where(mine, row, 0)`` rebuilds the full table on all
    agents. Exact: one nonzero contribution + zeros per row. When ``axis`` is None the
    engine is single-agent and sync is the identity. The field lists are generated
    from the registry's ``FieldSpec.mutable`` declarations (``Registry.sync_world``),
    and the world's own registry is used — extended models sync their tables with
    zero edits here.
    """
    return registry_of(world).sync_world(world, own, axis)


# ---------------------------------------------------------------------------
# Scenario builder (host-side; produces a World + initial events + spec)
# ---------------------------------------------------------------------------


class ScenarioBuilder(ScenarioBuilderBase):
    """Imperative builder mirroring the paper's "regional center" modeling style.

    The generic machinery (``add_component`` + generated ``add_<component>``
    methods + ``build``) comes from the registry; this subclass binds the
    builtin model and keeps the ergonomic wrappers — list-based farm/net
    signatures, regional centers (fig 1), and the generator's initial
    GEN_TICK event.
    """

    _registry = BUILTIN

    def __init__(self, max_cpu: int = 16, queue_cap: int = 32,
                 max_link: int = 8, max_flow: int = 64):
        super().__init__(max_cpu=max_cpu, queue_cap=queue_cap,
                         max_link=max_link, max_flow=max_flow)

    # --- basic components -------------------------------------------------
    def add_farm(self, cpu_powers, ctx: int = 0) -> int:
        assert len(cpu_powers) <= self.max_cpu
        return self.add_component("farm", cpu_power=list(cpu_powers), ctx=ctx)

    def add_net_region(self, link_bws, link_lats, ctx: int = 0) -> int:
        assert len(link_bws) <= self.max_link
        return self.add_component("net", link_bw=list(link_bws),
                                  link_lat=list(link_lats), ctx=ctx)

    def add_storage(self, disk_cap: float, tape_cap: float, tape_rate: float,
                    ctx: int = 0) -> int:
        return self.add_component("sto", sto_cap=[disk_cap, tape_cap],
                                  sto_rate=tape_rate, ctx=ctx)

    def add_generator(self, target_lp: int, kind, payload, interval: int,
                      count: int, start: int = 0, ctx: int = 0) -> int:
        lp = self.add_component(
            "gen", gen_interval=interval, gen_left=count,
            gen_target=target_lp, gen_kind=getattr(kind, "id", kind),
            gen_payload=list(payload), ctx=ctx)
        self.add_event(time=start, kind=K_GEN_TICK, src=lp, dst=lp, ctx=ctx)
        return lp

    # --- regional-center convenience (fig 1) -------------------------------
    def add_regional_center(self, n_cpu: int, cpu_power: float, disk: float,
                            tape: float, tape_rate: float, ctx: int = 0):
        farm = self.add_farm([cpu_power] * n_cpu, ctx=ctx)
        sto = self.add_storage(disk, tape, tape_rate, ctx=ctx)
        return dict(farm=farm, storage=sto)
