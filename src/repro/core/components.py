"""Simulation components (paper §4.2) as replicated, vectorized state tables.

The paper models Grid systems from basic components — CPU units, network links,
database servers + mass-storage centers, regional centers — implemented as Java
objects whose state is replicated across agents through JavaSpaces (C4). Here every
component class is a structure-of-arrays table inside ``World``; replication is
literal (every agent holds the full table) and synchronization is owner-wins /
commutative-delta all-reduce at conservative-window boundaries (see ``sync_world``).

Logical processes (C1) own component rows: ``lp_res`` maps an LP to its resource row
(farm / network region / storage / generator). The paper's five LP lifecycle states
(§4.3: created, ready, running, waiting, finished) are kept as a data column — under
SPMD they are window-granular annotations, not thread states (see DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import events as ev

# LP kinds.
LPK_IDLE = 0      # placeholder / finished LP slot
LPK_FARM = 1      # compute farm: CPU units + job queue
LPK_NET = 2       # network region: links + flows (interrupt-based traffic model)
LPK_STORAGE = 3   # database server (disk) + mass storage (tape)
LPK_GEN = 4       # activity generator ("production / analysis" job sources)

# LP lifecycle states (paper §4.3).
LPS_CREATED = 0
LPS_READY = 1
LPS_RUNNING = 2
LPS_WAITING = 3
LPS_FINISHED = 4

MAXHOP = 3  # max links on a flow route


class World(NamedTuple):
    """All mutable simulation state. Replicated on every agent; synced per window."""

    # --- logical processes (C1) ---
    lp_kind: jax.Array    # i32 (NLP,)
    lp_agent: jax.Array   # i32 (NLP,)  placement map — the scheduler (C3) rewrites it
    lp_res: jax.Array     # i32 (NLP,)  resource row owned by this LP
    lp_state: jax.Array   # i32 (NLP,)  lifecycle state
    lp_lvt: jax.Array     # i32 (NLP,)  per-LP local virtual time
    lp_ctx: jax.Array     # i32 (NLP,)  simulation context (C6)

    # --- compute farms (CPU units + FIFO job queue) ---
    cpu_power: jax.Array  # f32 (NFARM, MAXCPU)  ops/tick; 0 => slot absent
    cpu_busy: jax.Array   # i32 (NFARM, MAXCPU)  1 while a job runs
    cpu_mem: jax.Array    # f32 (NFARM, MAXCPU)  memory used by the running job
    jobq: jax.Array       # f32 (NFARM, QCAP, 6) queued [work, mem, nlp, nkind, size, _]
    jobq_n: jax.Array     # i32 (NFARM,) queue occupancy

    # --- network regions (interrupt-based traffic model, C5) ---
    link_bw: jax.Array    # f32 (NNET, MAXLINK)  MB/tick; 0 => absent
    link_lat: jax.Array   # i32 (NNET, MAXLINK)  ticks
    flow_active: jax.Array  # bool (NNET, MAXFLOW)
    flow_rem: jax.Array     # f32 (NNET, MAXFLOW)  MB remaining
    flow_rate: jax.Array    # f32 (NNET, MAXFLOW)  MB/tick (current fair share)
    flow_tlast: jax.Array   # i32 (NNET, MAXFLOW)  last progress timestamp
    flow_links: jax.Array   # i32 (NNET, MAXFLOW, MAXHOP)  route; -1 pads
    flow_notify: jax.Array  # f32 (NNET, MAXFLOW, 6) [nlp, nkind, work, size, n2lp, n2kind]
    net_gen: jax.Array      # i32 (NNET,) interrupt generation counter

    # --- storage (db server disk + mass-storage tape) ---
    sto_cap: jax.Array    # f32 (NSTO, 2)  [disk, tape] capacity MB
    sto_used: jax.Array   # f32 (NSTO, 2)  [disk, tape] used MB
    sto_rate: jax.Array   # f32 (NSTO,)    tape migration MB/tick
    sto_flag: jax.Array   # i32 (NSTO,)    1 while a disk->tape migration is scheduled

    # --- activity generators ---
    gen_interval: jax.Array  # i32 (NGEN,) ticks between emissions
    gen_left: jax.Array      # i32 (NGEN,) remaining emissions
    gen_target: jax.Array    # i32 (NGEN,) destination LP for generated events
    gen_kind: jax.Array      # i32 (NGEN,) kind of generated event
    gen_payload: jax.Array   # f32 (NGEN, ev.PAYLOAD) template payload

    @property
    def n_lp(self) -> int:
        return self.lp_kind.shape[-1]


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """Static (trace-time constant) facts about a built scenario."""

    n_agents: int
    n_ctx: int
    lookahead: int          # ticks; min event-generation delay (conservative window)
    t_end: int              # ticks; horizon after which the run stops
    pool_cap: int           # per-agent event-pool capacity
    emit_cap: int           # per-window emit-buffer capacity
    route_cap: int          # per-(src,dst)-agent routing-buffer capacity
    n_lp: int
    work_per_mb: float = 1.0  # CPU ops per transferred MB (job sizing)
    exec_cap: int = 256     # per-window execution-buffer capacity (compacted scan);
                            # safe events beyond it spill to the next window
    batched_dispatch: bool = True  # engine step 4: grouped vectorized dispatch
                                   # (False = PR 1 sequential compacted fold)
    merge_mode: str = "delta"      # batched-dispatch merge strategy:
                                   # "delta" = per-row segment scatters of the
                                   # handlers' declared rows, O(lanes x row);
                                   # "dense" = the PR 2 reference merge over
                                   # whole component tables, O(lanes x tables)
                                   # — kept for equivalence tests + benchmarks


def _owner_mask_rows(res_lp: jax.Array, lp_agent: jax.Array, me) -> jax.Array:
    """(N,) bool: rows whose owning LP is placed on this agent."""
    return lp_agent[res_lp] == me


class WorldOwnership(NamedTuple):
    """res -> LP inverse maps, built once per scenario (static shapes)."""

    farm_lp: jax.Array  # i32 (NFARM,)
    net_lp: jax.Array   # i32 (NNET,)
    sto_lp: jax.Array   # i32 (NSTO,)
    gen_lp: jax.Array   # i32 (NGEN,)


def sync_world(world: World, own: WorldOwnership, axis: str | None) -> World:
    """Owner-wins replication sync (C4: the JavaSpaces adaptation).

    Every row of every component table has exactly one owning agent (the agent of the
    LP that owns the resource). After a conservative window, only the owner holds the
    fresh row; an all-reduce of ``where(mine, row, 0)`` rebuilds the full table on all
    agents. Exact: one nonzero contribution + zeros per row. When ``axis`` is None the
    engine is single-agent and sync is the identity.
    """
    if axis is None:
        return world
    me = jax.lax.axis_index(axis)
    lp_mine = world.lp_agent == me
    farm_mine = _owner_mask_rows(own.farm_lp, world.lp_agent, me)
    net_mine = _owner_mask_rows(own.net_lp, world.lp_agent, me)
    sto_mine = _owner_mask_rows(own.sto_lp, world.lp_agent, me)
    gen_mine = _owner_mask_rows(own.gen_lp, world.lp_agent, me)

    def owner_wins(x, mask):
        m = mask.reshape(mask.shape + (1,) * (x.ndim - 1))
        if x.dtype == jnp.bool_:
            y = jax.lax.psum(jnp.where(m, x.astype(jnp.int32), 0), axis)
            return y > 0
        return jax.lax.psum(jnp.where(m, x, jnp.zeros((), x.dtype)), axis)

    return World(
        lp_kind=world.lp_kind,          # immutable after build
        lp_agent=world.lp_agent,        # rewritten only by the scheduler (replicated input)
        lp_res=world.lp_res,            # immutable after build
        lp_state=owner_wins(world.lp_state, lp_mine),
        lp_lvt=owner_wins(world.lp_lvt, lp_mine),
        lp_ctx=world.lp_ctx,            # immutable after build
        cpu_power=world.cpu_power,      # immutable after build
        cpu_busy=owner_wins(world.cpu_busy, farm_mine),
        cpu_mem=owner_wins(world.cpu_mem, farm_mine),
        jobq=owner_wins(world.jobq, farm_mine),
        jobq_n=owner_wins(world.jobq_n, farm_mine),
        sto_flag=owner_wins(world.sto_flag, sto_mine),
        link_bw=world.link_bw,          # immutable after build
        link_lat=world.link_lat,        # immutable after build
        flow_active=owner_wins(world.flow_active, net_mine),
        flow_rem=owner_wins(world.flow_rem, net_mine),
        flow_rate=owner_wins(world.flow_rate, net_mine),
        flow_tlast=owner_wins(world.flow_tlast, net_mine),
        flow_links=owner_wins(world.flow_links + 1, net_mine) - 1,  # -1 pad survives
        flow_notify=owner_wins(world.flow_notify, net_mine),
        net_gen=owner_wins(world.net_gen, net_mine),
        sto_cap=world.sto_cap,          # immutable after build
        sto_used=owner_wins(world.sto_used, sto_mine),
        sto_rate=world.sto_rate,        # immutable after build
        gen_interval=world.gen_interval,
        gen_left=owner_wins(world.gen_left, gen_mine),
        gen_target=world.gen_target,
        gen_kind=world.gen_kind,
        gen_payload=world.gen_payload,
    )


# ---------------------------------------------------------------------------
# Scenario builder (host-side; produces a World + initial events + spec)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ScenarioBuilder:
    """Imperative builder mirroring the paper's "regional center" modeling style.

    Regional centers (fig 1) are groupings of a farm + storage + a link to the WAN;
    the builder exposes them as convenience wrappers over the basic components.
    """

    max_cpu: int = 16
    queue_cap: int = 32
    max_link: int = 8
    max_flow: int = 64

    def __post_init__(self):
        self._lps: list[dict] = []       # kind, res, ctx
        self._farms: list[dict] = []
        self._nets: list[dict] = []
        self._stos: list[dict] = []
        self._gens: list[dict] = []
        self._events: list[dict] = []
        self._seq = 0

    # --- basic components -------------------------------------------------
    def _new_lp(self, kind: int, res: int, ctx: int) -> int:
        self._lps.append(dict(kind=kind, res=res, ctx=ctx))
        return len(self._lps) - 1

    def add_farm(self, cpu_powers, ctx: int = 0) -> int:
        assert len(cpu_powers) <= self.max_cpu
        self._farms.append(dict(powers=list(cpu_powers)))
        return self._new_lp(LPK_FARM, len(self._farms) - 1, ctx)

    def add_net_region(self, link_bws, link_lats, ctx: int = 0) -> int:
        assert len(link_bws) <= self.max_link
        self._nets.append(dict(bws=list(link_bws), lats=list(link_lats)))
        return self._new_lp(LPK_NET, len(self._nets) - 1, ctx)

    def add_idle_lp(self, ctx: int = 0) -> int:
        """A bare LP with no component row (LPK_IDLE): a NOOP event sink.

        Used by dispatch benchmarks/tests that want many distinct destination
        LPs without growing any component table, and as a placement target.
        """
        return self._new_lp(LPK_IDLE, 0, ctx)

    def add_storage(self, disk_cap: float, tape_cap: float, tape_rate: float,
                    ctx: int = 0) -> int:
        self._stos.append(dict(disk=disk_cap, tape=tape_cap, rate=tape_rate))
        return self._new_lp(LPK_STORAGE, len(self._stos) - 1, ctx)

    def add_generator(self, target_lp: int, kind: int, payload, interval: int,
                      count: int, start: int = 0, ctx: int = 0) -> int:
        self._gens.append(dict(target=target_lp, kind=kind, payload=list(payload),
                               interval=interval, count=count))
        lp = self._new_lp(LPK_GEN, len(self._gens) - 1, ctx)
        self.add_event(time=start, kind=ev.K_GEN_TICK, src=lp, dst=lp, ctx=ctx)
        return lp

    def add_event(self, *, time: int, kind: int, src: int, dst: int, payload=(),
                  ctx: int = 0):
        self._events.append(dict(time=time, seq=self._seq, kind=kind, src=src,
                                 dst=dst, payload=payload, ctx=ctx))
        self._seq += 1

    # --- regional-center convenience (fig 1) -------------------------------
    def add_regional_center(self, n_cpu: int, cpu_power: float, disk: float,
                            tape: float, tape_rate: float, ctx: int = 0):
        farm = self.add_farm([cpu_power] * n_cpu, ctx=ctx)
        sto = self.add_storage(disk, tape, tape_rate, ctx=ctx)
        return dict(farm=farm, storage=sto)

    # --- build -------------------------------------------------------------
    def build(self, *, n_agents: int = 1, n_ctx: int = 1, lookahead: int,
              t_end: int, pool_cap: int = 1024, emit_cap: int | None = None,
              route_cap: int | None = None, exec_cap: int | None = None,
              placement=None, work_per_mb: float = 1.0,
              batched_dispatch: bool = True, merge_mode: str = "delta"):
        nlp = max(len(self._lps), 1)
        nfarm = max(len(self._farms), 1)
        nnet = max(len(self._nets), 1)
        nsto = max(len(self._stos), 1)
        ngen = max(len(self._gens), 1)

        def arr(shape, dtype, fill=0):
            return jnp.full(shape, fill, dtype)

        lp_kind = jnp.asarray([l["kind"] for l in self._lps] or [0], jnp.int32)
        lp_res = jnp.asarray([l["res"] for l in self._lps] or [0], jnp.int32)
        lp_ctx = jnp.asarray([l["ctx"] for l in self._lps] or [0], jnp.int32)
        if placement is None:
            lp_agent = jnp.arange(nlp, dtype=jnp.int32) % n_agents
        else:
            lp_agent = jnp.asarray(placement, jnp.int32)

        cpu_power = arr((nfarm, self.max_cpu), jnp.float32)
        for i, f in enumerate(self._farms):
            cpu_power = cpu_power.at[i, : len(f["powers"])].set(
                jnp.asarray(f["powers"], jnp.float32))

        link_bw = arr((nnet, self.max_link), jnp.float32)
        link_lat = arr((nnet, self.max_link), jnp.int32)
        for i, nre in enumerate(self._nets):
            link_bw = link_bw.at[i, : len(nre["bws"])].set(
                jnp.asarray(nre["bws"], jnp.float32))
            link_lat = link_lat.at[i, : len(nre["lats"])].set(
                jnp.asarray(nre["lats"], jnp.int32))

        sto_cap = arr((nsto, 2), jnp.float32)
        sto_rate = arr((nsto,), jnp.float32)
        for i, s in enumerate(self._stos):
            sto_cap = sto_cap.at[i].set(jnp.asarray([s["disk"], s["tape"]], jnp.float32))
            sto_rate = sto_rate.at[i].set(s["rate"])

        gen_interval = arr((ngen,), jnp.int32, 1)
        gen_left = arr((ngen,), jnp.int32)
        gen_target = arr((ngen,), jnp.int32)
        gen_kind = arr((ngen,), jnp.int32)
        gen_payload = arr((ngen, ev.PAYLOAD), jnp.float32)
        for i, g in enumerate(self._gens):
            gen_interval = gen_interval.at[i].set(g["interval"])
            gen_left = gen_left.at[i].set(g["count"])
            gen_target = gen_target.at[i].set(g["target"])
            gen_kind = gen_kind.at[i].set(g["kind"])
            pl = jnp.asarray(g["payload"], jnp.float32)
            gen_payload = gen_payload.at[i, : pl.shape[0]].set(pl)

        world = World(
            lp_kind=lp_kind,
            lp_agent=lp_agent,
            lp_res=lp_res,
            lp_state=jnp.full((nlp,), LPS_READY, jnp.int32),
            lp_lvt=jnp.zeros((nlp,), jnp.int32),
            lp_ctx=lp_ctx,
            cpu_power=cpu_power,
            cpu_busy=arr((nfarm, self.max_cpu), jnp.int32),
            cpu_mem=arr((nfarm, self.max_cpu), jnp.float32),
            jobq=arr((nfarm, self.queue_cap, 6), jnp.float32),
            jobq_n=arr((nfarm,), jnp.int32),
            link_bw=link_bw,
            link_lat=link_lat,
            flow_active=jnp.zeros((nnet, self.max_flow), bool),
            flow_rem=arr((nnet, self.max_flow), jnp.float32),
            flow_rate=arr((nnet, self.max_flow), jnp.float32),
            flow_tlast=arr((nnet, self.max_flow), jnp.int32),
            flow_links=arr((nnet, self.max_flow, MAXHOP), jnp.int32, -1),
            flow_notify=arr((nnet, self.max_flow, 6), jnp.float32),
            net_gen=arr((nnet,), jnp.int32),
            sto_cap=sto_cap,
            sto_used=arr((nsto, 2), jnp.float32),
            sto_rate=sto_rate,
            sto_flag=arr((nsto,), jnp.int32),
            gen_interval=gen_interval,
            gen_left=gen_left,
            gen_target=gen_target,
            gen_kind=gen_kind,
            gen_payload=gen_payload,
        )

        def inverse_map(kind, n):
            out = [0] * n
            for lp, l in enumerate(self._lps):
                if l["kind"] == kind:
                    out[l["res"]] = lp
            return jnp.asarray(out, jnp.int32)

        own = WorldOwnership(
            farm_lp=inverse_map(LPK_FARM, nfarm),
            net_lp=inverse_map(LPK_NET, nnet),
            sto_lp=inverse_map(LPK_STORAGE, nsto),
            gen_lp=inverse_map(LPK_GEN, ngen),
        )

        spec = ScenarioSpec(
            n_agents=n_agents,
            n_ctx=n_ctx,
            lookahead=lookahead,
            t_end=t_end,
            pool_cap=pool_cap,
            emit_cap=emit_cap or pool_cap,
            route_cap=route_cap or max(pool_cap // max(n_agents, 1), 16),
            exec_cap=max(exec_cap if exec_cap is not None
                         else min(pool_cap, 256), 1),
            n_lp=nlp,
            work_per_mb=work_per_mb,
            batched_dispatch=batched_dispatch,
            merge_mode=merge_mode,
        )
        init_events = ev.batch_from_rows(self._events)
        return world, own, init_events, spec
