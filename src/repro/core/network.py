"""Interrupt-based network traffic model (paper §4.2).

The paper: "The proposed approach used to simulate the data traffic is again based on
the 'interrupt' scheme" — when a flow starts or ends, the fair share of every flow
crossing a shared link changes, and the predicted completion events of all affected
flows must be re-issued. This is exactly the mechanism behind Fig 2's super-linear
event growth at low bandwidth.

Bandwidth sharing across competing connections uses progressive filling (max–min
fairness), the standard model for "complex bandwidth sharing among competing network
connections" (§4.2). ``maxmin_rates`` is the jnp reference; the Pallas kernel in
``repro.kernels.bandwidth_share`` computes the same fixed point with VMEM tiling and
is validated against this function.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import events as ev
from repro.core.components import MAXHOP

_EPS = 1e-6
_BIG = jnp.float32(3.0e38)


def incidence(flow_links: jax.Array, n_links: int) -> jax.Array:
    """(F, MAXHOP) routes -> (F, L) 0/1 incidence. -1 hops are padding."""
    hops = flow_links[..., None] == jnp.arange(n_links, dtype=jnp.int32)  # (F,H,L)
    return jnp.any(hops, axis=-2).astype(jnp.float32)


def maxmin_rates(inc: jax.Array, bw: jax.Array, active: jax.Array) -> jax.Array:
    """Progressive-filling max–min fair rates.

    inc: (F, L) 0/1 flow-over-link incidence, bw: (L,) capacities (0 => absent link),
    active: (F,) bool. Returns (F,) rates; inactive flows get 0. At most L rounds are
    needed (each round freezes every flow crossing at least one bottleneck link).
    """
    F, L = inc.shape
    inc = inc * active[:, None].astype(inc.dtype)

    def round_(state, _):
        rate, frozen = state
        unfrozen = active & ~frozen
        n_unf = inc.T @ unfrozen.astype(jnp.float32)            # (L,)
        used = inc.T @ (rate * frozen.astype(jnp.float32))      # (L,)
        resid = jnp.maximum(bw - used, 0.0)
        fair = jnp.where(n_unf > 0, resid / jnp.maximum(n_unf, 1.0), _BIG)
        # links with no capacity but unfrozen flows: fair share 0 (starved flows)
        fair = jnp.where((bw <= 0) & (n_unf > 0), 0.0, fair)
        level = jnp.min(fair)                                   # global bottleneck level
        bottleneck = fair <= level + _EPS                       # (L,)
        hits = (inc @ bottleneck.astype(jnp.float32)) > 0       # (F,)
        newly = unfrozen & hits
        rate = jnp.where(newly, level, rate)
        frozen = frozen | newly
        return (rate, frozen), None

    rate0 = jnp.zeros((F,), jnp.float32)
    frozen0 = ~active
    (rate, _), _ = jax.lax.scan(round_, (rate0, frozen0), None, length=L)
    return jnp.where(active, rate, 0.0)


def progress_flows(rem, rate, tlast, active, now):
    """Advance all active flows of a region to virtual time ``now``."""
    dt = jnp.maximum(now - tlast, 0).astype(jnp.float32)
    rem2 = jnp.where(active, jnp.maximum(rem - rate * dt, 0.0), rem)
    tlast2 = jnp.where(active, now, tlast)
    return rem2, tlast2


def completion_times(rem, rate, tlast, active):
    """(F,) predicted completion tick per flow (T_INF when idle or starved)."""
    ticks = jnp.where(rate > _EPS, jnp.ceil(rem / jnp.maximum(rate, _EPS)), _BIG)
    t_fin = tlast.astype(jnp.float32) + jnp.maximum(ticks, 1.0)
    t_fin = jnp.where(active, t_fin, _BIG)
    return jnp.minimum(t_fin, jnp.float32(ev.T_INF)).astype(jnp.int32)


def route_latency(flow_links_row: jax.Array, link_lat: jax.Array) -> jax.Array:
    """Total propagation latency of a route (sum over real hops)."""
    valid = flow_links_row >= 0
    lat = link_lat[jnp.clip(flow_links_row, 0, link_lat.shape[0] - 1)]
    return jnp.sum(jnp.where(valid, lat, 0))
