"""Simulation contexts (paper §4.3, fig 9).

"Each simulation agent will execute a set of event schedulers in parallel ... no
object involved in one simulation run will affect other simulation objects involved
in other simulation runs."

Under SPMD the context factory degenerates to data: every LP and event carries a
``ctx`` id; GVT, horizons and termination are segment-reduced per context (sync.py),
so contexts advance independently while sharing the agent fleet — the paper's
utilization argument. Isolation is structural: handlers only touch resources of the
destination LP, and an LP belongs to exactly one context (asserted at build time by
tests). This module provides the bookkeeping helpers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import events as ev
from repro.core.components import ScenarioSpec, World


def ctx_event_counts(pool: ev.EventPool, n_ctx: int) -> jax.Array:
    """(n_ctx,) pending events per context on this agent."""
    seg = jnp.where(pool.valid, pool.ctx, n_ctx)
    return jnp.zeros((n_ctx,), jnp.int32).at[seg].add(
        pool.valid.astype(jnp.int32), mode="drop")


def ctx_done(gvt: jax.Array, t_end: int) -> jax.Array:
    """(n_ctx,) bool: which simulation runs have finished."""
    return (gvt >= jnp.int32(t_end)) | (gvt == ev.T_INF)


def ctx_lp_counts(world: World, n_ctx: int) -> jax.Array:
    """(n_ctx,) LPs per context (fleet-wide; world is replicated)."""
    return jnp.zeros((n_ctx,), jnp.int32).at[world.lp_ctx].add(1, mode="drop")


def validate_isolation(world: World) -> bool:
    """Host-side check: every resource row is referenced by LPs of a single ctx."""
    import numpy as np
    lp_res = np.asarray(world.lp_res)
    lp_kind = np.asarray(world.lp_kind)
    lp_ctx = np.asarray(world.lp_ctx)
    seen: dict[tuple[int, int], int] = {}
    for lp in range(lp_res.shape[0]):
        key = (int(lp_kind[lp]), int(lp_res[lp]))
        c = int(lp_ctx[lp])
        if key in seen and seen[key] != c:
            return False
        seen[key] = c
    return True
