"""Conservative synchronization (paper §4.3) — the CMB / null-message adaptation.

The paper's agents keep per-peer LVT queues and exchange *null messages on demand*:
an agent blocks until every peer's last-known LVT is >= the timestamp it wants to
process ("the simulation agents for whom the known LVT values are higher or equal
with the value of the timestamp are guaranteeing that will not produce events with
lower timestamps in the future"). The fixed point of that protocol is exactly the
global minimum of pending-event timestamps plus lookahead.

On a TPU fleet point-to-point null messages have no fast path; the ICI-native
equivalent is a single ``lax.pmin`` all-reduce per conservative window, which computes
the same bound in O(log A) hops. The paper's own observation — "instead of
synchronizing logical processes we are synchronizing the distributed simulation
agents altogether" — is what makes the collective formulation legal. Per-context
GVTs (C6) fall out of a segmented min before the collective.

This module also hosts the *intra-window* safety analysis for the engine's
batched dispatch: ``conflict_mask`` decides which safe events may execute in one
vectorized handler call. Its soundness rests on the delta contract stated in
``handlers.py`` — every handler reads and writes exactly the component row it
declares (``(events.KIND_TABLE[kind], lp_res[dst])``), so pairwise-distinct
declared rows imply pairwise-disjoint world reads/writes, and batched execution
is byte-identical to any sequential order of the same events (see
docs/architecture.md for the full argument).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import events as ev


def local_min_per_ctx(pool: ev.EventPool, n_ctx: int) -> jax.Array:
    """(n_ctx,) minimum pending timestamp on this agent per simulation context."""
    return ev.min_pending_time_per_ctx(pool, n_ctx)


def global_min(x: jax.Array, axis: str | tuple[str, ...] | None) -> jax.Array:
    """All-reduce min across agents — the collective null-message exchange.

    ``axis`` may be a tuple of axis names for the shard_map x vmap driver
    (mesh shard axis + in-shard lane axis): ``pmin`` reduces over both in one
    collective, so GVT is global across every packed agent."""
    if axis is None:
        return x
    return jax.lax.pmin(x, axis)


def horizons(gvt: jax.Array, lookahead: int, t_end: int) -> jax.Array:
    """Per-context safe horizon: every event strictly below it may execute.

    Correctness (DESIGN.md §5): all emit delays are >= lookahead, so any event still
    to be created lands at >= GVT + lookahead. Clamped to t_end (simulation stop).
    """
    h = jnp.where(gvt < ev.T_INF - lookahead, gvt + jnp.int32(lookahead), ev.T_INF)
    return jnp.minimum(h, jnp.int32(t_end))


def all_done(gvt: jax.Array, t_end: int) -> jax.Array:
    """True when every context has drained or passed the simulation horizon."""
    return jnp.all((gvt >= jnp.int32(t_end)) | (gvt == ev.T_INF))


def safe_mask(pool: ev.EventPool, horizon_per_ctx: jax.Array) -> jax.Array:
    """Events allowed to execute in this conservative window."""
    return pool.valid & (pool.time < horizon_per_ctx[pool.ctx])


def _dup_mask(key: jax.Array, active: jax.Array, n_keys: int) -> jax.Array:
    """True where ``key`` occurs more than once among ``active`` rows.

    Inactive rows are rewritten to per-row unique sentinels (>= n_keys) so they
    can never collide; a sort + equal-neighbour compare then marks every member
    of a duplicated group, scattered back to input order.
    """
    n = key.shape[0]
    pos = jnp.arange(n, dtype=jnp.int32)
    k = jnp.where(active, key, jnp.int32(n_keys) + pos)
    order = jnp.argsort(k)
    ks = k[order]
    eq = ks[1:] == ks[:-1]
    pad = jnp.zeros((1,), bool)
    dup_sorted = jnp.concatenate([pad, eq]) | jnp.concatenate([eq, pad])
    return jnp.zeros((n,), bool).at[order].set(dup_sorted)


def conflict_mask(safe: jax.Array, table_id: jax.Array, res: jax.Array, *,
                  n_res: int, n_tables: int | None = None) -> jax.Array:
    """Rows of a window whose handler writes may overlap another safe row's.

    Keys on *exactly the rows the delta contract declares* (handlers.py): the
    handler for kind ``k`` reads and writes one row of one component table —
    row ``lp_res[dst]`` of table ``events.KIND_TABLE[k]`` — so two safe rows
    conflict iff they address the same ``(table, resource-row)`` pair. Rows
    with ``table_id == 0`` (kinds that declare no component row, e.g. NOOP)
    never conflict — including duplicate-destination NOOPs, because the only
    state they share are the engine-owned per-LP columns, whose segment
    scatters commute (``lp_lvt`` is a max, the RUNNING mark is an idempotent
    constant set). This is strictly tighter than the PR 2 mask, which also
    flagged every duplicate destination LP regardless of what its handler
    writes.

    Conflict-free rows touch pairwise-disjoint component rows (the
    disjoint-write guarantee), so they execute in one vectorized batch whose
    per-row segment-scatter merge is byte-identical to the sequential fold.
    Conflicted rows take the engine's compacted sequential fallback.
    """
    if n_tables is None:
        n_tables = ev.N_TABLES   # the builtin model's table count
    rkey = table_id * jnp.int32(n_res) + res
    comp = safe & (table_id > 0)
    return safe & _dup_mask(rkey, comp, n_tables * n_res)


def exec_selection(safe: jax.Array, exec_idx: jax.Array):
    """Compacted-window execution masks (engine step 4).

    ``exec_idx`` is the (exec_cap,) safe-prefix of the per-window (time, seq)
    sort — distinct pool-slot indices with every safe slot ordered before any
    unsafe one. Returns ``(slot_mask, exec_safe)``: ``slot_mask`` marks the pool
    slots actually executed this window, ``exec_safe`` flags the executable rows
    of the gathered candidate buffer. Safe slots beyond ``exec_cap`` stay in the
    pool and spill to the next window; this is sound because they remain below
    the (unchanged) horizon, and GVT cannot advance past them while they are
    pending — conservative-window correctness is preserved, only window count
    grows.
    """
    exec_safe = safe[exec_idx]
    slot_mask = jnp.zeros_like(safe).at[exec_idx].set(exec_safe)
    return slot_mask, exec_safe


def exec_selection_ring(safe: jax.Array, exec_idx: jax.Array) -> jax.Array:
    """Execution flags over the ring-compacted candidates (engine step 4).

    The free-ring pool reclaims executed slots directly from ``(exec_idx,
    exec_safe)`` (``events.release`` — an O(exec_cap) scatter), so the
    per-window O(pool_cap) slot-mask build of :func:`exec_selection` is only
    needed by the retained ``insert_mode="ref"`` path. Same soundness
    argument: safe slots beyond ``exec_cap`` spill, stay below the horizon,
    and execute in a later window.
    """
    return safe[exec_idx]
