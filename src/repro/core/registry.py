"""Declarative component & handler registry — the scenario-authoring API.

The paper's pitch (§4.2) is a framework that "models very complex distributed
systems while hiding the computational effort from the end-user" through an
extensible component library. This module is that seam for the JAX engine:
instead of hand-editing six core files to add a component type, a model author
*declares* components, event kinds, and handlers, and the registry **generates**
every table the engine consumes:

  ``Registry.component(name, fields={...: FieldSpec(...)})``
      -> a structure-of-arrays table inside the generated ``World`` NamedTuple,
         a ``<name>_row`` column in the generated ``WorldDelta``, a
         ``<name>_lp`` inverse map in the generated ``WorldOwnership``, the
         owner-wins entries of ``sync_world``, and an ``add_<name>`` builder
         method.
  ``Registry.kind(name, table=..., payload=PayloadSpec(...))``
      -> an event-kind id, its row in the generated ``KIND_TABLE`` (what the
         conflict mask keys on), and a named payload view replacing magic
         index lists.
  ``@Registry.on(kind)``
      -> an entry in the generated ``lax.switch`` dispatch table.

The four built-in components (compute farm, network region, storage,
activity generator) are registered in ``components.py`` / ``handlers.py`` via
this same API — the hand-written ``World`` / ``WorldDelta`` NamedTuples of
PR 3 are now the *generated output*, pinned byte-identical by
``tests/test_registry.py`` and the ``tools/check_api.py`` drift gate. A new
component (see ``repro/scenarios/cache.py`` for a complete example) needs zero
edits inside core: ``BUILTIN.extend()`` gives a fresh registry that inherits
the built-ins, and every engine entry point (``Engine``, the oracle,
``sync_world``, ``apply_delta``) discovers the registry from the world/delta
*type* (``type(world)._registry``), so extended models run batched,
conflict-masked, and byte-identical to the sequential oracle automatically.

Handler contract: a registered handler has signature
``fn(env, world, counters, e) -> (delta, counters, EventBatch[MAX_EMIT])``
where ``env`` is a :class:`HandlerEnv` carrying the trace-time constants
(``env.delay`` clamps emit delays to the lookahead — the conservative-sync
invariant) and the validating delta constructor ``env.delta(...)`` which
enforces the delta contract (declared row + *every* mutable field of that
table, see handlers.py).
"""
from __future__ import annotations

import collections
import dataclasses
import importlib
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import monitoring as _mon

# Payload width: enough scalars for the richest built-in handler (flow start:
# size, route, two notify pairs). ``events.PAYLOAD`` re-exports this.
PAYLOAD = 8

# Sentinel row index meaning "this delta writes no row of that table".
# Out of bounds for every component table, so ``mode="drop"`` scatters skip it.
NO_ROW = jnp.int32(2**31 - 1)

# LP lifecycle states (paper §4.3) — engine infrastructure, not model state.
LPS_CREATED = 0
LPS_READY = 1
LPS_RUNNING = 2
LPS_WAITING = 3
LPS_FINISHED = 4

# The per-LP columns every generated World starts with (engine infrastructure;
# lp_state/lp_lvt are owner-wins synced, the rest are replicated inputs).
LP_FIELDS = ("lp_kind", "lp_agent", "lp_res", "lp_state", "lp_lvt", "lp_ctx")


class RegistryError(ValueError):
    """A scenario/model declaration violated the registry's rules."""


@dataclasses.dataclass(frozen=True)
class FieldSpec:
    """One column of a component table.

    ``shape`` is the *per-row* shape; entries may be ints or strings naming a
    builder dimension (declared with ``Registry.dim``) resolved at build time.
    ``mutable`` fields are the ones handlers may write — they enter the
    generated ``WorldDelta`` / ``DELTA_SCHEMA`` and the owner-wins sync list;
    immutable fields (topology, capacities) are replicated build-time inputs.
    ``fill`` is the initial/absent-row value (e.g. ``-1`` route padding).
    """

    shape: tuple
    dtype: Any
    mutable: bool = False
    fill: Any = 0
    doc: str = ""


class PayloadSpec:
    """Named, typed view of an event kind's payload scalars.

    Replaces magic index lists: ``spec.pack(size=40.0, notify_lp=f)`` builds
    the positional payload row with declared defaults for the rest. Fields are
    given as ``"name"`` (float32, default 0.0), ``("name", default)``
    (float32), or ``("name", default, dtype)`` — the **dtype view** (PR 5).

    The engine's payload storage is a flat float32 row; an ``int32`` field
    would historically round-trip through float32 *numerically* and silently
    lose precision beyond 2^24. Declaring ``("token", 0, jnp.int32)`` instead
    stores the int's raw bits reinterpreted as a float32 bit pattern
    (``lax.bitcast_convert_type`` in-graph, numpy views on the host): no
    arithmetic ever touches the value, and the engine only ever copies,
    gathers, and scatters payload bytes, so any 32-bit int — including the
    31-bit ids the registry tests pin — survives intact. Read typed fields
    back with :meth:`get` (which bitcasts int fields to int32); never read an
    int field positionally as a float.
    """

    def __init__(self, *fields):
        self.names: tuple[str, ...] = ()
        self.defaults: dict[str, Any] = {}
        self.dtypes: dict[str, Any] = {}
        for f in fields:
            if isinstance(f, str):
                name, default, dtype = f, 0.0, jnp.float32
            elif len(f) == 2:
                (name, default), dtype = f, jnp.float32
            else:
                name, default, dtype = f
            if not isinstance(name, str) or not name.isidentifier():
                raise RegistryError(f"payload field name {name!r} must be an "
                                    "identifier")
            if name in self.defaults:
                raise RegistryError(f"duplicate payload field {name!r}")
            dtype = jnp.dtype(dtype)
            if dtype not in (jnp.dtype(jnp.float32), jnp.dtype(jnp.int32)):
                raise RegistryError(
                    f"payload field {name!r} dtype must be float32 or int32 "
                    f"(a payload scalar is one 32-bit lane), got {dtype}")
            self.names += (name,)
            self.dtypes[name] = dtype
            self.defaults[name] = (int(default) if dtype == jnp.int32
                                   else float(default))
        if len(self.names) > PAYLOAD:
            raise RegistryError(
                f"payload has {len(self.names)} fields; the engine carries at "
                f"most PAYLOAD={PAYLOAD} scalars per event")

    def index(self, name: str) -> int:
        """Positional index of ``name`` in the payload row."""
        try:
            return self.names.index(name)
        except ValueError:
            raise RegistryError(f"unknown payload field {name!r}; "
                                f"declared: {self.names}") from None

    def _check_known(self, values):
        unknown = set(values) - set(self.names)
        if unknown:
            raise RegistryError(f"unknown payload field(s) {sorted(unknown)}; "
                                f"declared: {self.names}")

    def pack(self, **values) -> "np.ndarray":
        """Positional payload row from named values (declared defaults fill
        the rest). The builder pads it to ``PAYLOAD`` scalars.

        Host-side: returns a float32 numpy row. Int32 fields are encoded as
        raw bit patterns via numpy views — never through a Python float, whose
        float64 round-trip would quiet signaling-NaN bit patterns.
        """
        self._check_known(values)
        row = np.zeros((len(self.names),), np.float32)
        for i, n in enumerate(self.names):
            v = values.get(n, self.defaults[n])
            if self.dtypes[n] == jnp.int32:
                row[i] = np.asarray(int(v), np.int32).view(np.float32)
            else:
                row[i] = v
        return row

    def pack_jax(self, **values) -> jax.Array:
        """In-graph payload packing: a padded (``PAYLOAD``,) float32 row for
        handler emits, bitcasting int32 fields (the traced twin of
        :meth:`pack`)."""
        self._check_known(values)
        row = jnp.zeros((PAYLOAD,), jnp.float32)
        for i, n in enumerate(self.names):
            v = values.get(n, self.defaults[n])
            if self.dtypes[n] == jnp.int32:
                f = jax.lax.bitcast_convert_type(
                    jnp.asarray(v, jnp.int32), jnp.float32)
            else:
                f = jnp.asarray(v, jnp.float32)
            row = row.at[i].set(f)
        return row

    def get(self, payload: jax.Array, name: str) -> jax.Array:
        """Read one named scalar from a (``PAYLOAD``,) payload row — int32
        fields are bit-exact (bitcast, not a float->int conversion)."""
        v = payload[..., self.index(name)]
        if self.dtypes[name] == jnp.int32:
            return jax.lax.bitcast_convert_type(v, jnp.int32)
        return v

    def __repr__(self):
        return f"PayloadSpec({', '.join(self.names)})"


@dataclasses.dataclass(frozen=True)
class ComponentDef:
    """A registered component table (returned by ``Registry.component``)."""

    name: str
    table_id: int                     # conflict-mask table id (0 == no table)
    fields: dict                      # field name -> FieldSpec, decl order
    doc: str = ""

    @property
    def lp_kind(self) -> int:
        """The ``lp_kind`` value of LPs owning a row of this component."""
        return self.table_id

    @property
    def row_field(self) -> str:
        """The WorldDelta column that declares this table's written row."""
        return f"{self.name}_row"

    @property
    def own_field(self) -> str:
        """The WorldOwnership column mapping rows back to owning LPs."""
        return f"{self.name}_lp"

    @property
    def first_field(self) -> str:
        return next(iter(self.fields))

    def mutable_fields(self):
        return tuple(f for f, s in self.fields.items() if s.mutable)


@dataclasses.dataclass(frozen=True)
class EventKindDef:
    """A registered event kind (returned by ``Registry.kind``)."""

    name: str
    id: int
    table: str | None                 # component written by the handler
    payload: PayloadSpec

    def pack(self, **values) -> list:
        """Named payload packing — sugar for ``self.payload.pack``."""
        return self.payload.pack(**values)


class HandlerEnv:
    """Trace-time constants + helpers passed to every registered handler."""

    __slots__ = ("registry", "lookahead", "work_per_mb", "_LA")

    def __init__(self, registry: "Registry", lookahead: int,
                 work_per_mb: float):
        self.registry = registry
        self.lookahead = lookahead
        self.work_per_mb = work_per_mb
        self._LA = jnp.int32(lookahead)

    def delay(self, d) -> jax.Array:
        """Clamp an emit delay to the lookahead (the conservative-sync
        invariant: every emitted event lands >= lookahead ticks out)."""
        return jnp.maximum(jnp.asarray(d, jnp.int32), self._LA)

    def empty_delta(self, world):
        return self.registry.empty_delta(world)

    def delta(self, world, component: str, row, **writes):
        """Validating delta constructor — see ``Registry.make_delta``."""
        return self.registry.make_delta(world, component, row, **writes)


class Registry:
    """Holds component/kind/handler declarations and generates engine tables.

    Structural declarations (``dim``/``component``/``kind``) are sealed the
    first time a generated artifact is requested (``world_struct`` & co.);
    handler registration stays open until ``make_handlers`` validates full
    coverage. ``extend()`` returns an unsealed copy that inherits everything —
    the supported way to add components without touching core.
    """

    def __init__(self):
        self._dims: dict[str, int] = {}
        self._components: dict[str, ComponentDef] = {}
        self._kinds: list[EventKindDef] = []
        self._handlers: dict[int, Callable] = {}
        # counter name -> index. Every registry starts with the engine-
        # infrastructure counters (monitoring.BUILTIN_COUNTERS, whose C_*
        # constants are exactly these indices); extensions append their own
        # with Registry.counter and the engine sizes its per-agent counter
        # vector with Registry.n_counters.
        self._counters: dict[str, int] = {
            name: i for i, (name, _doc) in enumerate(_mon.BUILTIN_COUNTERS)}
        # counter name -> docstring: the documentation half of the counter
        # table. tools/gen_counter_docs.py renders it into
        # docs/architecture.md and monitoring.MetricsStream labels snapshots
        # with it, so declared docs are load-bearing, not decoration.
        self._counter_docs: dict[str, str] = {
            name: doc for name, doc in _mon.BUILTIN_COUNTERS}
        self._sealed = False
        # modules whose import registers handlers onto this registry (lets
        # components.py declare the model without importing handlers.py)
        self.deferred_handler_modules: list[str] = []
        self._cache: dict[str, Any] = {}

    # ------------------------------------------------------------ declaration
    def _check_open(self, what: str):
        if self._sealed:
            raise RegistryError(
                f"registry is sealed (a World/Delta struct was already "
                f"generated); cannot add {what}. Use .extend() to grow a "
                f"sealed registry.")

    def dim(self, name: str, default: int) -> str:
        """Declare a builder dimension (e.g. ``max_cpu``) with its default."""
        self._check_open(f"dim {name!r}")
        if not name.isidentifier():
            raise RegistryError(f"dim name {name!r} must be an identifier")
        if name in self._dims and self._dims[name] != default:
            raise RegistryError(f"dim {name!r} already declared with default "
                                f"{self._dims[name]}")
        self._dims[name] = int(default)
        return name

    @property
    def dims(self) -> dict:
        return dict(self._dims)

    def component(self, name: str, fields: dict, doc: str = "") -> ComponentDef:
        """Register a component table; returns its :class:`ComponentDef`."""
        self._check_open(f"component {name!r}")
        if not name.isidentifier():
            raise RegistryError(f"component name {name!r} must be an "
                                "identifier")
        if name in self._components:
            raise RegistryError(f"duplicate component {name!r}")
        if not fields:
            raise RegistryError(f"component {name!r} declares no fields")
        taken = set(LP_FIELDS)
        for comp in self._components.values():
            taken |= set(comp.fields) | {comp.row_field, comp.own_field}
        for fname, fs in fields.items():
            if not isinstance(fs, FieldSpec):
                raise RegistryError(f"{name}.{fname} must be a FieldSpec, "
                                    f"got {type(fs).__name__}")
            if not fname.isidentifier():
                raise RegistryError(f"field name {fname!r} must be an "
                                    "identifier")
            if fname in taken:
                raise RegistryError(
                    f"field {fname!r} of component {name!r} collides with an "
                    "existing World column (field names are global: World is "
                    "one flat structure-of-arrays)")
            for d in fs.shape:
                if isinstance(d, str):
                    if d not in self._dims:
                        raise RegistryError(
                            f"{name}.{fname} shape names unknown dim {d!r}; "
                            f"declare it with Registry.dim first")
                elif not (isinstance(d, int) and d > 0):
                    raise RegistryError(f"{name}.{fname} shape entry {d!r} "
                                        "must be a positive int or a dim name")
            if (fs.mutable and fs.fill != 0
                    and jnp.issubdtype(jnp.dtype(fs.dtype), jnp.floating)):
                raise RegistryError(
                    f"{name}.{fname}: mutable float fields must use fill=0 — "
                    "nonzero fills survive the owner-wins all-reduce via an "
                    "integer shift encoding, which is not byte-exact for "
                    "floats (see Registry.sync_world)")
            taken.add(fname)
        comp = ComponentDef(name=name, table_id=len(self._components) + 1,
                            fields=dict(fields), doc=doc)
        if comp.row_field in taken or comp.own_field in taken:
            raise RegistryError(f"component {name!r}: generated column "
                                f"{comp.row_field}/{comp.own_field} collides "
                                "with an existing field")
        self._components[name] = comp
        return comp

    @property
    def components(self) -> dict:
        return dict(self._components)

    def kind(self, name: str, table: str | None = None,
             payload: PayloadSpec | None = None) -> EventKindDef:
        """Register an event kind; returns its :class:`EventKindDef`.

        ``table`` names the component whose row the kind's handler writes
        (``None`` == the handler touches no component table, e.g. NOOP) —
        this is the row the conflict mask keys on, so it must match the delta
        the handler returns. Components may be registered after the kinds
        that reference them; resolution happens at seal time.
        """
        self._check_open(f"kind {name!r}")
        if not name.isidentifier():
            raise RegistryError(f"kind name {name!r} must be an identifier")
        if any(k.name == name for k in self._kinds):
            raise RegistryError(f"duplicate event kind {name!r}")
        kd = EventKindDef(name=name, id=len(self._kinds), table=table,
                          payload=payload or PayloadSpec())
        self._kinds.append(kd)
        return kd

    @property
    def kinds(self) -> tuple:
        return tuple(self._kinds)

    def kind_def(self, ref) -> EventKindDef:
        """Look up a kind by def / id / name."""
        if isinstance(ref, EventKindDef):
            return ref
        if isinstance(ref, int):
            if not 0 <= ref < len(self._kinds):
                raise RegistryError(f"unknown kind id {ref}")
            return self._kinds[ref]
        for k in self._kinds:
            if k.name == ref:
                return k
        raise RegistryError(f"unknown event kind {ref!r}")

    def counter(self, name: str, doc: str = "") -> int:
        """Declare a named monitoring counter; returns its index.

        The way outside-core components get named stats without editing
        ``monitoring.py``: the returned index is stable for this registry
        (builtin engine counters occupy ``0..monitoring.N_COUNTERS-1``; each
        declaration appends), and handlers bump it with ``mon.bump(counters,
        idx)`` exactly like a builtin. The engine, the oracle, and the batched
        dispatcher all size their counter vectors with :attr:`n_counters`, so
        declared counters flow through every execution path — including the
        batched-lane summation — with zero core edits.
        """
        self._check_open(f"counter {name!r}")
        if not name.isidentifier():
            raise RegistryError(f"counter name {name!r} must be an identifier")
        if name in self._counters:
            raise RegistryError(f"duplicate counter {name!r} "
                                f"(index {self._counters[name]})")
        idx = len(self._counters)
        self._counters[name] = idx
        self._counter_docs[name] = doc
        return idx

    @property
    def counters(self) -> dict:
        """counter name -> index (builtin engine counters first)."""
        return dict(self._counters)

    @property
    def counter_docs(self) -> dict:
        """counter name -> declared docstring (same keys as :attr:`counters`)."""
        return dict(self._counter_docs)

    @property
    def n_counters(self) -> int:
        """Width of the per-agent counter vector for this registry's models."""
        return len(self._counters)

    def counter_index(self, name: str) -> int:
        try:
            return self._counters[name]
        except KeyError:
            raise RegistryError(
                f"unknown counter {name!r}; declared: "
                f"{sorted(self._counters)}") from None

    def on(self, kind) -> Callable:
        """Decorator registering ``fn(env, world, counters, e)`` as the
        handler of ``kind`` (an :class:`EventKindDef`, id, or name)."""
        kd = self.kind_def(kind)

        def register(fn):
            if kd.id in self._handlers:
                raise RegistryError(
                    f"kind {kd.name!r} already has handler "
                    f"{self._handlers[kd.id].__name__!r}")
            self._handlers[kd.id] = fn
            return fn

        return register

    def extend(self) -> "Registry":
        """An unsealed copy inheriting dims, components, kinds, and handlers
        — the extension point for models defined outside core."""
        self._import_deferred()   # so already-registered handlers are copied
        child = Registry()
        child._dims = dict(self._dims)
        child._components = dict(self._components)
        child._kinds = list(self._kinds)
        child._handlers = dict(self._handlers)
        child._counters = dict(self._counters)
        child._counter_docs = dict(self._counter_docs)
        return child

    # ----------------------------------------------------------------- freeze
    def _seal(self):
        if self._sealed:
            return
        for k in self._kinds:
            if k.table is not None and k.table not in self._components:
                raise RegistryError(
                    f"kind {k.name!r} declares table {k.table!r}, which is "
                    f"not a registered component "
                    f"({sorted(self._components) or 'none registered'})")
        self._sealed = True

    def _import_deferred(self):
        for mod in self.deferred_handler_modules:
            importlib.import_module(mod)

    # ------------------------------------------------------- generated tables
    @property
    def n_kinds(self) -> int:
        return len(self._kinds)

    @property
    def n_tables(self) -> int:
        return len(self._components) + 1   # 0 == "no component table"

    @property
    def kind_table(self) -> tuple:
        """kind id -> component table id written by its handler (0 = none)."""
        self._seal()
        return tuple(
            0 if k.table is None else self._components[k.table].table_id
            for k in self._kinds)

    def _struct(self, key: str, name: str, field_names: tuple, doc: str,
                extra: dict | None = None):
        if key not in self._cache:
            base = collections.namedtuple(name, field_names)
            ns = {"__slots__": (), "__doc__": doc, "_registry": self}
            ns.update(extra or {})
            self._cache[key] = type(name, (base,), ns)
        return self._cache[key]

    def world_struct(self):
        """The generated ``World`` NamedTuple: per-LP columns + one
        structure-of-arrays table per registered component."""
        self._seal()
        names = LP_FIELDS + tuple(
            f for comp in self._components.values() for f in comp.fields)
        doc = ("All mutable simulation state (generated from the registry). "
               "Replicated on every agent; synced per window.")
        return self._struct(
            "world", "World", names, doc,
            {"n_lp": property(lambda s: s.lp_kind.shape[-1])})

    def ownership_struct(self):
        """The generated res -> LP inverse maps (one column per component)."""
        self._seal()
        names = tuple(c.own_field for c in self._components.values())
        return self._struct(
            "own", "WorldOwnership", names,
            "res -> LP inverse maps, built once per scenario (generated).")

    def delta_struct(self):
        """The generated ``WorldDelta``: per component, a declared row index
        (``NO_ROW`` == untouched) followed by its mutable fields' new rows."""
        self._seal()
        names = tuple(
            n for comp in self._components.values()
            for n in (comp.row_field,) + comp.mutable_fields())
        return self._struct(
            "delta", "WorldDelta",
            names, "Typed per-row write set of one handler invocation "
                   "(generated from the registry; see handlers.py for the "
                   "delta contract).")

    @property
    def delta_schema(self) -> dict:
        """mutable World field -> the WorldDelta row column addressing it."""
        self._seal()
        return {f: comp.row_field for comp in self._components.values()
                for f in comp.mutable_fields()}

    @property
    def row_fields(self) -> tuple:
        self._seal()
        return tuple(c.row_field for c in self._components.values())

    @property
    def mutable_fields(self) -> tuple:
        return tuple(self.delta_schema)

    def sync_plan(self) -> dict:
        """World field -> sync rule: ``"lp"`` (per-LP owner-wins),
        a component name (owner-wins with that table's mask), or
        ``"replicated"`` (build-time input, never synced)."""
        self._seal()
        plan = {f: "replicated" for f in LP_FIELDS}
        plan["lp_state"] = plan["lp_lvt"] = "lp"
        for comp in self._components.values():
            for fname, fs in comp.fields.items():
                plan[fname] = comp.name if fs.mutable else "replicated"
        return plan

    def resolve_shape(self, shape: tuple, dims: dict) -> tuple:
        return tuple(dims[d] if isinstance(d, str) else d for d in shape)

    def max_rows(self, world) -> int:
        """Widest component table — bound for the conflict-mask key space."""
        return max((getattr(world, c.first_field).shape[0]
                    for c in self._components.values()), default=1)

    # --------------------------------------------------------------- numerics
    def empty_delta(self, world):
        """The identity delta: no rows declared, zero-filled row payloads."""
        vals = {}
        for comp in self._components.values():
            vals[comp.row_field] = NO_ROW
            for f in comp.mutable_fields():
                vals[f] = jnp.zeros_like(getattr(world, f)[0])
        return self.delta_struct()(**vals)

    def make_delta(self, world, component: str, row, **writes):
        """Build a validated delta: declares ``row`` of ``component`` and
        writes *every* mutable field of that table (the whole-row-write half
        of the delta contract; missing or non-mutable fields raise)."""
        comp = self._components.get(component)
        if comp is None:
            raise RegistryError(f"unknown component {component!r}")
        mutable = set(comp.mutable_fields())
        bad = set(writes) - mutable
        if bad:
            immut = sorted(b for b in bad if b in comp.fields)
            if immut:
                raise RegistryError(
                    f"delta writes non-mutable field(s) {immut} of component "
                    f"{component!r}; declare them FieldSpec(mutable=True) if "
                    "handlers must write them")
            raise RegistryError(
                f"delta writes unknown field(s) {sorted(bad)} for component "
                f"{component!r}; declared mutable fields: {sorted(mutable)}")
        missing = mutable - set(writes)
        if missing:
            raise RegistryError(
                f"delta for component {component!r} must write every mutable "
                f"field of the row (whole-row-write contract); missing: "
                f"{sorted(missing)}")
        writes[comp.row_field] = jnp.asarray(row, jnp.int32)
        return self.empty_delta(world)._replace(**writes)

    def apply_delta(self, world, delta):
        """Scatter a delta's declared rows into the world (polymorphic over a
        leading lane axis — see handlers.apply_delta for the contract)."""
        return world._replace(**{
            f: getattr(world, f).at[getattr(delta, rf)].set(
                getattr(delta, f), mode="drop")
            for f, rf in self.delta_schema.items()})

    def sync_world(self, world, own, axis: str | tuple[str, ...] | None):
        """Owner-wins replication sync generated from the field specs.

        Mutable fields all-reduce ``where(mine, row, 0)`` with their owning
        component's mask (exact: one nonzero contribution per row); int
        fields with a nonzero ``fill`` are shifted so the pad value survives
        the zero-identity sum (e.g. ``-1`` route padding). Replicated fields
        pass through untouched.
        """
        if axis is None:
            return world
        me = jax.lax.axis_index(axis)

        def owner_wins(x, mask):
            m = mask.reshape(mask.shape + (1,) * (x.ndim - 1))
            if x.dtype == jnp.bool_:
                y = jax.lax.psum(jnp.where(m, x.astype(jnp.int32), 0), axis)
                return y > 0
            return jax.lax.psum(jnp.where(m, x, jnp.zeros((), x.dtype)), axis)

        lp_mine = world.lp_agent == me
        out = {"lp_state": owner_wins(world.lp_state, lp_mine),
               "lp_lvt": owner_wins(world.lp_lvt, lp_mine)}
        for comp in self._components.values():
            res_lp = getattr(own, comp.own_field)
            mask = world.lp_agent[res_lp] == me
            for fname, fs in comp.fields.items():
                if not fs.mutable:
                    continue
                x = getattr(world, fname)
                if fs.fill != 0 and x.dtype != jnp.bool_:
                    fill = jnp.asarray(fs.fill, x.dtype)
                    out[fname] = owner_wins(x - fill, mask) + fill
                else:
                    out[fname] = owner_wins(x, mask)
        return world._replace(**out)

    def make_handlers(self, lookahead: int, work_per_mb: float = 1.0) -> list:
        """The generated dispatch table: one ``(world, counters, e)`` row
        kernel per kind id, in kind order (the ``lax.switch`` index)."""
        self._seal()
        self._import_deferred()
        missing = [k.name for k in self._kinds if k.id not in self._handlers]
        if missing:
            raise RegistryError(f"no handler registered for kind(s) "
                                f"{missing}; attach one with @registry.on")
        env = HandlerEnv(self, lookahead, work_per_mb)

        def bind(fn):
            def kernel(world, counters, e, _fn=fn):
                return _fn(env, world, counters, e)
            kernel.__name__ = fn.__name__
            return kernel

        return [bind(self._handlers[k.id]) for k in self._kinds]


def registry_of(obj) -> Registry:
    """The registry that generated ``obj``'s type (World/WorldDelta/...)."""
    reg = getattr(type(obj), "_registry", None)
    if reg is None:
        raise RegistryError(
            f"{type(obj).__name__} was not generated by a Registry; build "
            "worlds through a registry ScenarioBuilder")
    return reg


# ---------------------------------------------------------------------------
# Scenario spec + builder base (host-side)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """Static (trace-time constant) facts about a built scenario."""

    n_agents: int
    n_ctx: int
    lookahead: int          # ticks; min event-generation delay (conservative window)
    t_end: int              # ticks; horizon after which the run stops
    pool_cap: int           # per-agent event-pool capacity
    emit_cap: int           # per-window emit-buffer capacity
    route_cap: int          # per-(src,dst)-agent routing-buffer capacity
    n_lp: int
    work_per_mb: float = 1.0  # CPU ops per transferred MB (job sizing)
    exec_policy: Any = 256  # per-window execution width: a static int (the
                            # PR 1-4 exec_cap; safe events beyond it spill to
                            # the next window) or a policy.ExecPolicy ladder
                            # driven by monitoring (Engine.run_adaptive)
    batched_dispatch: bool = True  # engine step 4: grouped vectorized dispatch
                                   # (False = PR 1 sequential compacted fold)
    merge_mode: str = "delta"      # batched-dispatch merge strategy:
                                   # "delta" = per-row segment scatters of the
                                   # handlers' declared rows, O(lanes x row);
                                   # "dense" = the PR 2 reference merge over
                                   # whole component tables, O(lanes x tables)
                                   # — kept for equivalence tests + benchmarks
    insert_mode: str = "ring"      # event-pool lifecycle strategy: "ring" =
                                   # free-list ring (O(n_insert) insert +
                                   # O(exec_cap) release); "ref" = the PR 1-4
                                   # O(pool_cap) rank-scan insert + pool-wide
                                   # pop mask — kept for equivalence tests and
                                   # the insert_churn benchmark gate
    fused_select: bool = False     # window front-end: True fuses select +
                                   # gather + conflict + group + release ranks
                                   # into one Pallas megakernel call (engine
                                   # fused_fn hook; compiled on TPU,
                                   # interpreted elsewhere — byte-identical
                                   # either way); False (default) keeps the
                                   # XLA-stitched per-stage path

    @property
    def exec_cap(self) -> int:
        """The static per-window execution width the non-adaptive drivers
        use: the int itself, or an adaptive policy's initial-rung width."""
        p = self.exec_policy
        return p if isinstance(p, int) else p.ladder[p.init_rung]


class ScenarioBuilderBase:
    """Generic registry-driven scenario builder.

    Subclasses bind a registry with the ``_registry`` class attribute
    (``components.ScenarioBuilder`` binds the built-ins and layers the legacy
    ergonomic wrappers on top). For every registered component the builder
    exposes ``add_<component>(**field_values)`` (resolved dynamically, unless
    the subclass defines a bespoke wrapper) plus the generic
    ``add_component(name, **field_values)``; ``build()`` allocates the
    generated ``World`` tables, the ownership inverse maps, the initial event
    batch, and the :class:`ScenarioSpec`.
    """

    _registry: Registry

    def __init__(self, **dims):
        reg = self._registry
        unknown = set(dims) - set(reg.dims)
        if unknown:
            raise RegistryError(f"unknown builder dim(s) {sorted(unknown)}; "
                                f"declared: {sorted(reg.dims)}")
        self.dims = {**reg.dims, **{k: int(v) for k, v in dims.items()}}
        for k, v in self.dims.items():
            setattr(self, k, v)
        self._lps: list[dict] = []       # kind, res, ctx
        self._rows: dict[str, list] = {c: [] for c in reg.components}
        self._events: list[dict] = []
        self._seq = 0

    # --------------------------------------------------------------- generic
    def __getattr__(self, name):
        # add_<component> sugar for components without a bespoke wrapper
        if name.startswith("add_"):
            reg = type(self)._registry
            comp = reg.components.get(name[len("add_"):])
            if comp is not None:
                return lambda **kw: self.add_component(comp.name, **kw)
        raise AttributeError(
            f"{type(self).__name__!r} object has no attribute {name!r}")

    def _new_lp(self, kind: int, res: int, ctx: int) -> int:
        self._lps.append(dict(kind=kind, res=res, ctx=ctx))
        return len(self._lps) - 1

    def add_component(self, name: str, *, ctx: int = 0, **fields) -> int:
        """Add one row of component ``name``; returns the owning LP's id.

        Field values are validated against the component's declared shapes:
        scalars for ``()`` fields, sequences no longer than the declared dim
        for 1-D fields (shorter sequences prefix-fill, the rest keeps the
        declared ``fill``), exact shape for >=2-D fields.
        """
        reg = self._registry
        comp = reg.components.get(name)
        if comp is None:
            raise RegistryError(f"unknown component {name!r}; registered: "
                                f"{sorted(reg.components)}")
        unknown = set(fields) - set(comp.fields)
        if unknown:
            raise RegistryError(
                f"unknown field(s) {sorted(unknown)} for component {name!r}; "
                f"declared: {sorted(comp.fields)}")
        import numpy as np
        for fname, value in fields.items():
            spec = comp.fields[fname]
            shape = reg.resolve_shape(spec.shape, self.dims)
            v = np.asarray(value)
            if v.ndim != len(shape):
                raise RegistryError(
                    f"{name}.{fname} expects a rank-{len(shape)} row "
                    f"{spec.shape}, got shape {v.shape}")
            if len(shape) >= 1 and v.shape[0] > shape[0]:
                raise RegistryError(
                    f"{name}.{fname} row of length {v.shape[0]} exceeds the "
                    f"declared dim {spec.shape[0]!r}={shape[0]}")
            if len(shape) >= 2 and v.shape[1:] != shape[1:]:
                raise RegistryError(
                    f"{name}.{fname} trailing shape {v.shape[1:]} must match "
                    f"declared {shape[1:]}")
        self._rows[name].append(dict(fields))
        return self._new_lp(comp.lp_kind, len(self._rows[name]) - 1, ctx)

    def add_idle_lp(self, ctx: int = 0) -> int:
        """A bare LP with no component row (lp_kind 0): a NOOP event sink.

        Used by dispatch benchmarks/tests that want many distinct destination
        LPs without growing any component table, and as a placement target.
        """
        return self._new_lp(0, 0, ctx)

    def add_event(self, *, time: int, kind, src: int, dst: int, payload=(),
                  ctx: int = 0):
        """Seed one initial event. ``kind`` may be an :class:`EventKindDef`
        or a kind id; ``payload`` a positional list (use ``kind.pack(...)``
        for named packing)."""
        self._events.append(dict(time=time, seq=self._seq,
                                 kind=getattr(kind, "id", kind), src=src,
                                 dst=dst, payload=payload, ctx=ctx))
        self._seq += 1

    # ----------------------------------------------------------------- build
    def build(self, *, n_agents: int = 1, n_ctx: int = 1, lookahead: int,
              t_end: int, pool_cap: int = 1024, emit_cap: int | None = None,
              route_cap: int | None = None, exec_cap: int | None = None,
              exec_policy=None, placement=None, work_per_mb: float = 1.0,
              batched_dispatch: bool = True, merge_mode: str = "delta",
              insert_mode: str = "ring", fused_select: bool = False):
        from repro.core import events as ev   # late: events imports registry

        reg = self._registry
        World = reg.world_struct()
        nlp = max(len(self._lps), 1)

        lp_kind = jnp.asarray([l["kind"] for l in self._lps] or [0], jnp.int32)
        lp_res = jnp.asarray([l["res"] for l in self._lps] or [0], jnp.int32)
        lp_ctx = jnp.asarray([l["ctx"] for l in self._lps] or [0], jnp.int32)
        if placement is None:
            lp_agent = jnp.arange(nlp, dtype=jnp.int32) % n_agents
        else:
            lp_agent = jnp.asarray(placement, jnp.int32)

        vals = dict(
            lp_kind=lp_kind,
            lp_agent=lp_agent,
            lp_res=lp_res,
            lp_state=jnp.full((nlp,), LPS_READY, jnp.int32),
            lp_lvt=jnp.zeros((nlp,), jnp.int32),
            lp_ctx=lp_ctx,
        )
        n_rows = {}
        for comp in reg.components.values():
            rows = self._rows[comp.name]
            n = max(len(rows), 1)
            n_rows[comp.name] = n
            for fname, spec in comp.fields.items():
                shape = (n,) + reg.resolve_shape(spec.shape, self.dims)
                arr = jnp.full(shape, spec.fill, spec.dtype)
                for i, row in enumerate(rows):
                    if fname not in row:
                        continue
                    v = jnp.asarray(row[fname], spec.dtype)
                    if v.ndim == 0:
                        arr = arr.at[i].set(v)
                    else:
                        arr = arr.at[i, : v.shape[0]].set(v)
                vals[fname] = arr
        world = World(**vals)

        def inverse_map(comp):
            out = [0] * n_rows[comp.name]
            for lp, l in enumerate(self._lps):
                if l["kind"] == comp.lp_kind:
                    out[l["res"]] = lp
            return jnp.asarray(out, jnp.int32)

        own = reg.ownership_struct()(**{
            comp.own_field: inverse_map(comp)
            for comp in reg.components.values()})

        if exec_policy is not None and exec_cap is not None:
            raise RegistryError(
                "pass either exec_cap (static width) or exec_policy "
                "(adaptive ladder), not both")
        if exec_policy is None:
            exec_policy = max(exec_cap if exec_cap is not None
                              else min(pool_cap, 256), 1)
        spec = ScenarioSpec(
            n_agents=n_agents,
            n_ctx=n_ctx,
            lookahead=lookahead,
            t_end=t_end,
            pool_cap=pool_cap,
            emit_cap=emit_cap or pool_cap,
            route_cap=route_cap or max(pool_cap // max(n_agents, 1), 16),
            exec_policy=exec_policy,
            n_lp=nlp,
            work_per_mb=work_per_mb,
            batched_dispatch=batched_dispatch,
            merge_mode=merge_mode,
            insert_mode=insert_mode,
            fused_select=fused_select,
        )
        init_events = ev.batch_from_rows(self._events)
        return world, own, init_events, spec
