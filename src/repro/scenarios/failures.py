"""Failure/repair process LP — the third component defined entirely outside core.

The paper's availability studies (§3/§4.2) model resources that fail and
recover while the workload runs; this module adds that as a registry
extension with **zero edits** inside ``repro/core``: a *failure process*
component whose LP tortures a compute farm with bursts of CPU failures at
pseudo-exponential intervals, plus repair events that bring the CPUs back.
It is the stress case the adaptive exec policy (``core/policy.py``) was built
for — failure bursts make some conservative windows dense (many same-tick
events -> spill pressure at a narrow exec width) while the exponential gaps
leave others nearly empty (shrink opportunity) — and the third proof of the
registry seam after the builtins and the replica cache.

The module demonstrates every PR 5 registry feature at once:

* **Extension kinds on a builtin table**: ``CPU_FAIL`` / ``CPU_REPAIR``
  declare ``table="farm"`` — their handlers write the farm row of the
  destination LP under the ordinary delta contract, so the conflict mask
  automatically serializes a burst hitting one farm (same ``(farm, row)``
  key) while failures on distinct farms batch in one vectorized call.
* **Declared monitoring counters** (``Registry.counter``): ``CPU_FAILS`` /
  ``CPU_REPAIRS`` / ``FAIL_BURSTS`` are named fleet stats with no edit in
  ``monitoring.py``.
* **Payload dtype views**: the failed CPU slot and the repair delay travel
  as declared ``int32`` payload fields (bit-exact through the float32
  payload lanes — see ``PayloadSpec``).

Model caveat (a stress generator, not a faithful FT study): a failure marks
the CPU slot busy — the farm scheduler stops placing jobs there — but a job
already running on the slot still completes, and its ``JOB_END`` may reclaim
the slot before the repair arrives. Randomness is an in-handler LCG carried
in mutable component state, so the sequential oracle replays the identical
stream and every execution path stays byte-identical.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import events as ev
from repro.core import handlers as hd
from repro.core import monitoring as mon
from repro.core.components import BUILTIN, JOB_SUBMIT, ScenarioBuilder
from repro.core.registry import (FieldSpec, PayloadSpec, Registry,
                                 ScenarioBuilderBase)


def _lcg(rng):
    """One step of the classic 32-bit LCG (int32 wrap-around, oracle-exact)."""
    return rng * jnp.int32(1664525) + jnp.int32(1013904223)


def _unit(rng):
    """(0, 1) float32 from the LCG's high bits (sign-safe shift + mask)."""
    bits = jnp.bitwise_and(jnp.right_shift(rng, 9), jnp.int32((1 << 22) - 1))
    return (bits.astype(jnp.float32) + 0.5) / jnp.float32(1 << 22)


def _expo(rng, mean):
    """Pseudo-exponential delay with the given mean, in [1, 8*mean] ticks."""
    m = jnp.maximum(mean, 1).astype(jnp.float32)
    d = jnp.ceil(-m * jnp.log(_unit(rng)))
    return jnp.clip(d, 1.0, 8.0 * m).astype(jnp.int32)


def register_failure_model(reg: Registry) -> dict:
    """Declare the failure-process component, kinds, handlers, and counters."""
    c_fails = reg.counter("CPU_FAILS", "CPU slots taken down by failures")
    c_repairs = reg.counter("CPU_REPAIRS", "CPU slots brought back up")
    c_bursts = reg.counter("FAIL_BURSTS", "failure bursts fired")
    c_trunc = reg.counter(
        "FAIL_BURST_TRUNC",
        f"failures not emitted because fp_burst exceeded the "
        f"{ev.MAX_EMIT - 1} emit slots a FAIL_TICK carries")

    fproc = reg.component("fproc", doc="failure/repair process LP", fields=dict(
        fp_target=FieldSpec((), jnp.int32, doc="farm LP the process torments"),
        fp_burst=FieldSpec((), jnp.int32, fill=1,
                           doc=f"CPU failures per burst (<= {ev.MAX_EMIT - 1})"),
        fp_fail_mean=FieldSpec((), jnp.int32, fill=16,
                               doc="mean ticks between bursts (exponential)"),
        fp_repair_mean=FieldSpec((), jnp.int32, fill=8,
                                 doc="mean ticks a failed CPU stays down"),
        fp_rng=FieldSpec((), jnp.int32, mutable=True, doc="LCG state"),
        fp_left=FieldSpec((), jnp.int32, mutable=True,
                          doc="remaining bursts to fire"),
    ))
    # int32 dtype views: slot ids and delays travel bit-exact through the
    # float32 payload lanes (never a numeric float round-trip)
    fail_payload = PayloadSpec(("slot", 0, jnp.int32),
                               ("repair_delay", 1, jnp.int32))
    tick = reg.kind("FAIL_TICK", table="fproc")
    fail = reg.kind("CPU_FAIL", table="farm", payload=fail_payload)
    repair = reg.kind("CPU_REPAIR", table="farm",
                      payload=PayloadSpec(("slot", 0, jnp.int32)))

    @reg.on(tick)
    def h_fail_tick(env, world, counters, e):
        g = world.lp_res[e.dst]
        rng = world.fp_rng[g]
        left = world.fp_left[g]
        fire = left > 0
        target = world.fp_target[g]
        burst = world.fp_burst[g]
        n_cpu = world.cpu_busy.shape[1]
        out = hd.no_emits()
        # the burst: up to MAX_EMIT-1 same-tick CPU_FAILs at the target farm
        # (one conservative window -> one conflict group on that farm row)
        for s in range(ev.MAX_EMIT - 1):
            rng = _lcg(rng)
            slot = jnp.bitwise_and(jnp.right_shift(rng, 7),
                                   jnp.int32(2**24 - 1)) % jnp.int32(n_cpu)
            rng = _lcg(rng)
            delay = _expo(rng, world.fp_repair_mean[g])
            out = hd.set_emit(
                out, s, valid=fire & (s < burst),
                time=e.time + env.delay(1), kind=fail.id, src=e.dst,
                dst=target, ctx=e.ctx,
                payload=fail_payload.pack_jax(slot=slot, repair_delay=delay),
                parent_seq=e.seq)
        # next burst after a pseudo-exponential gap
        rng = _lcg(rng)
        gap = _expo(rng, world.fp_fail_mean[g])
        out = hd.set_emit(
            out, ev.MAX_EMIT - 1, valid=fire & (left > 1),
            time=e.time + env.delay(gap), kind=tick.id, src=e.dst, dst=e.dst,
            ctx=e.ctx, payload=jnp.zeros((ev.PAYLOAD,), jnp.float32),
            parent_seq=e.seq)
        counters = mon.bump(counters, c_bursts, jnp.where(fire, 1, 0))
        # a burst wider than the emit slots is truncated — like every other
        # overflow in this engine, counted, never silent
        trunc = jnp.maximum(burst - jnp.int32(ev.MAX_EMIT - 1), 0)
        counters = mon.bump(counters, c_trunc, jnp.where(fire, trunc, 0))
        delta = env.delta(world, "fproc", g, fp_rng=rng,
                          fp_left=left - jnp.where(fire, 1, 0))
        return delta, counters, out

    @reg.on(fail)
    def h_cpu_fail(env, world, counters, e):
        f = world.lp_res[e.dst]
        slot = fail_payload.get(e.payload, "slot")
        busy = world.cpu_busy[f].at[slot].set(1)
        memr = world.cpu_mem[f].at[slot].set(0.0)
        counters = mon.bump(counters, c_fails)
        out = hd.set_emit(
            hd.no_emits(), 0, valid=True,
            time=e.time + env.delay(fail_payload.get(e.payload,
                                                     "repair_delay")),
            kind=repair.id, src=e.dst, dst=e.dst, ctx=e.ctx,
            payload=repair.payload.pack_jax(slot=slot), parent_seq=e.seq)
        delta = env.delta(world, "farm", f, cpu_busy=busy, cpu_mem=memr,
                          jobq=world.jobq[f], jobq_n=world.jobq_n[f])
        return delta, counters, out

    @reg.on(repair)
    def h_cpu_repair(env, world, counters, e):
        """Bring the slot back up — and, like JOB_END, pop the FIFO head
        onto the repaired CPU so jobs queued during the outage restart
        (``handlers.start_queued_job`` is the shared queue discipline)."""
        f = world.lp_res[e.dst]
        slot = repair.payload.get(e.payload, "slot")
        counters = mon.bump(counters, c_repairs)
        busy_v, mem_v, new_jq, new_qn, out = hd.start_queued_job(
            env, world, f, slot, e, hd.no_emits(), 0)
        delta = env.delta(world, "farm", f,
                          cpu_busy=world.cpu_busy[f].at[slot].set(busy_v),
                          cpu_mem=world.cpu_mem[f].at[slot].set(mem_v),
                          jobq=new_jq, jobq_n=new_qn)
        return delta, counters, out

    return dict(fproc=fproc, FAIL_TICK=tick, CPU_FAIL=fail, CPU_REPAIR=repair,
                C_CPU_FAILS=c_fails, C_CPU_REPAIRS=c_repairs,
                C_FAIL_BURSTS=c_bursts, C_FAIL_BURST_TRUNC=c_trunc)


FAIL_REGISTRY = BUILTIN.extend()
_DEFS = register_failure_model(FAIL_REGISTRY)
FPROC = _DEFS["fproc"]
FAIL_TICK = _DEFS["FAIL_TICK"]
CPU_FAIL = _DEFS["CPU_FAIL"]
CPU_REPAIR = _DEFS["CPU_REPAIR"]
C_CPU_FAILS = _DEFS["C_CPU_FAILS"]
C_CPU_REPAIRS = _DEFS["C_CPU_REPAIRS"]
C_FAIL_BURSTS = _DEFS["C_FAIL_BURSTS"]
C_FAIL_BURST_TRUNC = _DEFS["C_FAIL_BURST_TRUNC"]
K_FAIL_TICK = FAIL_TICK.id
LPK_FPROC = FPROC.lp_kind


class FailureScenarioBuilder(ScenarioBuilder):
    """Builtin builder + the generated ``add_fproc(...)`` method."""

    _registry = FAIL_REGISTRY

    def __init__(self, max_cpu: int = 16, queue_cap: int = 32,
                 max_link: int = 8, max_flow: int = 64):
        ScenarioBuilderBase.__init__(
            self, max_cpu=max_cpu, queue_cap=queue_cap, max_link=max_link,
            max_flow=max_flow)


def build_failure_scenario(*, n_farms: int = 8, n_cpu: int = 4,
                           procs_per_farm: int = 1, burst: int = 3,
                           fail_mean: int = 12, repair_mean: int = 6,
                           n_bursts: int = 6, jobs_per_farm: int = 0,
                           job_interval: int = 8, seed: int = 1,
                           lookahead: int = 2, n_agents: int = 1,
                           pool_cap: int = 1024, **build_kw):
    """Farms under failure/repair churn (optionally with a job workload).

    One failure process per (farm, proc) pair; distinct farms give the
    batched dispatcher conflict-free lanes, ``procs_per_farm > 1`` (or
    ``burst > 1``) forces same-row collisions through the sequential
    fallback. ``jobs_per_farm`` adds a JOB_SUBMIT generator per farm so
    failures contend with the workload for CPU slots.
    """
    if burst > ev.MAX_EMIT - 1:
        raise ValueError(
            f"burst={burst} exceeds the {ev.MAX_EMIT - 1} CPU_FAIL emit "
            "slots a FAIL_TICK carries (excess would be truncated and "
            "counted in FAIL_BURST_TRUNC)")
    b = FailureScenarioBuilder(max_cpu=n_cpu, queue_cap=8, max_link=1,
                               max_flow=2)
    farms = [b.add_farm([1.0] * n_cpu) for _ in range(n_farms)]
    procs = []
    for i, farm in enumerate(farms):
        for p in range(procs_per_farm):
            lp = b.add_fproc(fp_target=farm, fp_burst=burst,
                             fp_fail_mean=fail_mean,
                             fp_repair_mean=repair_mean,
                             fp_rng=seed + 7919 * (i * procs_per_farm + p),
                             fp_left=n_bursts)
            b.add_event(time=1 + (i * procs_per_farm + p) % lookahead,
                        kind=FAIL_TICK, src=lp, dst=lp)
            procs.append(lp)
    for farm in farms[: n_farms if jobs_per_farm else 0]:
        b.add_generator(target_lp=farm, kind=JOB_SUBMIT,
                        payload=JOB_SUBMIT.pack(work=3.0, mem=1.0),
                        interval=job_interval, count=jobs_per_farm)
    t_end = (n_bursts + 2) * 8 * max(fail_mean, repair_mean)
    built = b.build(n_agents=n_agents, lookahead=lookahead, t_end=t_end,
                    pool_cap=pool_cap, **build_kw)
    return built, dict(farms=farms, procs=procs)
