"""repro.scenarios — simulation models defined *outside* the engine core.

Each module here extends the builtin registry (``BUILTIN.extend()``) with new
components, event kinds, and handlers — the scenario-authoring seam described
in docs/scenario_api.md. Nothing in this package edits ``repro.core``
internals; the engine, the conflict mask, the owner-wins sync, and the
sequential oracle pick the extended model up from the generated ``World``
type automatically.

The declarative scenario *catalog* also lives here (``catalog.py``): named,
parameterized experiment declarations — ports of the workloads above plus
the builtin T0/T1 study — that ``simulate run <name> [--set k=v]`` resolves
and dispatches through ``repro.fleet.Orchestrator``.
"""
from repro.scenarios import cache, catalog

__all__ = ["cache", "catalog"]
