"""repro.scenarios — simulation models defined *outside* the engine core.

Each module here extends the builtin registry (``BUILTIN.extend()``) with new
components, event kinds, and handlers — the scenario-authoring seam described
in docs/scenario_api.md. Nothing in this package edits ``repro.core``
internals; the engine, the conflict mask, the owner-wins sync, and the
sequential oracle pick the extended model up from the generated ``World``
type automatically.
"""
from repro.scenarios import cache

__all__ = ["cache"]
