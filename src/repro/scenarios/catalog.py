"""Declarative scenario catalog: named, parameterized experiments.

The paper's end-user surface is *named experiments over a shared simulation
core* (CGSim's config-driven scenarios, SimGrid's stable user API — see
PAPERS.md): a user asks for "the T0/T1 replication study at 2 MB/s", not for
a hand-assembled ``ScenarioSpec``. This module is that surface: a
:class:`ScenarioDef` is a frozen declaration — a name, a docstring, the
declared parameters with their defaults, and a build callable returning the
``(world, own, init_events, spec)`` tuple every driver consumes — and the
module-level registry (:func:`register` / :func:`get` / :func:`names`) is
the lookup the ``simulate run <name> [--set k=v]`` CLI resolves against,
dispatching through :class:`repro.fleet.Orchestrator` as the single entry
point.

Authoring a new entry (see docs/scenario_api.md, "Scenario catalog"):

    from repro.scenarios import catalog

    def _build_mine(*, knob=4, n_agents=1):
        b = ScenarioBuilder(...)
        ...
        return b.build(n_agents=n_agents, lookahead=2, t_end=1000)

    catalog.register(catalog.ScenarioDef(
        name="mine", doc="what it models", build=_build_mine,
        params=(("knob", 4), ("n_agents", 1))))

``params`` declares exactly the overridable surface: an override naming an
undeclared parameter is a loud :class:`CatalogError`, and override values
are coerced to the declared default's type (so ``--set wan_bw=0.5`` works
from the CLI's strings).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping


class CatalogError(ValueError):
    """Unknown scenario name, duplicate registration, or a bad override."""


def _coerce(value, default):
    """Coerce a (possibly string) override to the declared default's type."""
    if isinstance(value, str) and not isinstance(default, str):
        if isinstance(default, bool):
            if value.lower() in ("1", "true", "yes"):
                return True
            if value.lower() in ("0", "false", "no"):
                return False
            raise CatalogError(f"cannot parse {value!r} as a bool")
        try:
            return type(default)(value)
        except ValueError as e:
            raise CatalogError(
                f"cannot parse {value!r} as {type(default).__name__}") from e
    return value


@dataclasses.dataclass(frozen=True)
class ScenarioDef:
    """One catalog entry: a named, parameterized scenario declaration.

    ``build(**params)`` must return the ``(world, own, init_events, spec)``
    tuple of ``ScenarioBuilderBase.build``. ``params`` is the declared
    override surface as ``(name, default)`` pairs — :meth:`resolve` rejects
    overrides outside it. ``driver`` is the orchestrator dispatch hint
    (``"auto"`` picks distributed/adaptive from the device count and the
    spec's exec policy; ``"ensemble"`` marks a vmap-over-seeds entry whose
    ``replicas``/``seed0`` params size the seed vector instead of being
    build arguments).
    """

    name: str
    doc: str
    build: Callable[..., tuple]
    params: tuple[tuple[str, Any], ...] = ()
    driver: str = "auto"

    def defaults(self) -> dict[str, Any]:
        return dict(self.params)

    def resolve(self, overrides: Mapping[str, Any] | None = None):
        """Apply overrides and build. Returns ``(built, params)`` where
        ``built`` is the 4-tuple the engine/orchestrator consumes and
        ``params`` the fully-resolved parameter dict (the run's record)."""
        params = self.defaults()
        for key, value in (overrides or {}).items():
            if key not in params:
                raise CatalogError(
                    f"scenario {self.name!r} has no parameter {key!r}; "
                    f"declared: {', '.join(sorted(params)) or '(none)'}")
            params[key] = _coerce(value, params[key])
        build_kw = {k: v for k, v in params.items()
                    if k not in ("replicas", "seed0")}
        return self.build(**build_kw), params


_CATALOG: dict[str, ScenarioDef] = {}


def register(scenario: ScenarioDef) -> ScenarioDef:
    """Add an entry to the catalog (duplicate names are rejected)."""
    if scenario.name in _CATALOG:
        raise CatalogError(f"scenario {scenario.name!r} already registered")
    if scenario.driver == "ensemble" and "replicas" not in dict(scenario.params):
        raise CatalogError(
            f"ensemble scenario {scenario.name!r} must declare a "
            f"'replicas' parameter")
    _CATALOG[scenario.name] = scenario
    return scenario


def get(name: str) -> ScenarioDef:
    """Look up an entry by name (loud on unknown names)."""
    try:
        return _CATALOG[name]
    except KeyError:
        raise CatalogError(
            f"unknown scenario {name!r}; catalog has: "
            f"{', '.join(names())}") from None


def names() -> tuple[str, ...]:
    """All registered scenario names, sorted."""
    return tuple(sorted(_CATALOG))


def resolve(name: str, overrides: Mapping[str, Any] | None = None):
    """``get(name).resolve(overrides)`` in one call."""
    return get(name).resolve(overrides)


# --------------------------------------------------------- builtin entries
def _build_t0t1(*, wan_bw=2.0, n_flows=16, interval=20, flow_mb=40.0,
                lookahead=2, n_agents=1, pool_cap=512, t_end=20_000,
                exec_cap=0, fused=False):
    """The paper's T0/T1 replication study: production at tier-0 generates
    WAN transfers; each arrival triggers an analysis job at tier-1 whose
    output lands in tier-1 storage (the quickstart/Fig-2 scenario)."""
    from repro.core import ScenarioBuilder
    from repro.core.components import DATA_WRITE, FLOW_START, JOB_SUBMIT

    b = ScenarioBuilder(max_cpu=4, queue_cap=16, max_link=4, max_flow=32)
    b.add_regional_center(n_cpu=2, cpu_power=10.0, disk=1000.0,
                          tape=10000.0, tape_rate=5.0)
    t1 = b.add_regional_center(n_cpu=2, cpu_power=8.0, disk=500.0,
                               tape=5000.0, tape_rate=5.0)
    wan = b.add_net_region(link_bws=[wan_bw, wan_bw], link_lats=[5, 5])
    b.add_generator(
        target_lp=wan, kind=FLOW_START,
        payload=FLOW_START.pack(size=flow_mb, l0=0, notify_lp=t1["farm"],
                                notify_kind=JOB_SUBMIT.id,
                                notify2_lp=t1["storage"],
                                notify2_kind=DATA_WRITE.id),
        interval=interval, count=n_flows, start=0)
    extra = dict(exec_cap=exec_cap) if exec_cap else {}
    return b.build(n_agents=n_agents, lookahead=lookahead, t_end=t_end,
                   pool_cap=pool_cap, work_per_mb=2.0, fused_select=fused,
                   **extra)


def _build_cache_churn(*, n_caches=8, n_keys=4, n_rounds=6, cache_ways=8,
                       n_agents=1, pool_cap=1024, fused=False):
    from repro.scenarios.cache import build_churn_scenario

    built, _caches = build_churn_scenario(
        n_caches=n_caches, n_keys=n_keys, n_rounds=n_rounds,
        cache_ways=cache_ways, n_agents=n_agents, pool_cap=pool_cap,
        fused_select=fused)
    return built


def _build_failure_farm(*, n_farms=8, n_cpu=4, burst=3, n_bursts=6,
                        jobs_per_farm=4, seed=1, n_agents=1, pool_cap=1024,
                        fused=False):
    from repro.scenarios.failures import build_failure_scenario

    built, _info = build_failure_scenario(
        n_farms=n_farms, n_cpu=n_cpu, burst=burst, n_bursts=n_bursts,
        jobs_per_farm=jobs_per_farm, seed=seed, n_agents=n_agents,
        pool_cap=pool_cap, fused_select=fused)
    return built


def _build_ensemble_farm(*, n_farms=2, n_cpu=4, burst=3, n_bursts=6,
                         pool_cap=128):
    from repro.scenarios.failures import build_failure_scenario

    built, _info = build_failure_scenario(
        n_farms=n_farms, n_cpu=n_cpu, burst=burst, n_bursts=n_bursts,
        pool_cap=pool_cap)
    return built


register(ScenarioDef(
    name="t0t1",
    doc="T0/T1 replication study: WAN transfers trigger tier-1 analysis "
        "jobs and storage writes (the paper's Fig-2 scenario at one "
        "bandwidth point)",
    build=_build_t0t1,
    params=(("wan_bw", 2.0), ("n_flows", 16), ("interval", 20),
            ("flow_mb", 40.0), ("lookahead", 2), ("n_agents", 1),
            ("pool_cap", 512), ("t_end", 20_000), ("exec_cap", 0),
            ("fused", False))))

register(ScenarioDef(
    name="cache_churn",
    doc="replica-cache lookup churn: per-round lookups miss cold and hit "
        "warm (the outside-core registry-extension component)",
    build=_build_cache_churn,
    params=(("n_caches", 8), ("n_keys", 4), ("n_rounds", 6),
            ("cache_ways", 8), ("n_agents", 1), ("pool_cap", 1024),
            ("fused", False))))

register(ScenarioDef(
    name="failure_farm",
    doc="compute farms under failure/repair churn contending with a job "
        "workload (failure-process extension LPs)",
    build=_build_failure_farm,
    params=(("n_farms", 8), ("n_cpu", 4), ("burst", 3), ("n_bursts", 6),
            ("jobs_per_farm", 4), ("seed", 1), ("n_agents", 1),
            ("pool_cap", 1024), ("fused", False))))

register(ScenarioDef(
    name="ensemble_farm",
    doc="Monte-Carlo failure-farm ensemble: R seed-perturbed replicas in "
        "one fused vmap-over-seeds launch",
    build=_build_ensemble_farm,
    params=(("replicas", 8), ("seed0", 1), ("n_farms", 2), ("n_cpu", 4),
            ("burst", 3), ("n_bursts", 6), ("pool_cap", 128)),
    driver="ensemble"))
