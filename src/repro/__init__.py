"""repro: distributed DES framework (Dobre/Cristea/Legrand 2011) + multi-pod
JAX training/serving stack. See DESIGN.md for the map."""
__version__ = "0.1.0"
