"""repro.fleet — elastic fleet orchestration (simulation-as-a-service).

The host control loop that wraps the four engine drivers behind one
``Orchestrator.run(built, devices, policy)`` entry point: GVT-aligned
durable checkpoints, shard-loss detection (injected probe + SIGKILL
restart discovery), automatic resume on the surviving device set through
the device-layout-free checkpoint reshard path, retry/backoff caps, a
degraded-mode device floor, and host-side fleet counters
(``C_PREEMPT``/``C_RESUME``/``C_RESHARD``) surfaced through
``MetricsStream``. See docs/architecture.md, "Elastic fleet orchestration".
"""
from repro.fleet.orchestrator import (
    FleetError,
    FleetPolicy,
    Orchestrator,
    OrchestratorResult,
    PreemptionError,
)

__all__ = [
    "FleetError",
    "FleetPolicy",
    "Orchestrator",
    "OrchestratorResult",
    "PreemptionError",
]
