"""Elastic preemptible execution: the fleet orchestration host loop.

The paper pitches a simulation *service* that "hides the computational
effort from the end-user" — the run should survive the fabric it executes
on. PRs 5-8 built the mechanisms (GVT-aligned durable checkpoints that are
device-layout-free, host-streamed observability that concatenates exactly
across a resume, a SIGKILL crash harness); :class:`Orchestrator` is the
control loop that composes them:

* **One entry point over all drivers.** ``run(built, devices, policy)``
  dispatches to ``run_local`` / ``run_adaptive`` / ``run_distributed`` /
  ``run_distributed_adaptive`` (``policy.driver="auto"`` picks from the
  device count and the spec's exec policy) — or ``run_ensemble`` for
  catalog ensemble entries.
* **GVT-aligned checkpoints.** A :class:`~repro.checkpoint.SimCheckpointer`
  saves the unpadded EngineState (plus the drained trace spans and emitted
  metrics records) every ``checkpoint_every`` windows.
* **Shard-loss detection.** Two lanes: an injected probe (``preempt=``)
  fired through the engine's per-window host hook — the in-process test
  lane — and process death (SIGKILL), discovered at the next start through
  the ``fleet.json`` sidecar's missing clean flag.
* **Automatic resume on the survivors.** The next attempt restores the
  latest committed checkpoint and re-enters the driver on the surviving
  device set; the unpadded checkpoint re-pads for whatever mesh the
  smaller fleet builds, so a 4-device run resumes on 3 (or 1) with
  traces/counters/world byte-identical to the uninterrupted run — the
  orchestrator changes *where* the run executes, never *what* it computes.
* **Caps and floors.** ``max_retries`` bounds the preemption count,
  exponential ``backoff`` (capped) spaces the attempts, and ``min_devices``
  is the degraded-mode floor below which the run hard-fails
  (:class:`FleetError`) instead of limping.
* **Fleet counters.** ``C_PREEMPT`` / ``C_RESUME`` / ``C_RESHARD`` are
  registry-declared but booked *host-side* (``MetricsStream.book``) — never
  in-graph, so the resumed EngineState stays byte-identical to the
  uninterrupted run's, preemption bookkeeping included.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Callable, NamedTuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint import SimCheckpointer
from repro.core import policy as pol_mod
from repro.core.engine import Engine

_SIDECAR = "fleet.json"


class PreemptionError(RuntimeError):
    """A shard-loss signal: the run lost devices mid-flight.

    Raised by the injected probe (or any window hook) to abort the current
    attempt; ``survivors`` is the surviving device count the orchestrator
    shrinks to before resuming."""

    def __init__(self, survivors: int, at_window: int | None = None):
        self.survivors = int(survivors)
        self.at_window = at_window
        super().__init__(
            f"preempted at window {at_window}: "
            f"{self.survivors} surviving device(s)")


class FleetError(RuntimeError):
    """Unrecoverable orchestration failure: the degraded-mode device floor
    was breached, the retry cap was exhausted, or the policy is invalid."""


@dataclasses.dataclass(frozen=True)
class FleetPolicy:
    """Declarative orchestration policy for one elastic run.

    ``driver`` selects the engine driver (``"auto"`` = distributed when more
    than one device is given, the adaptive variant when the spec carries an
    exec ladder; ``"ensemble"`` runs the fused vmap-over-seeds driver, which
    supports neither checkpointing nor elastic resume — one XLA program has
    no window boundaries to save at). ``checkpoint_dir`` enables durable
    GVT-aligned checkpoints every ``checkpoint_every`` windows (the elastic
    loop requires it to resume across preemptions); ``kill_after`` passes
    through to the SIGKILL crash harness. ``max_retries`` caps preemptions
    per run, ``backoff``/``backoff_cap`` space the attempts (seconds;
    attempt k sleeps ``min(backoff * 2**(k-1), backoff_cap)``), and
    ``min_devices`` is the degraded-mode floor: a preemption that leaves
    fewer survivors hard-fails instead of resuming."""

    driver: str = "auto"
    checkpoint_dir: str | None = None
    checkpoint_every: int = 8
    checkpoint_keep: int = 3
    kill_after: int | None = None
    max_windows: int = 10_000
    max_retries: int = 3
    backoff: float = 0.0
    backoff_cap: float = 30.0
    min_devices: int = 1

    _DRIVERS = ("auto", "local", "adaptive", "distributed",
                "distributed_adaptive", "ensemble")

    def __post_init__(self):
        if self.driver not in self._DRIVERS:
            raise FleetError(
                f"unknown driver {self.driver!r}; one of {self._DRIVERS}")
        if self.min_devices < 1:
            raise FleetError(
                f"min_devices must be >= 1, got {self.min_devices}")
        if self.max_retries < 0:
            raise FleetError(
                f"max_retries must be >= 0, got {self.max_retries}")
        if self.checkpoint_every < 0:
            raise FleetError(
                f"checkpoint_every must be >= 0, got {self.checkpoint_every}")


class OrchestratorResult(NamedTuple):
    """The elastic run's outcome.

    ``state`` is the final unpadded EngineState (stacked ``(R, A, ...)``
    for the ensemble driver); ``devices`` the device count the finishing
    attempt ran on; ``attempts`` the total driver attempts (1 = no
    preemption); ``counts`` the host-side fleet-counter books
    (``{"PREEMPT": n, "RESUME": n, "RESHARD": n}``)."""

    state: Any
    driver: str
    devices: int
    attempts: int
    counts: dict


class Orchestrator:
    """The elastic host loop: checkpoint, preempt, shrink, resume, finish.

    Streams (``trace_stream``/``metrics_stream``) and the device-side trace
    ring size (``trace_cap``/``drain_every``) are orchestrator-level because
    they must outlive individual engine attempts: the same stream objects
    attach to every attempt's engine, and the checkpoint/restore path
    carries their host state across the preemption boundary so observability
    concatenates exactly.

    ``preempt`` is the injected shard-loss probe for tests and smokes:
    ``preempt(window, attempt) -> surviving-device-count | None``, called at
    every host-stepped window boundary (after any due checkpoint save).
    Returning an int aborts the attempt with :class:`PreemptionError`.
    """

    def __init__(self, policy: FleetPolicy | None = None, *,
                 trace_stream=None, metrics_stream=None,
                 preempt: Callable[[int, int], int | None] | None = None,
                 trace_cap: int = 0, drain_every: int = 16,
                 sleep: Callable[[float], None] = time.sleep):
        self.policy = FleetPolicy() if policy is None else policy
        self.trace_stream = trace_stream
        self.metrics_stream = metrics_stream
        self._preempt = preempt
        self.trace_cap = trace_cap
        self.drain_every = drain_every
        self._sleep = sleep
        self.counts = {"PREEMPT": 0, "RESUME": 0, "RESHARD": 0}

    # ------------------------------------------------------------- bookkeeping
    def _book(self, name: str, amount: int = 1) -> None:
        """Host-side fleet-counter booking (never the in-graph vector)."""
        self.counts[name] += amount
        if self.metrics_stream is not None:
            self.metrics_stream.book(name, amount)

    def _sidecar_path(self, pol: FleetPolicy) -> str | None:
        if pol.checkpoint_dir is None:
            return None
        return os.path.join(pol.checkpoint_dir, _SIDECAR)

    def _write_sidecar(self, pol: FleetPolicy, n_devices: int,
                       clean: bool) -> None:
        """Record the attempt's device count and books (atomic rename).

        ``clean=False`` at attempt start, flipped to True only on a
        completed run — a missing clean flag at the next start IS the
        process-death preemption signal (the SIGKILL lane)."""
        path = self._sidecar_path(pol)
        if path is None:
            return
        os.makedirs(pol.checkpoint_dir, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"n_devices": n_devices, "clean": clean,
                       "counts": self.counts}, f)
        os.replace(tmp, path)

    def _read_sidecar(self, pol: FleetPolicy) -> dict | None:
        path = self._sidecar_path(pol)
        if path is None or not os.path.exists(path):
            return None
        with open(path) as f:
            return json.load(f)

    # ---------------------------------------------------------------- dispatch
    def _resolve_driver(self, pol: FleetPolicy, spec, n_devices: int) -> str:
        if pol.driver != "auto":
            return pol.driver
        ladder = isinstance(spec.exec_policy, pol_mod.ExecPolicy)
        if n_devices > 1:
            return "distributed_adaptive" if ladder else "distributed"
        return "adaptive" if ladder else "local"

    def _dispatch(self, engine: Engine, driver: str, pol: FleetPolicy,
                  devices: list, state, rung):
        mw = pol.max_windows
        if driver == "local":
            return engine.run_local(mw, state=state)
        if driver == "adaptive":
            return engine.run_adaptive(mw, state=state, rung=rung)
        mesh = Mesh(np.array(devices), ("agents",))
        if driver == "distributed":
            return engine.run_distributed(mesh, mw, state=state)
        if driver == "distributed_adaptive":
            return engine.run_distributed_adaptive(mesh, mw, state=state,
                                                   rung=rung)
        raise FleetError(f"unknown driver {driver!r}")  # pragma: no cover

    def _hook(self, attempt: int):
        """The engine window hook wrapping the injected preemption probe."""
        probe = self._preempt
        if probe is None:
            return None

        def hook(window: int, _state) -> None:
            survivors = probe(window, attempt)
            if survivors is not None:
                raise PreemptionError(survivors, at_window=window)

        return hook

    # --------------------------------------------------------------------- run
    def run(self, built, devices=None,
            policy: FleetPolicy | None = None,
            seeds=None) -> OrchestratorResult:
        """Run a built scenario elastically to completion.

        ``built`` is the ``(world, own, init_events, spec)`` tuple of
        ``ScenarioBuilderBase.build`` (what a catalog entry resolves to);
        ``devices`` the device list to start on (default ``jax.devices()``);
        ``policy`` overrides the constructor's. For the ensemble driver,
        ``seeds`` is the per-replica seed vector.

        Use a fresh ``checkpoint_dir`` per logical run: existing committed
        checkpoints in the directory are treated as *this* run's and
        auto-resumed (that is exactly the restart-after-SIGKILL contract).
        """
        pol = self.policy if policy is None else policy
        world, own, init_events, spec = built
        if pol.driver == "ensemble":
            return self._run_ensemble(built, pol, seeds)
        devices = list(jax.devices()) if devices is None else list(devices)
        ck = None
        if pol.checkpoint_dir is not None and pol.checkpoint_every > 0:
            ck = SimCheckpointer(pol.checkpoint_dir,
                                 every=pol.checkpoint_every,
                                 keep=pol.checkpoint_keep,
                                 kill_after=pol.kill_after)

        # The SIGKILL lane: a sidecar without the clean flag means the prior
        # orchestrated process died mid-run — restore its books and count
        # the death as the preemption it was.
        prev = self._read_sidecar(pol)
        saved_n_dev = None
        if prev is not None and not prev.get("clean", False):
            for name, value in (prev.get("counts") or {}).items():
                if name in self.counts and value:
                    self._book(name, int(value) - self.counts[name])
            saved_n_dev = prev.get("n_devices")
            self._book("PREEMPT")

        attempt = 0
        while True:
            n_dev = len(devices)
            if n_dev < pol.min_devices:
                raise FleetError(
                    f"degraded below the device floor: {n_dev} survivor(s) "
                    f"< min_devices={pol.min_devices}")
            driver = self._resolve_driver(pol, spec, n_dev)
            engine = Engine(world, own, init_events, spec,
                            trace_cap=self.trace_cap,
                            trace_stream=self.trace_stream,
                            metrics_stream=self.metrics_stream,
                            drain_every=self.drain_every,
                            checkpointer=ck,
                            window_hook=self._hook(attempt))
            state = rung = None
            if ck is not None and ck.latest_step() is not None:
                rec = engine.restore()
                state, rung = rec.state, rec.rung
                self._book("RESUME")
                if saved_n_dev is not None and saved_n_dev != n_dev:
                    self._book("RESHARD")
            self._write_sidecar(pol, n_dev, clean=False)
            try:
                st = self._dispatch(engine, driver, pol, devices, state, rung)
            except PreemptionError as e:
                self._book("PREEMPT")
                attempt += 1
                if attempt > pol.max_retries:
                    raise FleetError(
                        f"retry cap exhausted: {attempt - 1} retries after "
                        f"{self.counts['PREEMPT']} preemption(s)") from e
                saved_n_dev = n_dev
                if e.survivors < n_dev:
                    devices = devices[:e.survivors]
                if pol.backoff > 0:
                    self._sleep(min(pol.backoff * 2 ** (attempt - 1),
                                    pol.backoff_cap))
                continue
            self._write_sidecar(pol, n_dev, clean=True)
            return OrchestratorResult(state=st, driver=driver, devices=n_dev,
                                      attempts=attempt + 1,
                                      counts=dict(self.counts))

    def _run_ensemble(self, built, pol: FleetPolicy,
                      seeds) -> OrchestratorResult:
        """The fused vmap-over-seeds driver (no elastic features: one XLA
        program has no window boundaries to checkpoint or probe at — the
        engine itself rejects streaming traces and checkpointing here)."""
        if seeds is None:
            raise FleetError("the ensemble driver needs a seed vector "
                             "(pass seeds=)")
        world, own, init_events, spec = built
        engine = Engine(world, own, init_events, spec,
                        metrics_stream=self.metrics_stream)
        st = engine.run_ensemble(np.asarray(seeds), pol.max_windows)
        return OrchestratorResult(state=st, driver="ensemble", devices=1,
                                  attempts=1, counts=dict(self.counts))
