"""Training launcher: ``python -m repro.launch.train --arch smollm-135m --steps 300``.

On this CPU container it trains reduced configs (--smoke, default) or the real
config on a single device; on a TPU fleet the same entrypoint builds the
production mesh (launch/mesh.py), applies the sharding rules from
launch/dryrun.RULE_VARIANTS and runs the identical jit'd step.
"""
from __future__ import annotations

import argparse

from repro.configs.base import TrainConfig
from repro.configs.registry import ARCHS, get_config, smoke_config
from repro.data import pipeline as dp
from repro.models.model import build_model
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=ARCHS)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--full", dest="smoke", action="store_false")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    tc = TrainConfig(learning_rate=args.lr, warmup_steps=20,
                     microbatches=args.microbatches)
    dcfg = dp.DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                         global_batch=args.global_batch)
    extra = None
    if cfg.family == "encdec":
        import jax, jax.numpy as jnp
        extra = {"frames": jax.random.normal(
            jax.random.PRNGKey(0),
            (args.global_batch, args.seq_len, cfg.d_model), jnp.float32)}
        dcfg = dp.DataConfig(vocab=cfg.vocab, seq_len=cfg.decoder_len,
                             global_batch=args.global_batch)
    params, opt_state, history = train(
        model, tc, steps=args.steps, data_cfg=dcfg, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, extra_batch=extra)
    print(f"[train] done: first-10 loss {sum(history[:10]) / max(len(history[:10]),1):.4f} "
          f"-> last-10 loss {sum(history[-10:]) / max(len(history[-10:]),1):.4f}")


if __name__ == "__main__":
    main()
