"""Serving launcher: batched requests through the ServeEngine.

``python -m repro.launch.serve --arch deepseek-7b --requests 8``
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs.registry import ARCHS, get_config, smoke_config
from repro.models.model import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b", choices=ARCHS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    args = ap.parse_args()

    import dataclasses
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, cache_headroom=args.max_new)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_slots=args.batch_slots,
                      prompt_len=args.prompt_len,
                      temperature=args.temperature)
    rng = jax.random.PRNGKey(1)
    reqs = []
    for i in range(args.requests):
        rng, k = jax.random.split(rng)
        toks = jax.random.randint(k, (12,), 1, cfg.vocab).tolist()
        reqs.append(Request(rid=i, tokens=toks, max_new=args.max_new))

    done = 0
    t0 = time.perf_counter()
    for i in range(0, len(reqs), args.batch_slots):
        batch = reqs[i:i + args.batch_slots]
        eng.run(batch, max_ticks=args.max_new + 2)
        done += sum(r.done for r in batch)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out) for r in reqs)
    print(f"[serve] {done}/{len(reqs)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens / dt:.1f} tok/s)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: {r.out}")


if __name__ == "__main__":
    main()
