"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax device
state — the dry-run sets XLA_FLAGS for 512 host devices before any jax import,
and tests/benches must keep seeing 1 device.

Topology: TPU v5e pods of 256 chips as a (16, 16) (data, model) grid; the
multi-pod mesh adds a leading "pod" axis (2, 16, 16) whose collectives cross DCN
— which is why gradient compression (train/compression.py) targets exactly that
axis and why the sharding rules put batch on ("pod", "data") but weights (fsdp)
only on "data" (no cross-pod weight gathers on the critical path).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_devices: int | None = None, model: int = 2):
    """Small mesh over available devices (subprocess tests with 4-8 devices)."""
    n = n_devices or len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_sim_mesh(n_devices: int | None = None, axis: str = "agents"):
    """1-D mesh for the DES engine's scale-out driver.

    ``Engine.run_distributed`` composes shard_map over this axis with vmap
    inside each shard, packing ceil(n_agents / n_devices) agent rows per
    device — so any agent count works on any device count; the axis only has
    to be 1-D (the engine splits it internally into (shard, lane))."""
    n = n_devices or len(jax.devices())
    return jax.make_mesh((n,), (axis,))


# Hardware constants (TPU v5e) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12     # per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW_PER_LINK = 50e9       # bytes/s per link
CHIPS_PER_POD = 256
