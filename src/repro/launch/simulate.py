"""Simulation launcher — the paper's own workflow, as a CLI.

Modes:
  t0t1       reproduce the paper's §3.1 CERN study (bandwidth sweep)
  workload   simulate a training cell from a dry-run roofline JSON
  distributed run the T0/T1 scenario under the shard_map x vmap scale-out
             driver (needs >1 device:
             XLA_FLAGS=--xla_force_host_platform_device_count=8);
             --agents-per-device packs multiple agent rows per shard,
             --migrate demos cross-shard event migration, --adaptive-exec
             runs the lockstep per-shard width ladder
  ensemble   Monte Carlo vmap-over-seeds sweep of the failure scenario:
             hundreds of replicas per launch (Engine.run_ensemble), with
             per-replica counters reduced into a MetricsStream summary
  run        resolve a named catalog scenario (repro.scenarios.catalog) and
             dispatch it through the elastic fleet orchestrator
             (repro.fleet.Orchestrator): ``simulate run t0t1 --set wan_bw=0.5``;
             ``simulate run --list`` prints the catalog. The orchestrator
             knobs (--max-retries/--min-devices/--preempt-at-window ...)
             make it the elastic-execution entry point: a preempted run
             auto-resumes from the latest checkpoint on the survivors.

The t0t1 and distributed modes take durable checkpoint/resume knobs:
``--checkpoint-dir D --checkpoint-every W`` saves the full EngineState at
every W-th GVT-aligned window boundary; ``--resume`` restores the latest
checkpoint and continues — for distributed, onto whatever device count the
resumed process has (the checkpoint is device-layout-free). A multi-point
t0t1 sweep keys per-point subdirectories (``DIR/bw_<bw>``) so every sweep
point checkpoints and resumes independently.
``--kill-after-window W`` SIGKILLs the process right after the first
committed checkpoint at window >= W — the CI crash harness.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

import numpy as np


def _stream_args(p):
    """The host-streaming observability knobs (t0t1 + distributed modes)."""
    p.add_argument("--stream-trace", type=int, default=None, metavar="CAP",
                   help="stream the full event trace to the host through a "
                        "CAP-row device-side ring drained at window "
                        "boundaries (keeps C_TRACE_DROP == 0 for runs of any "
                        "length; CAP must be >= the exec width)")
    p.add_argument("--metrics-interval", type=int, default=None, metavar="N",
                   help="emit a fleet metrics snapshot as one JSON line on "
                        "stdout every N windows (registry-declared counter "
                        "names; a final snapshot is always emitted)")
    p.add_argument("--drain-every", type=int, default=16, metavar="N",
                   help="trace-ring drain cadence in windows (forced drains "
                        "still fire whenever the next window could overrun "
                        "the ring; default 16)")


def _build_streams(args):
    """(engine kwargs, TraceStream | None, MetricsStream | None) from the
    CLI knobs — empty kwargs when streaming is off."""
    kw = {}
    ts = ms = None
    if args.stream_trace is not None:
        from repro.core.monitoring import TraceStream
        ts = TraceStream()
        kw.update(trace_cap=args.stream_trace, trace_stream=ts,
                  drain_every=args.drain_every)
    if args.metrics_interval is not None:
        from repro.core.monitoring import MetricsStream
        ms = MetricsStream(interval=args.metrics_interval, out=sys.stdout)
        kw.update(metrics_stream=ms, drain_every=args.drain_every)
    return kw, ts, ms


def _checkpoint_args(p):
    """The durable checkpoint/resume knobs (t0t1 + distributed modes)."""
    p.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                   help="directory for durable EngineState checkpoints "
                        "(atomic step_* subdirs; enables the other "
                        "checkpoint knobs)")
    p.add_argument("--checkpoint-every", type=int, default=0, metavar="W",
                   help="save a checkpoint every W windows (GVT-aligned "
                        "boundaries; 0 disables periodic saves)")
    p.add_argument("--checkpoint-keep", type=int, default=3, metavar="N",
                   help="retain the newest N checkpoints (default 3)")
    p.add_argument("--resume", action="store_true",
                   help="restore the latest checkpoint from "
                        "--checkpoint-dir and continue the run from it "
                        "(byte-identical to never having stopped)")
    p.add_argument("--kill-after-window", type=int, default=None, metavar="W",
                   help="SIGKILL this process right after the first "
                        "committed checkpoint at window >= W (crash-harness "
                        "knob; needs --checkpoint-every)")


def _build_checkpointer(args, directory=None):
    """A SimCheckpointer from the CLI knobs, or None when checkpointing is
    off — with the cross-knob validation in one place. ``directory``
    overrides ``args.checkpoint_dir`` (the per-sweep-point subdir case)."""
    if args.checkpoint_dir is None:
        if (args.checkpoint_every or args.resume
                or args.kill_after_window is not None):
            raise SystemExit("--checkpoint-every/--resume/--kill-after-window "
                             "need --checkpoint-dir DIR")
        return None
    if args.kill_after_window is not None and not args.checkpoint_every:
        raise SystemExit("--kill-after-window needs --checkpoint-every W "
                         "(the kill fires after a committed checkpoint)")
    from repro.checkpoint import SimCheckpointer
    return SimCheckpointer(directory or args.checkpoint_dir,
                           every=args.checkpoint_every,
                           keep=args.checkpoint_keep,
                           kill_after=args.kill_after_window)


def _exec_policy_args(args, pool_cap):
    """(exec_cap | exec_policy) build kwargs from the CLI knobs.

    ``pool_cap`` must be the value the builder is given — the default ladder
    tops out at the pool, so the two may not drift apart.
    """
    if not getattr(args, "adaptive_exec", False):
        return dict(exec_cap=args.exec_cap)
    if args.exec_cap is not None:
        raise SystemExit(
            "--exec-cap and --adaptive-exec conflict: pass either a static "
            "width or a ladder (--exec-ladder), not both")
    from repro.core.policy import ExecPolicy, default_ladder
    ladder = (tuple(args.exec_ladder) if args.exec_ladder
              else default_ladder(pool_cap))
    return dict(exec_policy=ExecPolicy(ladder=ladder))


def run_t0t1(args):
    from repro.core import Engine, ScenarioBuilder
    from repro.core import monitoring as mon
    from repro.core.components import DATA_WRITE, FLOW_START, JOB_SUBMIT

    # A multi-point sweep keys one checkpoint subdir per bandwidth so every
    # point saves/resumes independently (a single point uses DIR itself).
    sweep_dirs = {bw: args.checkpoint_dir for bw in args.bandwidths}
    if args.checkpoint_dir is not None and len(args.bandwidths) > 1:
        sweep_dirs = {bw: os.path.join(args.checkpoint_dir, f"bw_{bw:g}")
                      for bw in args.bandwidths}
    for bw in args.bandwidths:
        ck = _build_checkpointer(args, directory=sweep_dirs[bw])
        b = ScenarioBuilder(max_cpu=4, queue_cap=16, max_link=4, max_flow=32)
        t0 = b.add_regional_center(n_cpu=2, cpu_power=10.0, disk=2000.0,
                                   tape=20000.0, tape_rate=5.0)
        t1 = b.add_regional_center(n_cpu=2, cpu_power=8.0, disk=2000.0,
                                   tape=20000.0, tape_rate=5.0)
        wan = b.add_net_region(link_bws=[bw, bw], link_lats=[5, 5])
        b.add_generator(target_lp=wan, kind=FLOW_START,
                        payload=FLOW_START.pack(
                            size=40.0, l0=0, notify_lp=t1["farm"],
                            notify_kind=JOB_SUBMIT.id,
                            notify2_lp=t1["storage"],
                            notify2_kind=DATA_WRITE.id),
                        interval=15, count=args.flows)
        pool_cap = 1024
        world, own, init_ev, spec = b.build(
            n_agents=args.agents, lookahead=2, t_end=100_000,
            pool_cap=pool_cap, work_per_mb=2.0,
            batched_dispatch=args.batched_dispatch,
            merge_mode=args.merge_mode, insert_mode=args.insert_mode,
            fused_select=args.fused_select,
            **_exec_policy_args(args, pool_cap))
        stream_kw, ts, _ms = _build_streams(args)
        eng = Engine(world, own, init_ev, spec, checkpointer=ck, **stream_kw)
        state, rung = None, None
        if args.resume:
            rec = eng.restore()
            state, rung = rec.state, rec.rung
            print(f"[resume] window {rec.step} from {sweep_dirs[bw]}")
        if args.adaptive_exec:
            st = eng.run_adaptive(max_windows=200_000, state=state, rung=rung)
        else:
            st = eng.run_local(max_windows=200_000, state=state)
        c = np.asarray(st.counters).sum(axis=0)
        extra = ""
        if ts is not None:
            extra = (f" streamed={ts.n_streamed}"
                     f" trace_drop={int(c[mon.C_TRACE_DROP])}")
        print(f"[t0t1] bw={bw:7.3f} MB/tick  events={int(c[mon.C_EVENTS]):6d} "
              f"stale={int(c[mon.C_STALE]):5d} "
              f"interrupts={int(c[mon.C_INTERRUPTS]):5d} "
              f"MB={int(c[mon.C_MB_TRANSFERRED])} "
              f"windows={int(np.asarray(st.windows)[0])}" + extra)


def run_workload(args):
    from repro.core.workload import cell_from_roofline, simulate_training
    paths = sorted(glob.glob(os.path.join(args.results, "*.json")))
    if args.cell:
        paths = [p for p in paths if args.cell in p]
    for p in paths[: args.limit]:
        with open(p) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        cell = cell_from_roofline(rec["roofline"], n_pods=2, n_steps=4)
        out = simulate_training(cell)
        print(f"[workload] {rec['arch']} x {rec['shape']} x {rec['mesh']}: "
              f"sim={out['simulated_step_s']:.4f}s "
              f"analytic={out['analytic_step_s']:.4f}s "
              f"events={out['events']}")


def run_distributed(args):
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    import jax
    from repro.core import Engine, ScenarioBuilder
    from repro.core import monitoring as mon
    from repro.core.components import DATA_WRITE, FLOW_START, JOB_SUBMIT
    from repro.launch.mesh import make_sim_mesh

    n_dev = min(len(jax.devices()), 8)
    n = n_dev * args.agents_per_device
    b = ScenarioBuilder(max_cpu=4, queue_cap=16, max_link=4, max_flow=32)
    t0 = b.add_regional_center(n_cpu=2, cpu_power=10.0, disk=2000.0,
                               tape=20000.0, tape_rate=5.0)
    t1 = b.add_regional_center(n_cpu=2, cpu_power=8.0, disk=2000.0,
                               tape=20000.0, tape_rate=5.0)
    wan = b.add_net_region(link_bws=[0.5, 0.5], link_lats=[5, 5])
    b.add_generator(target_lp=wan, kind=FLOW_START,
                    payload=FLOW_START.pack(
                        size=40.0, l0=0, notify_lp=t1["farm"],
                        notify_kind=JOB_SUBMIT.id, notify2_lp=t1["storage"],
                        notify2_kind=DATA_WRITE.id),
                    interval=15, count=args.flows)
    pool_cap = 512
    world, own, init_ev, spec = b.build(n_agents=n, lookahead=2,
                                        t_end=100_000, pool_cap=pool_cap,
                                        work_per_mb=2.0,
                                        batched_dispatch=args.batched_dispatch,
                                        merge_mode=args.merge_mode,
                                        insert_mode=args.insert_mode,
                                        fused_select=args.fused_select,
                                        **_exec_policy_args(args, pool_cap))
    if args.stream_check and args.stream_trace is None:
        raise SystemExit("--stream-check needs --stream-trace CAP")
    ck = _build_checkpointer(args)
    if args.resume and args.migrate:
        raise SystemExit("--resume and --migrate conflict: the checkpoint "
                         "already contains the (possibly migrated) state")
    stream_kw, ts, _ms = _build_streams(args)
    eng = Engine(world, own, init_ev, spec, checkpointer=ck, **stream_kw)
    mesh = make_sim_mesh(n_dev)
    state = None
    if args.migrate and n > 1:
        # cross-shard migration demo: move the agent holding the seeded
        # events (the generator LP's owner) to the opposite end of the fleet
        # so its pool ships through the all_to_all path, then continue from
        # the migrated state
        st0 = eng.init_state()
        la = np.asarray(st0.world.lp_agent[0])
        src = int(np.asarray(st0.pool.valid).sum(axis=1).argmax())
        dst = 0 if src != 0 else n - 1
        new_la = np.where(la == src, dst,
                          np.where(la == dst, src, la)).astype(np.int32)
        state = eng.apply_placement_distributed(st0, new_la, mesh)
    run_state, run_rung = state, None
    if args.resume:
        rec = eng.restore()
        run_state, run_rung = rec.state, rec.rung
        print(f"[resume] window {rec.step} from {args.checkpoint_dir} "
              f"onto {n_dev} devices")
    if args.adaptive_exec:
        st = eng.run_distributed_adaptive(mesh, max_windows=200_000,
                                          state=run_state, rung=run_rung)
    else:
        st = eng.run_distributed(mesh, max_windows=200_000, state=run_state)
    c = np.asarray(st.counters).sum(axis=0)
    extra = ""
    if args.migrate:
        extra = (f" migrate_out={int(c[mon.C_MIGRATE_OUT])}"
                 f" migrate_in={int(c[mon.C_MIGRATE_IN])}")
    if args.adaptive_exec:
        extra += f" rungs={sorted(set(eng.adaptive_rungs))}"
    if ts is not None:
        extra += (f" streamed={ts.n_streamed}"
                  f" trace_drop={int(c[mon.C_TRACE_DROP])}")
    print(f"[distributed] agents={n} devices={n_dev} "
          f"events={int(c[mon.C_EVENTS])} "
          f"windows={int(np.asarray(st.windows)[0])} "
          f"remote_msgs={int(c[mon.C_MSGS_REMOTE])}" + extra)
    if args.stream_check:
        # end-to-end streaming gate (CI): the streamed trace must (1) have
        # dropped nothing, (2) actually exceed the in-device ring (the run
        # would fit in the buffer otherwise and the check would be vacuous),
        # and (3) be byte-identical to an un-streamed reference run with a
        # buffer big enough to hold everything — which PR 6 pinned to the
        # sequential oracle, closing the chain stream == buffer == oracle.
        # Under --resume the reference still replays the FULL run from
        # scratch (state is the initial state, not the restored one), so the
        # equality proves the killed-and-resumed streamed trace is exactly
        # the never-interrupted trace.
        from repro.core import merged_engine_trace
        drop = int(c[mon.C_TRACE_DROP])
        if drop:
            raise SystemExit(f"stream-check FAILED: C_TRACE_DROP={drop}")
        tn = np.asarray(st.trace_n)
        if int(tn.max()) <= args.stream_trace:
            raise SystemExit(
                f"stream-check vacuous: per-agent trace_n max {int(tn.max())}"
                f" never exceeded the ring cap {args.stream_trace} — lower "
                f"--stream-trace or raise the event count")
        ref_eng = Engine(world, own, init_ev, spec, trace_cap=1 << 16)
        if args.adaptive_exec:
            ref = ref_eng.run_distributed_adaptive(mesh, max_windows=200_000,
                                                   state=state)
        else:
            ref = ref_eng.run_distributed(mesh, max_windows=200_000,
                                          state=state)
        want = merged_engine_trace(np.asarray(ref.trace),
                                   np.asarray(ref.trace_n))
        got = ts.merged()
        if got != want:
            raise SystemExit(
                f"stream-check FAILED: streamed trace ({len(got)} rows) != "
                f"in-device reference ({len(want)} rows)")
        print(f"[stream-check] OK: {len(got)} rows streamed through a "
              f"{args.stream_trace}-row ring == reference, trace_drop=0")


def run_ensemble(args):
    from repro.core import Engine
    from repro.core.monitoring import MetricsStream
    from repro.scenarios.failures import build_failure_scenario

    built, _info = build_failure_scenario(n_farms=args.farms,
                                          pool_cap=args.pool_cap)
    ms = MetricsStream(interval=1_000_000, out=sys.stdout)
    eng = Engine(*built, metrics_stream=ms)
    seeds = np.arange(args.seed0, args.seed0 + args.replicas, dtype=np.int32)
    eng.run_ensemble(seeds)
    ev_stats = ms.latest["per_replica"]["EVENTS"]
    fail_stats = ms.latest["per_replica"]["CPU_FAILS"]
    print(f"[ensemble] replicas={args.replicas} farms={args.farms} "
          f"windows={ms.latest['windows']} "
          f"events/replica min={ev_stats['min']} mean={ev_stats['mean']:.1f} "
          f"max={ev_stats['max']} "
          f"fails/replica min={fail_stats['min']} max={fail_stats['max']}")


def run_catalog(args):
    from repro.scenarios import catalog

    if args.list:
        for name in catalog.names():
            sd = catalog.get(name)
            print(f"{name:15s} [{sd.driver}] {sd.doc}")
            defaults = " ".join(f"{k}={v}" for k, v in sd.params)
            if defaults:
                print(f"{'':15s} params: {defaults}")
        return
    if args.name is None:
        raise SystemExit("simulate run: pass a scenario name (or --list)")
    overrides = {}
    for item in args.set:
        key, sep, value = item.partition("=")
        if not sep or not key:
            raise SystemExit(f"--set expects K=V, got {item!r}")
        overrides[key] = value
    try:
        sd = catalog.get(args.name)
        built, params = sd.resolve(overrides)
    except catalog.CatalogError as e:
        raise SystemExit(str(e)) from None

    if args.devices is not None and args.devices > 1:
        os.environ.setdefault("XLA_FLAGS",
                              "--xla_force_host_platform_device_count=8")
    import jax
    from repro.fleet import FleetPolicy, Orchestrator

    devices = None
    if args.devices is not None:
        have = jax.devices()
        if args.devices > len(have):
            raise SystemExit(f"--devices {args.devices} > available "
                             f"{len(have)} (set XLA_FLAGS="
                             f"--xla_force_host_platform_device_count=N)")
        devices = have[: args.devices]

    preempt = None
    if args.preempt_at_window is not None:
        if args.preempt_survivors is None:
            raise SystemExit("--preempt-at-window needs --preempt-survivors K")
        if args.checkpoint_dir is None:
            raise SystemExit("--preempt-at-window needs --checkpoint-dir DIR "
                             "(the resume path requires checkpoints)")

        def preempt(window, attempt, *, _w=args.preempt_at_window,
                    _k=args.preempt_survivors):
            # one injected shard loss: the first attempt dies once it
            # reaches window _w, leaving _k survivors; later attempts run out
            return _k if attempt == 0 and window >= _w else None

    if args.stream_check and args.stream_trace is None:
        raise SystemExit("--stream-check needs --stream-trace CAP")
    _stream_kw, ts, ms = _build_streams(args)
    pol = FleetPolicy(
        driver=sd.driver if sd.driver != "auto" else args.driver,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
        checkpoint_keep=args.checkpoint_keep,
        kill_after=args.kill_after_window,
        max_windows=args.max_windows,
        max_retries=args.max_retries,
        backoff=args.backoff,
        min_devices=args.min_devices)
    orch = Orchestrator(pol, trace_stream=ts, metrics_stream=ms,
                        preempt=preempt,
                        trace_cap=args.stream_trace or 0,
                        drain_every=args.drain_every)
    seeds = None
    if sd.driver == "ensemble":
        seeds = np.arange(params["seed0"],
                          params["seed0"] + params["replicas"],
                          dtype=np.int32)
    res = orch.run(built, devices=devices, seeds=seeds)

    from repro.core import monitoring as mon
    st = res.state
    cn = np.asarray(st.counters)  # (A, N) — or (R, A, N) for ensembles
    c = cn.sum(axis=tuple(range(cn.ndim - 1)))
    print(f"[run] {args.name} driver={res.driver} devices={res.devices} "
          f"attempts={res.attempts} events={int(c[mon.C_EVENTS])} "
          f"windows={int(np.asarray(st.windows).reshape(-1)[0])} "
          f"preempt={res.counts['PREEMPT']} resume={res.counts['RESUME']} "
          f"reshard={res.counts['RESHARD']}")
    if args.stream_check:
        # the elastic streaming gate: the (possibly preempted-and-resumed)
        # streamed trace must have dropped nothing, actually exceeded the
        # in-device ring, and be byte-identical to an un-streamed big-buffer
        # reference run that was never interrupted — the zero-drop oracle
        # equality the orchestrator promises.
        from repro.core import Engine, merged_engine_trace
        drop = int(c[mon.C_TRACE_DROP])
        if drop:
            raise SystemExit(f"stream-check FAILED: C_TRACE_DROP={drop}")
        tn = np.asarray(st.trace_n)
        if int(tn.max()) <= args.stream_trace:
            raise SystemExit(
                f"stream-check vacuous: per-agent trace_n max {int(tn.max())}"
                f" never exceeded the ring cap {args.stream_trace}")
        ref_eng = Engine(*built, trace_cap=1 << 16)
        if res.driver == "local":
            ref = ref_eng.run_local(pol.max_windows)
        elif res.driver == "adaptive":
            ref = ref_eng.run_adaptive(pol.max_windows)
        else:
            from jax.sharding import Mesh
            mesh = Mesh(np.array(jax.devices()[: res.devices]), ("agents",))
            if res.driver == "distributed_adaptive":
                ref = ref_eng.run_distributed_adaptive(mesh, pol.max_windows)
            else:
                ref = ref_eng.run_distributed(mesh, pol.max_windows)
        want = merged_engine_trace(np.asarray(ref.trace),
                                   np.asarray(ref.trace_n))
        got = ts.merged()
        if got != want:
            raise SystemExit(
                f"stream-check FAILED: streamed trace ({len(got)} rows) != "
                f"uninterrupted reference ({len(want)} rows)")
        print(f"[stream-check] OK: {len(got)} rows streamed through a "
              f"{args.stream_trace}-row ring across {res.attempts} "
              f"attempt(s) == uninterrupted reference, trace_drop=0")


def main():
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="mode", required=True)
    p1 = sub.add_parser("t0t1")
    p1.add_argument("--bandwidths", type=float, nargs="+",
                    default=[8.0, 2.0, 0.5, 0.125])
    p1.add_argument("--flows", type=int, default=24)
    p1.add_argument("--agents", type=int, default=1)
    p1.add_argument("--exec-cap", type=int, default=None,
                    help="per-window compacted execution cap "
                         "(default min(pool_cap, 256))")
    p1.add_argument("--batched-dispatch", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="grouped vectorized handler dispatch (engine step 4); "
                         "--no-batched-dispatch restores the sequential fold")
    p1.add_argument("--merge-mode", choices=("delta", "dense"),
                    default="delta",
                    help="batched-merge strategy: per-row delta scatters "
                         "(default) or the PR 2 whole-table reference merge")
    p1.add_argument("--insert-mode", choices=("ring", "ref"), default="ring",
                    help="event-pool lifecycle: free-list ring (default) or "
                         "the retained O(pool_cap) insert_ref scan")
    p1.add_argument("--fused-select", action="store_true",
                    help="run the window selection front-end (sort + safe "
                         "prefix + gather + conflict + rank + ring slots) as "
                         "one fused Pallas superstep megakernel instead of "
                         "the XLA-stitched stages (compiled on TPU, "
                         "interpreted elsewhere)")
    p1.add_argument("--adaptive-exec", action="store_true",
                    help="monitoring-driven exec width (core/policy.py "
                         "ladder; Engine.run_adaptive) instead of a static "
                         "exec_cap")
    p1.add_argument("--exec-ladder", type=int, nargs="+", default=None,
                    help="explicit width ladder for --adaptive-exec "
                         "(default: policy.default_ladder(pool_cap))")
    _stream_args(p1)
    _checkpoint_args(p1)
    p2 = sub.add_parser("workload")
    p2.add_argument("--results", default="results/dryrun")
    p2.add_argument("--cell", default="")
    p2.add_argument("--limit", type=int, default=5)
    p3 = sub.add_parser("distributed")
    p3.add_argument("--agents-per-device", type=int, default=2,
                    help="agent rows vmapped inside each shard (total agents "
                         "= devices x this; the engine pads internally, so "
                         "uneven packings also work via the API)")
    p3.add_argument("--migrate", action="store_true",
                    help="demo cross-shard event migration: swap the first "
                         "and last agents' LP placements through the "
                         "all_to_all freight path before running, and report "
                         "MIGRATE_OUT/MIGRATE_IN")
    p3.add_argument("--adaptive-exec", action="store_true",
                    help="lockstep monitoring-driven per-shard exec width "
                         "(Engine.run_distributed_adaptive) instead of a "
                         "static exec_cap")
    p3.add_argument("--exec-ladder", type=int, nargs="+", default=None,
                    help="explicit width ladder for --adaptive-exec "
                         "(default: policy.default_ladder(pool_cap))")
    p3.add_argument("--exec-cap", type=int, default=None,
                    help="per-window compacted execution cap "
                         "(default min(pool_cap, 256))")
    p3.add_argument("--batched-dispatch", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="grouped vectorized handler dispatch (engine step 4); "
                         "--no-batched-dispatch restores the sequential fold")
    p3.add_argument("--merge-mode", choices=("delta", "dense"),
                    default="delta",
                    help="batched-merge strategy: per-row delta scatters "
                         "(default) or the PR 2 whole-table reference merge")
    p3.add_argument("--insert-mode", choices=("ring", "ref"), default="ring",
                    help="event-pool lifecycle: free-list ring (default) or "
                         "the retained O(pool_cap) insert_ref scan")
    p3.add_argument("--fused-select", action="store_true",
                    help="run the window selection front-end as one fused "
                         "Pallas superstep megakernel instead of the "
                         "XLA-stitched stages (compiled on TPU, interpreted "
                         "elsewhere)")
    p3.add_argument("--flows", type=int, default=24,
                    help="generator flow count (drives total event volume — "
                         "raise it to push runs past any in-device trace cap)")
    _stream_args(p3)
    p3.add_argument("--stream-check", action="store_true",
                    help="end-to-end streaming gate (CI): after the streamed "
                         "run, assert C_TRACE_DROP == 0, that the trace "
                         "actually exceeded the ring cap, and that the "
                         "streamed trace is byte-identical to an un-streamed "
                         "big-buffer reference run; exit nonzero on any "
                         "mismatch")
    _checkpoint_args(p3)
    p4 = sub.add_parser("ensemble")
    p4.add_argument("--replicas", type=int, default=128,
                    help="Monte Carlo replicas per launch (one fused "
                         "vmap-over-seeds program; default 128)")
    p4.add_argument("--farms", type=int, default=4,
                    help="failure-scenario farm count (scenario size knob)")
    p4.add_argument("--pool-cap", type=int, default=256)
    p4.add_argument("--seed0", type=int, default=0,
                    help="first replica seed (replica r runs seed0 + r)")
    p5 = sub.add_parser("run")
    p5.add_argument("name", nargs="?", default=None,
                    help="catalog scenario name (see --list)")
    p5.add_argument("--list", action="store_true",
                    help="print the scenario catalog (names, drivers, "
                         "declared parameters) and exit")
    p5.add_argument("--set", action="append", default=[], metavar="K=V",
                    help="override a declared scenario parameter (repeat "
                         "for several; values are coerced to the default's "
                         "type — undeclared keys are a loud error)")
    p5.add_argument("--devices", type=int, default=None, metavar="N",
                    help="start the fleet on the first N jax devices "
                         "(default: all; >1 needs XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    p5.add_argument("--driver",
                    choices=("auto", "local", "adaptive", "distributed",
                             "distributed_adaptive"), default="auto",
                    help="engine driver (auto picks distributed/adaptive "
                         "from the device count and the spec's exec policy; "
                         "ensemble catalog entries force their own driver)")
    p5.add_argument("--max-windows", type=int, default=10_000, metavar="W",
                    help="per-attempt window budget (default 10000)")
    p5.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="durable checkpoint directory (enables the elastic "
                         "resume path; existing committed checkpoints are "
                         "auto-resumed — the restart-after-SIGKILL contract)")
    p5.add_argument("--checkpoint-every", type=int, default=8, metavar="W",
                    help="save every W windows (default 8; 0 disables)")
    p5.add_argument("--checkpoint-keep", type=int, default=3, metavar="N",
                    help="retain the newest N checkpoints (default 3)")
    p5.add_argument("--kill-after-window", type=int, default=None,
                    metavar="W",
                    help="SIGKILL the process right after the first "
                         "committed checkpoint at window >= W (the crash "
                         "lane; rerun the same command to auto-resume)")
    p5.add_argument("--max-retries", type=int, default=3, metavar="N",
                    help="preemption retry cap before FleetError (default 3)")
    p5.add_argument("--min-devices", type=int, default=1, metavar="N",
                    help="degraded-mode device floor: fewer survivors "
                         "hard-fail instead of resuming (default 1)")
    p5.add_argument("--backoff", type=float, default=0.0, metavar="S",
                    help="base retry backoff seconds (exponential, capped; "
                         "default 0 = immediate)")
    p5.add_argument("--preempt-at-window", type=int, default=None,
                    metavar="W",
                    help="inject one shard-loss preemption once the first "
                         "attempt reaches window W (the in-process elastic "
                         "smoke; needs --preempt-survivors and "
                         "--checkpoint-dir)")
    p5.add_argument("--preempt-survivors", type=int, default=None,
                    metavar="K",
                    help="surviving device count after the injected "
                         "preemption (the fleet shrinks to the first K)")
    _stream_args(p5)
    p5.add_argument("--stream-check", action="store_true",
                    help="elastic streaming gate (CI): after the run, "
                         "assert C_TRACE_DROP == 0, that the trace exceeded "
                         "the ring cap, and that the streamed trace is "
                         "byte-identical to an uninterrupted big-buffer "
                         "reference run; exit nonzero on any mismatch")
    args = ap.parse_args()
    dict(t0t1=run_t0t1, workload=run_workload, distributed=run_distributed,
         ensemble=run_ensemble, run=run_catalog)[args.mode](args)


if __name__ == "__main__":
    main()
