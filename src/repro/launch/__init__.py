"""repro.launch subpackage."""
