import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax-importing module: jax locks the
# device count at first init, and the production meshes need 512 placeholders.

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import SHAPES, TrainConfig, applicable_shapes  # noqa: E402
from repro.configs.registry import ARCHS, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import sharding as sh  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.roofline import analysis as roof  # noqa: E402
from repro.train.loop import make_train_step  # noqa: E402
from repro.train.optimizer import init_opt_state  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# Named sharding-rule variants for §Perf iterations.
RULE_VARIANTS: dict[str, dict] = {
    "baseline": dict(sh.DEFAULT_RULES),
    # fsdp over both pod+data: ZeRO-3 across the fleet (more weight gather, less mem)
    "fsdp_global": {**sh.DEFAULT_RULES,
                    "fsdp": (("pod", "data"), ("data",))},
    # sequence-parallel activations off (saved acts replicated over model axis)
    "no_seqpar": {**sh.DEFAULT_RULES, "act_seq": ()},
    # experts preferred over mlp sharding disabled (TP inside experts)
    "moe_tp": {**sh.DEFAULT_RULES, "experts": ()},
    # decode: shard the residual stream's embed dim over model — collectives
    # become reduce-scatters of d/16 instead of all-reduces of d (§Perf H2)
    "decode_embed": {**sh.DEFAULT_RULES, "embed": (("model",),)},
    # inference: no ZeRO weight sharding — fsdp gathers (whole weight matrices
    # per decoded token!) disappear; weights replicate over data, TP over model
    "serve": {**sh.DEFAULT_RULES, "fsdp": ()},
}


def _input_names(batch_specs: dict) -> dict:
    names = {}
    for k, v in batch_specs.items():
        if k == "positions3":
            names[k] = ("conv", "batch", "seq")
        elif v.ndim == 2:
            names[k] = ("batch", "seq")
        elif v.ndim == 3:
            names[k] = ("batch", "seq", "embed")
        else:
            names[k] = tuple(["seq"] * v.ndim)
    return names


def _kv_names(cache_sds):
    from repro.models.layers import KVCache
    return KVCache(k=("layers", "batch", "seq_kv", "kv_heads", "head"),
                   v=("layers", "batch", "seq_kv", "kv_heads", "head"),
                   length=("layers",))


def decode_state_names(model, state_sds):
    """names pytree congruent with the decode-state structure."""
    cfg = model.cfg
    out = {}
    for key, sub in state_sds.items():
        if key in ("kv", "kv_first") and sub is not None:
            out[key] = _kv_names(sub)
        elif key == "cross":
            nm = ("layers", "batch", "seq_kv", "kv_heads", "head")
            out[key] = (nm, nm)
        elif key == "rnn" and sub is not None:
            nm = {}
            for k2, leaf in sub.items():
                if k2 == "S":
                    nm[k2] = ("layers", "batch", "heads", "head", "head")
                elif k2 == "ssd":
                    nm[k2] = ("layers", "batch", "heads", "ssm_state", "head")
                else:  # tm_prev / cm_prev
                    nm[k2] = ("layers", "batch", "seq", "embed")
            out[key] = nm
        else:
            out[key] = sub
    return out


def shardings_for(sds_tree, names_tree, mesh, rules):
    ms = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(sds, names):
        if sds is None:
            return None
        if isinstance(names, tuple) and len(names) == len(sds.shape):
            spec = sh.spec_for(sds.shape, names, rules, ms)
        else:
            spec = jax.sharding.PartitionSpec()
        return jax.sharding.NamedSharding(mesh, spec)

    is_none = lambda x: x is None
    flat_sds, treedef = jax.tree.flatten(sds_tree, is_leaf=is_none)
    is_names = lambda x: x is None or (isinstance(x, tuple) and all(
        isinstance(s, str) or s is None for s in x))
    flat_names = jax.tree.flatten(names_tree, is_leaf=is_names)[0]
    assert len(flat_sds) == len(flat_names), (len(flat_sds), len(flat_names))
    return jax.tree.unflatten(treedef, [one(s, n) for s, n
                                        in zip(flat_sds, flat_names)])


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, rules_name="baseline",
             overrides=None, tag="", verbose=True, train_overrides=None):
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    if shape_name not in applicable_shapes(cfg):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped (DESIGN.md §6: not applicable)"}
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rules = RULE_VARIANTS[rules_name]
    chips = mesh.devices.size

    rng = jax.random.PRNGKey(0)
    holder = {}

    def _vals_only(r):
        vals, names = model.init(r)
        holder["names"] = names        # trace-invariant python side-channel
        return vals

    params_sds = jax.eval_shape(_vals_only, rng)
    names = holder["names"]
    p_shard = shardings_for(params_sds, names, mesh, rules)

    batch_specs = model.input_specs(shape)
    b_names = _input_names(batch_specs)
    b_shard = shardings_for(batch_specs, b_names, mesh, rules)

    t0 = time.time()
    with sh.sharding_ctx(mesh, rules):
        if shape.mode == "train":
            tc = TrainConfig(**(train_overrides or {}))
            opt_sds = jax.eval_shape(
                lambda p: init_opt_state(p, tc.opt_dtype), params_sds)
            o_shard = shardings_for(
                opt_sds, type(opt_sds)(step=(), m=names, v=names), mesh, rules)
            step = make_train_step(model, tc)
            jitted = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_sds, opt_sds, batch_specs)
        elif shape.mode == "prefill":
            jitted = jax.jit(model.prefill_fn, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(params_sds, batch_specs)
        else:  # decode
            state_sds = model.decode_state_specs(shape)
            s_names = decode_state_names(model, state_sds)
            s_shard = shardings_for(state_sds, s_names, mesh, rules)
            tok_sds = batch_specs["tokens"]
            tok_shard = shardings_for(
                {"tokens": tok_sds}, {"tokens": ("batch", "seq")}, mesh,
                rules)["tokens"]
            len_sds = jax.ShapeDtypeStruct((), jnp.int32)
            len_shard = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec())
            # logits stay vocab-sharded on the way out: sampling/argmax runs on
            # shards; replicating (b, vocab) f32 per token costs an all-gather
            # that dominated decode collectives (§Perf H2).
            logits_shard = shardings_for(
                {"x": jax.ShapeDtypeStruct(
                    (shape.global_batch, cfg.vocab), jnp.float32)},
                {"x": ("batch", "vocab")}, mesh, rules)["x"]
            jitted = jax.jit(model.decode_fn,
                             in_shardings=(p_shard, s_shard, tok_shard,
                                           len_shard),
                             out_shardings=(logits_shard, s_shard),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_sds, state_sds, tok_sds, len_sds)
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    mem_info = {}
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            if hasattr(mem, attr):
                mem_info[attr] = int(getattr(mem, attr))
    hlo = compiled.as_text()
    terms = roof.terms_from_artifacts(arch, shape, mesh_kind, chips, cfg,
                                      lowered.as_text(), hlo)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "chips": chips,
        "status": "ok", "rules": rules_name, "tag": tag,
        "overrides": {k: str(v) for k, v in (overrides or {}).items()},
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "memory_analysis": mem_info,
        "roofline": terms.row(),
        "hlo_bytes": len(hlo),
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: "
              f"compile {t_compile:.0f}s  bottleneck={terms.bottleneck}  "
              f"t=({terms.t_compute:.4f},{terms.t_memory:.4f},"
              f"{terms.t_collective:.4f})s  frac={terms.roofline_fraction:.3f}")
        print("  memory_analysis:", mem_info)
    return result


def cell_path(arch, shape, mesh_kind, rules_name="baseline", tag=""):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = f"__{tag}" if tag else (
        f"__{rules_name}" if rules_name != "baseline" else "")
    return os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh_kind}{suffix}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCHS) + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--rules", default="baseline", choices=list(RULE_VARIANTS))
    ap.add_argument("--tag", default="")
    ap.add_argument("--set", action="append", default=[],
                    help="ModelConfig overrides, e.g. --set causal_scheme=tri")
    ap.add_argument("--tset", action="append", default=[],
                    help="TrainConfig overrides, e.g. --tset microbatches=4")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    def parse_kv(items):
        out = {}
        for kv in items:
            k, v = kv.split("=", 1)
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
            out[k] = v
        return out

    overrides = parse_kv(args.set)
    train_overrides = parse_kv(args.tset)

    cells = []
    if args.all:
        for a in ARCHS:
            for s in applicable_shapes(get_config(a)):
                for m in ("single", "multi"):
                    cells.append((a, s, m))
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape, args.mesh)]

    for a, s, m in cells:
        path = cell_path(a, s, m, args.rules, args.tag)
        if os.path.exists(path) and not args.force:
            print(f"[dryrun] cached: {path}")
            continue
        try:
            res = run_cell(a, s, m, rules_name=args.rules,
                           overrides=overrides or None, tag=args.tag,
                           train_overrides=train_overrides or None)
        except Exception as e:  # noqa: BLE001 — record failures, keep sweeping
            res = {"arch": a, "shape": s, "mesh": m, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
            print(f"[dryrun] FAIL {a} x {s} x {m}: {e}")
        with open(path, "w") as f:
            json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
