"""starcoder2-3b [dense] — GQA kv=2 (assignment), RoPE, linear bias. [arXiv:2402.19173]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv=2, d_ff=12288, vocab=49152,
    rope_theta=1e5, use_bias=True,
)
