"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import ModelConfig

ARCHS = (
    "mixtral-8x22b",
    "moonshot-v1-16b-a3b",
    "codeqwen1.5-7b",
    "deepseek-7b",
    "smollm-135m",
    "starcoder2-3b",
    "hymba-1.5b",
    "qwen2-vl-72b",
    "whisper-large-v3",
    "rwkv6-7b",
)

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
            for a in ARCHS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config: tiny layers/width/experts for CPU smoke tests."""
    cfg = get_config(arch)
    kw = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=max(1, min(cfg.n_kv, 2)),
        d_ff=128,
        vocab=256,
        head_dim=16,
        attn_chunk_q=32,
        attn_chunk_kv=32,
        chunk_gla=16,
    )
    if cfg.n_experts:
        kw.update(n_experts=4, top_k=2)
    if cfg.window:
        kw.update(window=32)
    if cfg.encoder_layers:
        kw.update(encoder_layers=2, decoder_len=16)
    if cfg.ssm_state:
        kw.update(ssm_state=8)
    if cfg.moe_first_dense:
        kw.update(moe_first_dense=1)
    return dataclasses.replace(cfg, **kw)
