"""moonshot-v1-16b-a3b (Moonlight) [moe] — 64 experts top-6, leading dense layer.

[hf:moonshotai/Moonlight-16B-A3B; hf]. Deviation noted in DESIGN.md: shared experts
are folded into the routed set; the published leading dense layer is kept.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv=16, d_ff=1408, vocab=163840,
    n_experts=64, top_k=6, moe_first_dense=1, rope_theta=5e4,
)
