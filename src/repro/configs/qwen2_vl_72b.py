"""qwen2-vl-72b [vlm] — M-RoPE backbone; stub patch-embedding frontend.
[arXiv:2409.12191; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv=8, d_ff=29568, vocab=152064,
    m_rope=True, rope_theta=1e6, use_bias=True,
)
