"""hymba-1.5b [hybrid] — parallel attention + mamba(SSD) heads, SWA. [arXiv:2411.13676]

Deviations noted in DESIGN.md: all layers sliding-window (the published mix of
global/local layers breaks scan homogeneity); meta-tokens omitted; the SSM half is
the scalar-decay SSD form.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv=5, d_ff=5504, vocab=32001,
    ssm_state=16, window=1024, rope_theta=1e4,
)
