"""Architecture configs + shapes (--arch/--shape registry)."""
