"""whisper-large-v3 [audio] — enc-dec backbone; conv frontend is a stub
(input_specs provides precomputed frame embeddings). [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, d_model=1280, n_heads=20, n_kv=20, d_ff=5120, vocab=51866,
    encoder_layers=32, decoder_len=448, use_bias=True,
)
