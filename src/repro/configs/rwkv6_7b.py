"""rwkv6-7b (Finch) [ssm] — attention-free, data-dependent decay. [arXiv:2404.05892]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv=64, d_ff=14336, vocab=65536,
    rwkv=True, head_dim=64,
)
