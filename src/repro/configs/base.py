"""Model / run configuration dataclasses shared by the zoo, launcher and dry-run."""
from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "hybrid", "ssm", "encdec", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_first_dense: int = 0         # leading dense layers (moonshot/deepseek style)
    capacity_factor: float = 1.25

    # attention
    window: int = 0                  # sliding-window size; 0 = full causal
    rope_theta: float = 1e4
    m_rope: bool = False             # qwen2-vl multimodal RoPE
    use_bias: bool = False           # starcoder2-style linear bias

    # SSM / hybrid / linear-attn
    ssm_state: int = 0               # mamba state width (hymba)
    rwkv: bool = False               # rwkv6 channel/time mix instead of attention

    # encoder-decoder (whisper)
    encoder_layers: int = 0          # >0 => enc-dec; n_layers counts decoder layers
    decoder_len: int = 448

    # numerics / perf knobs
    dtype: str = "bfloat16"
    remat: Literal["full", "none"] = "full"
    use_flash: bool = False          # Pallas kernels (TPU); XLA chunked path otherwise
    attn_chunk_q: int = 2048
    attn_chunk_kv: int = 1024
    causal_scheme: Literal["rect", "tri"] = "rect"   # §Perf knob
    scan_layers: bool = True
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    chunk_gla: int = 64              # chunked gated-linear-attention block
    cache_headroom: int = 0          # extra KV slots beyond the prefill length
    kv_dtype: str = ""               # KV-cache dtype override ("float8_e4m3fn"
                                     # halves cache bytes; "" = activation dtype)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def sub_quadratic(self) -> bool:
        """Can this architecture decode 500k-token contexts? (DESIGN.md §6)"""
        return self.family in ("ssm", "hybrid") or self.window > 0

    @property
    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks), for roofline terms."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv * hd + self.n_heads * hd * d
        if self.rwkv:
            mix = 2 * d * d + d * self.n_heads * hd * 2   # r,k,v,w,g projections approx
            ffn = 2 * d * f
            block = mix + ffn
        elif self.n_experts:
            ffn_moe = self.n_experts * 3 * d * f + d * self.n_experts
            ffn_dense = 3 * d * f
            n_moe = self.n_layers - self.moe_first_dense
            block = attn + ffn_moe
            total = (n_moe * (attn + ffn_moe)
                     + self.moe_first_dense * (attn + ffn_dense) + 2 * v * d)
            return total
        else:
            ffn = 3 * d * f
            block = attn + ffn
        layers = self.n_layers + self.encoder_layers
        return layers * block + 2 * v * d

    @property
    def active_param_count(self) -> int:
        """Active params per token (= param_count for dense; routed subset for MoE)."""
        if not self.n_experts:
            return self.param_count
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv * hd + self.n_heads * hd * d
        ffn_act = self.top_k * 3 * d * f
        return self.n_layers * (attn + ffn_act) + 2 * v * d


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: Literal["train", "prefill", "decode"]


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """DESIGN.md §6: long_500k only for sub-quadratic archs; all else universal."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        names.append("long_500k")
    return names


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    microbatches: int = 1            # gradient-accumulation steps
    compress_grads: bool = False     # int8 + error-feedback DCN compression
    opt_dtype: str = "float32"       # Adam moment dtype ("bfloat16" halves state)
    seed: int = 0
