"""repro.checkpoint subpackage."""
