"""Checkpoint/resume of simulation and training state (atomic, resume-exact).

:class:`Checkpointer` saves any pytree; :class:`SimCheckpointer` is the
engine-aware layer — full ``EngineState`` snapshots at GVT-aligned window
boundaries, restorable into any of the four drivers on any device count.
``tools/check_api.py`` gates the saved key layout against the
registry-generated structs.
"""
from repro.checkpoint.checkpointer import (Checkpointer, SimCheckpoint,
                                           SimCheckpointer, tree_keys)

__all__ = ["Checkpointer", "SimCheckpoint", "SimCheckpointer", "tree_keys"]
