"""Checkpointing: atomic, async, per-host sharded, resume-exact.

Layout (one step):
  <dir>/step_000123.tmp/            written first
      host_<k>.npz                  this host's tree leaves (flattened)
      manifest.json                 keys + shapes + dtypes + step (+ sim aux)
  <dir>/step_000123/                atomic rename on completion (commit point)

Restart picks the highest committed step, validates the manifest against the
current tree structure, and casts leaves back to the template dtypes. The
async writer runs in a daemon thread; ``wait()`` joins before the next save
or exit. A 1000-node deployment maps host_<k> to the process index; here
(single process) k == 0 holds the full tree, which keeps tests exact without
loss of generality.

Two layers live here:

* :class:`Checkpointer` — the generic tree saver (any pytree: training
  params/opt tuples, raw arrays). Leaf keys come from
  ``jax.tree_util.tree_flatten_with_path`` via :func:`tree_keys`, so
  registry-generated NamedTuple structs (``World``, ``EngineState``) produce
  stable human-readable names like ``world/lp_agent`` — the layout
  ``tools/check_api.py`` gates against the regenerated structs.
* :class:`SimCheckpointer` — the engine-aware layer: ``save_sim`` captures a
  full ``EngineState`` at a GVT-aligned window boundary (event pool ring +
  cursors, world tables incl. in-handler RNG/LCG state, counters, trace
  ring + ``trace_tail``) plus the adaptive policy rung and the host-side
  drained :class:`~repro.core.monitoring.TraceStream` spans, so a resumed
  run — on any of the four drivers, on a *different* device count — is
  byte-identical to the uninterrupted one. Sim saves are blocking and the
  rename is the commit point, so a SIGKILL at any instant leaves either the
  previous checkpoint or the new one, never a torn file.
"""
from __future__ import annotations

import json
import os
import shutil
import signal
import threading
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


def _keystr(path) -> str:
    """One tree-path entry -> a stable, readable key segment.

    Registry-generated structs are NamedTuples, whose path entries are
    ``GetAttrKey`` (``.name``); dicts give ``DictKey`` (``.key``), tuples and
    lists ``SequenceKey`` (``.idx``). The pre-PR 4 code fell through to
    ``str(p)`` for NamedTuples, producing ``.world/.lp_agent``-style keys —
    the seed API drift this PR fixes.
    """
    parts = []
    for p in path:
        if hasattr(p, "name"):       # GetAttrKey (NamedTuple fields)
            parts.append(str(p.name))
        elif hasattr(p, "key"):      # DictKey / FlattenedIndexKey
            parts.append(str(p.key))
        elif hasattr(p, "idx"):      # SequenceKey
            parts.append(str(p.idx))
        else:  # pragma: no cover - future key types
            parts.append(str(p).strip("."))
    return "/".join(parts)


def _tree_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(_keystr(path), leaf) for path, leaf in flat]


def tree_keys(tree) -> list[str]:
    """The flattened leaf key names a tree saves under (checkpoint layout).

    For an ``EngineState`` this is ``world/<field>`` for every
    registry-generated ``World`` field, ``pool/<field>`` for the event pool
    (free ring + cursors included), and the top-level scalars (``counters``,
    ``t_now``, ``done``, ``windows``, ``trace``, ``trace_n``,
    ``trace_tail``). ``tools/check_api.py`` regenerates this list from a
    fresh registry and fails on drift.
    """
    return [k for k, _leaf in _tree_paths(tree)]


class Checkpointer:
    """Generic atomic tree checkpointing (see module docstring)."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ save
    def _write_step(self, step: int, arrays: dict[str, np.ndarray],
                    manifest: dict, *, host: int = 0,
                    blocking: bool = False) -> None:
        """Atomic commit of one step: tmp dir -> rename (the commit point)."""
        self.wait()

        def write():
            tmp = os.path.join(self.dir, f"step_{step:09d}.tmp")
            final = os.path.join(self.dir, f"step_{step:09d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, f"host_{host}.npz"), **arrays)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)                      # commit point
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def save(self, step: int, tree, *, host: int = 0, blocking: bool = False):
        arrays = {k: np.asarray(v) for k, v in _tree_paths(tree)}
        manifest = {
            "step": step,
            "keys": sorted(arrays),
            "shapes": {k: list(v.shape) for k, v in arrays.items()},
            "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        }
        self._write_step(step, arrays, manifest, host=host, blocking=blocking)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _read_step(self, step: int | None, *, host: int = 0):
        """(step, npz blob, manifest) of a committed step (default latest)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        blob = np.load(os.path.join(path, f"host_{host}.npz"))
        return step, blob, manifest

    def restore(self, tree_like, step: int | None = None, *, host: int = 0):
        """Restore into the structure of ``tree_like``. Returns (step, tree)."""
        step, blob, manifest = self._read_step(step, host=host)
        want = {k for k, _ in _tree_paths(tree_like)}
        have = set(manifest["keys"])
        if want != have:
            raise ValueError(
                f"checkpoint structure mismatch: missing {sorted(want - have)[:5]} "
                f"unexpected {sorted(have - want)[:5]}")
        flat, treedef = jax.tree_util.tree_flatten(tree_like)
        keys = [k for k, _ in _tree_paths(tree_like)]
        leaves = []
        for k, proto in zip(keys, flat):
            arr = blob[k]
            leaves.append(jnp.asarray(arr, dtype=proto.dtype if hasattr(
                proto, "dtype") else arr.dtype))
        return step, jax.tree_util.tree_unflatten(treedef, leaves)


# ------------------------------------------------------------ engine layer
_STATE = "state/"        # EngineState leaves
_TRACE_SEG = "trace_seg/"  # drained TraceStream spans: trace_seg/<agent>/<start>
_METRICS = "metrics/"    # MetricsStream interval records: metrics/lines


class SimCheckpoint(NamedTuple):
    """One restored simulation checkpoint.

    ``state`` is the unpadded (A, ...) ``EngineState`` — pass it to any
    driver's ``state=``; the distributed drivers re-pad for whatever mesh
    they are given, so a checkpoint taken on D devices restores onto D'.
    ``rung`` is the adaptive ladder rung chosen for the *next* window at
    save time (None for the static drivers) — pass it to
    ``run_adaptive``/``run_distributed_adaptive``'s ``rung=``.
    """

    step: int
    state: Any
    rung: int | None


class SimCheckpointer(Checkpointer):
    """Engine-aware checkpointing at GVT-aligned window boundaries.

    Attach to an :class:`~repro.core.engine.Engine` (``checkpointer=``):
    every ``every`` windows the engine hands the unpadded ``EngineState``
    (plus the adaptive rung, if any) to :meth:`save_sim`. Saves are
    blocking — the window boundary is the only point where the device
    state, the host-side drained trace spans, and the policy rung are
    mutually consistent, so the save must complete before the next window
    mutates any of them.

    ``kill_after`` is the crash-harness knob: SIGKILL this process right
    after the first *committed* checkpoint at a window >= ``kill_after``
    (a real, unhandled kill — the atomic-rename commit point is what makes
    the resulting checkpoint directory trustworthy).
    """

    def __init__(self, directory: str, every: int = 0, keep: int = 3,
                 kill_after: int | None = None):
        super().__init__(directory, keep=keep)
        if every < 0:
            raise ValueError(f"every must be >= 0, got {every}")
        self.every = int(every)
        self.kill_after = kill_after

    def due(self, window: int) -> bool:
        """Does the cadence call for a save at this window boundary?"""
        return self.every > 0 and window > 0 and window % self.every == 0

    # ------------------------------------------------------------------ save
    def save_sim(self, window: int, state, *, engine=None,
                 rung: int | None = None) -> None:
        """Save one window-boundary snapshot (blocking, atomic).

        ``state`` must be the unpadded (A, ...) ``EngineState``. With
        ``engine`` given, the attached :class:`TraceStream`'s drained spans
        and the attached :class:`MetricsStream`'s emitted interval records
        ride along (after an ``effects_barrier`` so every in-flight window
        callback has landed) — a streamed run resumed from this checkpoint
        reassembles the full ``[0, trace_n)`` trace and a metrics record
        sequence that concatenates exactly onto the uninterrupted run's.
        """
        arrays = {_STATE + k: np.asarray(v) for k, v in _tree_paths(state)}
        ts = getattr(engine, "trace_stream", None)
        ms = getattr(engine, "metrics_stream", None)
        if ts is not None or ms is not None:
            getattr(jax, "effects_barrier", lambda: None)()
        if ts is not None:
            for k, rows in ts.state_dict().items():
                arrays[_TRACE_SEG + k] = rows
        if ms is not None:
            for k, rows in ms.state_dict().items():
                arrays[_METRICS + k] = rows
        manifest = {
            "step": window,
            "sim": True,
            "rung": rung,
            "n_agents": int(np.asarray(state.t_now).shape[0]),
            "keys": sorted(arrays),
            "shapes": {k: list(v.shape) for k, v in arrays.items()},
            "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        }
        self._write_step(window, arrays, manifest, blocking=True)
        if self.kill_after is not None and window >= int(self.kill_after):
            os.kill(os.getpid(), signal.SIGKILL)  # the crash harness

    # --------------------------------------------------------------- restore
    def restore_sim(self, engine, step: int | None = None) -> SimCheckpoint:
        """Restore a checkpoint into ``engine``'s state structure.

        Validates every leaf against ``engine.init_state()`` (same scenario
        spec => same unpadded shapes regardless of device count) and loads
        the saved drained-trace spans into ``engine.trace_stream`` and the
        saved metrics records into ``engine.metrics_stream`` (both are
        consumed by the stream's next ``begin()``, i.e. when a driver runs).
        Returns a :class:`SimCheckpoint`; feed ``state``/``rung`` to any
        driver.
        """
        step, blob, manifest = self._read_step(step)
        template = engine.init_state()
        flat, treedef = jax.tree_util.tree_flatten(template)
        keyed = _tree_paths(template)
        want = {_STATE + k for k, _ in keyed}
        have = {k for k in manifest["keys"] if k.startswith(_STATE)}
        if want != have:
            raise ValueError(
                f"checkpoint does not match this engine's EngineState: "
                f"missing {sorted(want - have)[:5]} "
                f"unexpected {sorted(have - want)[:5]}")
        leaves = []
        for (k, _), proto in zip(keyed, flat):
            arr = blob[_STATE + k]
            if tuple(arr.shape) != tuple(np.shape(proto)):
                raise ValueError(
                    f"checkpoint leaf {k!r} has shape {arr.shape}, engine "
                    f"expects {np.shape(proto)} — same scenario spec "
                    f"(n_agents, pool_cap, trace_cap) required to resume")
            leaves.append(jnp.asarray(arr, dtype=proto.dtype))
        state = jax.tree_util.tree_unflatten(treedef, leaves)
        segs = {k[len(_TRACE_SEG):]: np.asarray(blob[k])
                for k in manifest["keys"] if k.startswith(_TRACE_SEG)}
        ts = getattr(engine, "trace_stream", None)
        if ts is not None and segs:
            ts.load_state(segs)
        recs = {k[len(_METRICS):]: np.asarray(blob[k])
                for k in manifest["keys"] if k.startswith(_METRICS)}
        ms = getattr(engine, "metrics_stream", None)
        if ms is not None and recs:
            ms.load_state(recs)
        rung = manifest.get("rung")
        return SimCheckpoint(step=step, state=state,
                             rung=None if rung is None else int(rung))
