"""Checkpointing: atomic, async, per-host sharded, resume-exact.

Layout (one step):
  <dir>/step_000123.tmp/            written first
      host_<k>.npz                  this host's param/opt shards (flattened tree)
      manifest.json                 treedef + shapes + dtypes + step + mesh
  <dir>/step_000123/                atomic rename on completion (commit point)

Restart picks the highest committed step, validates the manifest against the
current tree structure, and re-shards automatically (arrays are saved unsharded
per host slice; on mesh change ft/elastic.py derives the new slicing). The async
writer runs in a daemon thread; ``wait()`` joins before the next save or exit.

A 1000-node deployment maps host_<k> to the process index; here (single process)
k == 0 holds the full tree, which keeps tests exact without loss of generality.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _tree_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, *, host: int = 0, blocking: bool = False):
        self.wait()
        arrays = {k: np.asarray(v) for k, v in _tree_paths(tree)}
        manifest = {
            "step": step,
            "keys": sorted(arrays),
            "shapes": {k: list(v.shape) for k, v in arrays.items()},
            "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        }

        def write():
            tmp = os.path.join(self.dir, f"step_{step:09d}.tmp")
            final = os.path.join(self.dir, f"step_{step:09d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, f"host_{host}.npz"), **arrays)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)                      # commit point
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: int | None = None, *, host: int = 0):
        """Restore into the structure of ``tree_like``. Returns (step, tree)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        blob = np.load(os.path.join(path, f"host_{host}.npz"))
        want = {k for k, _ in _tree_paths(tree_like)}
        have = set(manifest["keys"])
        if want != have:
            raise ValueError(
                f"checkpoint structure mismatch: missing {sorted(want - have)[:5]} "
                f"unexpected {sorted(have - want)[:5]}")
        flat, treedef = jax.tree_util.tree_flatten(tree_like)
        keys = [k for k, _ in _tree_paths(tree_like)]
        leaves = []
        for k, proto in zip(keys, flat):
            arr = blob[k]
            leaves.append(jnp.asarray(arr, dtype=proto.dtype if hasattr(
                proto, "dtype") else arr.dtype))
        return step, jax.tree_util.tree_unflatten(treedef, leaves)
