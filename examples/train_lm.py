"""End-to-end driver: train a ~100M-param smollm-135m variant for 300 steps on
the synthetic Markov pipeline, with checkpointing + resume.

(The assignment's full smollm-135m is 135M params; on this CPU container we
train a width-reduced sibling by default — pass --full for the real config.)

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300] [--full]
"""
import argparse
import dataclasses
import tempfile

from repro.configs.base import TrainConfig
from repro.configs.registry import get_config, smoke_config
from repro.data import pipeline as dp
from repro.models.model import build_model
from repro.train.loop import train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--full", action="store_true")
args = ap.parse_args()

if args.full:
    cfg = dataclasses.replace(get_config("smollm-135m"), dtype="float32")
else:
    cfg = dataclasses.replace(
        smoke_config("smollm-135m"), n_layers=4, d_model=128, n_heads=4,
        n_kv=2, d_ff=384, vocab=2048, head_dim=32, dtype="float32")

model = build_model(cfg)
tc = TrainConfig(learning_rate=3e-3, warmup_steps=20)
dcfg = dp.DataConfig(vocab=cfg.vocab, seq_len=128, global_batch=8)

with tempfile.TemporaryDirectory() as ckpt_dir:
    params, opt_state, history = train(
        model, tc, steps=args.steps, data_cfg=dcfg, ckpt_dir=ckpt_dir,
        ckpt_every=100, log_every=25)

first = sum(history[:20]) / len(history[:20])
last = sum(history[-20:]) / len(history[-20:])
print(f"\nloss: {first:.3f} -> {last:.3f} over {len(history)} steps")
assert last < first, "training did not reduce loss"
print("OK")
