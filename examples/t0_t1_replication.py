"""Paper §3.1: the T0/T1 data-replication study, including Fig 2's effect.

Sweeps the simulated WAN bandwidth and reports event counts + wall time: as
bandwidth shrinks, transfers overlap, every start/finish re-shares the links
(the interrupt scheme) and invalidates predicted completions — event count and
simulation cost grow super-linearly. The distributed fleet (4 agents) then
absorbs exactly that growth, which is the paper's core argument.

Run: PYTHONPATH=src python examples/t0_t1_replication.py
"""
import time

import numpy as np

from repro.core import Engine, ScenarioBuilder, events as ev
from repro.core import monitoring as mon


def build(bw, n_agents):
    b = ScenarioBuilder(max_cpu=4, queue_cap=16, max_link=4, max_flow=32)
    t0c = b.add_regional_center(n_cpu=2, cpu_power=10.0, disk=2000.0,
                                tape=20000.0, tape_rate=5.0)
    t1c = b.add_regional_center(n_cpu=2, cpu_power=8.0, disk=2000.0,
                                tape=20000.0, tape_rate=5.0)
    wan = b.add_net_region(link_bws=[bw, bw], link_lats=[5, 5])
    b.add_generator(target_lp=wan, kind=ev.K_FLOW_START,
                    payload=[40.0, 0, -1, -1, t1c["farm"], ev.K_JOB_SUBMIT,
                             t1c["storage"], ev.K_DATA_WRITE],
                    interval=15, count=24)
    return b.build(n_agents=n_agents, lookahead=2, t_end=100_000,
                   pool_cap=1024, work_per_mb=2.0)


print(f"{'bw MB/tick':>10} {'events':>8} {'stale':>6} {'interrupts':>10} "
      f"{'wall ms':>8}")
rows = []
for bw in (8.0, 2.0, 0.5, 0.125):
    built = build(bw, 1)
    eng = Engine(*built)
    eng.run_local(max_windows=200_000)           # compile
    t0 = time.perf_counter()
    st = eng.run_local(max_windows=200_000)
    dt = (time.perf_counter() - t0) * 1e3
    c = np.asarray(st.counters).sum(axis=0)
    rows.append((bw, int(c[mon.C_EVENTS]), dt))
    print(f"{bw:>10.3f} {int(c[mon.C_EVENTS]):>8d} "
          f"{int(c[mon.C_STALE]):>6d} {int(c[mon.C_INTERRUPTS]):>10d} "
          f"{dt:>8.1f}")

# Fig-2 shape check: events grow as bandwidth shrinks
events = [r[1] for r in rows]
assert events[-1] > events[0], "interrupt storm did not materialize"
print("\nFig-2 effect reproduced: "
      f"{events[0]} events at high bw -> {events[-1]} at starved bw")
