"""Quickstart: build a small Grid model, simulate it distributed, read results.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import Engine, ScenarioBuilder
from repro.core import monitoring as mon
from repro.core.components import DATA_WRITE, FLOW_START, JOB_SUBMIT

# --- 1. describe the system (paper fig 1: regional centers) ---------------
b = ScenarioBuilder(max_cpu=4, queue_cap=16, max_link=4, max_flow=32)
tier0 = b.add_regional_center(n_cpu=2, cpu_power=10.0, disk=1000.0,
                              tape=10000.0, tape_rate=5.0)
tier1 = b.add_regional_center(n_cpu=2, cpu_power=8.0, disk=500.0,
                              tape=5000.0, tape_rate=5.0)
wan = b.add_net_region(link_bws=[1.0, 1.0], link_lats=[5, 5])

# production at tier-0 replicates 40 MB datasets to tier-1; each arrival
# triggers an analysis job whose output lands in tier-1 storage. Payloads are
# packed by field name through the kind's PayloadSpec (the declarative model
# in repro/core/components.py; see docs/scenario_api.md) — no index lists.
b.add_generator(
    target_lp=wan, kind=FLOW_START,
    payload=FLOW_START.pack(size=40.0, l0=0,
                            notify_lp=tier1["farm"],
                            notify_kind=JOB_SUBMIT.id,
                            notify2_lp=tier1["storage"],
                            notify2_kind=DATA_WRITE.id),
    interval=20, count=16)

# --- 2. build for a 4-agent fleet and run ----------------------------------
# Engine step 4 defaults to grouped vectorized dispatch (conflict-free events
# of one window execute in a single vmapped handler call whose per-row deltas
# merge as segment scatters, byte-identical to the sequential fold); pass
# batched_dispatch=False here — or --no-batched-dispatch on launch/simulate.py
# — to force the sequential path, and merge_mode="dense" to force the
# whole-table reference merge. docs/architecture.md walks the whole pipeline;
# benchmarks/run.py --json PATH dumps machine-readable rows comparing paths.
world, own, init_events, spec = b.build(n_agents=4, lookahead=2, t_end=20_000,
                                        pool_cap=512, work_per_mb=2.0)
engine = Engine(world, own, init_events, spec)
state = engine.run_local()          # vmap fleet; .run_distributed(mesh) on pods

# --- 3. inspect ------------------------------------------------------------
c = np.asarray(state.counters).sum(axis=0)
w = jax.tree.map(lambda x: np.asarray(x[0]), state.world)
print(f"windows (conservative syncs): {int(np.asarray(state.windows)[0])}")
print(f"events processed:             {int(c[mon.C_EVENTS])}")
print(f"flows completed:              {int(c[mon.C_FLOWS_DONE])}")
print(f"interrupt re-shares:          {int(c[mon.C_INTERRUPTS])}")
print(f"stale completions:            {int(c[mon.C_STALE])}")
print(f"jobs finished:                {int(c[mon.C_JOBS_DONE])}")
print(f"tier-1 disk/tape MB:          {w.sto_used[1].round(1).tolist()}")
assert int(c[mon.C_FLOWS_DONE]) == 16
print("OK")
