"""Serve a small model with batched requests through the production engine.

Run: PYTHONPATH=src python examples/serve_lm.py
"""
import dataclasses
import time

import jax

from repro.configs.registry import smoke_config
from repro.models.model import build_model
from repro.serve.engine import Request, ServeEngine

cfg = dataclasses.replace(smoke_config("deepseek-7b"), dtype="float32",
                          cache_headroom=16)
model = build_model(cfg)
params, _ = model.init(jax.random.PRNGKey(0))

engine = ServeEngine(model, params, batch_slots=4, prompt_len=32,
                     temperature=0.0)

rng = jax.random.PRNGKey(1)
requests = []
for i in range(8):
    rng, k = jax.random.split(rng)
    prompt = jax.random.randint(k, (10,), 1, cfg.vocab).tolist()
    requests.append(Request(rid=i, tokens=prompt, max_new=12))

t0 = time.perf_counter()
for i in range(0, len(requests), 4):
    engine.run(requests[i:i + 4], max_ticks=14)
dt = time.perf_counter() - t0

tokens = sum(len(r.out) for r in requests)
print(f"served {len(requests)} requests / {tokens} tokens in {dt:.2f}s "
      f"({tokens / dt:.1f} tok/s, batch=4)")
for r in requests[:3]:
    print(f"  req {r.rid}: prompt {r.tokens[:5]}... -> {r.out}")
assert all(r.done for r in requests)
print("OK")
